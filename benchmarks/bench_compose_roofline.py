"""Roofline of the PAPER'S TECHNIQUE at pod scale (hillclimb cell #3).

The dataset-level audit of §IV — 'which source records reach the training
set' — at production size: a packed lineage relation between 4.2M corpus
documents and 131k packed sequences, sharded row-wise over the data axes of
the 16x16 mesh.  Three lowered programs are analyzed (hloanal terms):

  audit      AND + popcount + psum        (the backward_frontier/audit path)
  compose32  (OR,AND)-matmul, f32 unpack  (naive composition step)
  composebf  (OR,AND)-matmul, bf16 unpack (halved traffic, same result)

plus the ANALYTIC terms for the Pallas bitplane kernel (repro.kernels), which
executes 32 boolean MACs per uint32 VPU lane-op — the TPU-native path this
container can only validate in interpret mode.

    PYTHONPATH=src python -m benchmarks.bench_compose_roofline
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hloanal import analyze_hlo
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
# VPU: 8 cores x (8,128) lanes x ~940 MHz ~= 1e12 lane-ops/s; each uint32
# lane-op retires 32 boolean MACs in the bitplane kernel.
VPU_WORD_OPS = 0.96e12

N_DOCS = 4_194_304        # 4M corpus documents
N_SEQ = 131_072           # packed sequences (the training set's row space)
DW = N_SEQ // 32          # packed words per doc row


def _spec(mesh, *axes):
    return NamedSharding(mesh, P(*axes))


def lower_audit(mesh):
    rel = jax.ShapeDtypeStruct((N_DOCS, DW), jnp.uint32)
    mask = jax.ShapeDtypeStruct((DW,), jnp.uint32)
    group = jax.ShapeDtypeStruct((N_DOCS,), jnp.int32)

    def audit(rel, group, mask):
        hit_words = rel & mask[None, :]
        hits = jax.lax.population_count(hit_words).astype(jnp.int32).sum(axis=1) > 0
        onehot = jax.nn.one_hot(group, 8, dtype=jnp.int32)
        return (hits.astype(jnp.int32)[:, None] * onehot).sum(axis=0)

    with jax.set_mesh(mesh):
        return jax.jit(
            audit,
            in_shardings=(_spec(mesh, "data", None), _spec(mesh, "data"),
                          _spec(mesh, None)),
            out_shardings=_spec(mesh, None),
        ).lower(rel, group, mask).compile()


def lower_compose(mesh, unpack_dtype):
    # one composition hop: sequences->batches relation applied to the
    # doc->sequence relation: (N_DOCS, N_SEQ) x (N_SEQ, N_BATCH)
    n_batch_w = 1024 // 32
    a = jax.ShapeDtypeStruct((N_DOCS, DW), jnp.uint32)
    b = jax.ShapeDtypeStruct((N_SEQ, n_batch_w), jnp.uint32)

    def compose(a_bits, b_bits):
        shifts = jnp.arange(32, dtype=jnp.uint32)
        au = ((a_bits[:, :, None] >> shifts) & 1).reshape(N_DOCS, DW * 32)
        bu = ((b_bits[:, :, None] >> shifts) & 1).reshape(N_SEQ, n_batch_w * 32)
        c = (au.astype(unpack_dtype) @ bu.astype(unpack_dtype)) > 0
        cw = (c.reshape(N_DOCS, n_batch_w, 32).astype(jnp.uint32)
              << shifts[None, None, :]).sum(axis=-1, dtype=jnp.uint32)
        return cw

    with jax.set_mesh(mesh):
        return jax.jit(
            compose,
            in_shardings=(_spec(mesh, "data", None), _spec(mesh, None, None)),
            out_shardings=_spec(mesh, "data", None),
        ).lower(a, b).compile()


def run(quick: bool = False):
    mesh = make_production_mesh()
    n_chips = 256
    rows = []
    for name, builder in [
        ("audit", lambda: lower_audit(mesh)),
        ("compose_f32", lambda: lower_compose(mesh, jnp.float32)),
        ("compose_bf16", lambda: lower_compose(mesh, jnp.bfloat16)),
    ]:
        compiled = builder()
        h = analyze_hlo(compiled.as_text())
        t_c = h.dot_flops / PEAK_FLOPS
        t_m = h.traffic_bytes / HBM_BW
        t_x = h.collective_bytes / LINK_BW
        rows.append({"variant": name, "t_compute_s": t_c, "t_memory_s": t_m,
                     "t_collective_s": t_x,
                     "dominant": max([("compute", t_c), ("memory", t_m),
                                      ("collective", t_x)], key=lambda kv: kv[1])[0]})

    # analytic Pallas bitplane kernel terms for the same compose hop
    word_ops = (N_DOCS / n_chips) * N_SEQ * (1024 // 32)   # m*k*nw per device
    t_vpu = word_ops / VPU_WORD_OPS
    bytes_hbm = ((N_DOCS / n_chips) * DW * 4               # A shard read
                 + N_SEQ * (1024 // 32) * 4                # B read (fits VMEM? no: streamed)
                 + (N_DOCS / n_chips) * (1024 // 32) * 4)  # C write
    rows.append({"variant": "compose_pallas(analytic)",
                 "t_compute_s": t_vpu, "t_memory_s": bytes_hbm / HBM_BW,
                 "t_collective_s": 0.0,
                 "dominant": "compute" if t_vpu > bytes_hbm / HBM_BW else "memory"})

    print("\n== Paper-technique roofline: 4.2M docs x 131k sequences, 16x16 mesh ==")
    print(f"{'variant':26s} {'compute':>10s} {'memory':>10s} {'collective':>11s} {'dominant':>9s}")
    for r in rows:
        print(f"{r['variant']:26s} {r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:11.4f} {r['dominant']:>9s}")
    return {"table": "compose_roofline", "rows": rows}


if __name__ == "__main__":
    run()
