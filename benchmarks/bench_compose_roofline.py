"""Roofline of the PAPER'S TECHNIQUE at pod scale (hillclimb cell #3).

The dataset-level audit of §IV — 'which source records reach the training
set' — at production size: a packed lineage relation between 4.2M corpus
documents and 131k packed sequences, sharded row-wise over the data axes of
the 16x16 mesh.  Three lowered programs are analyzed (hloanal terms):

  audit      AND + popcount + psum        (the backward_frontier/audit path)
  compose32  (OR,AND)-matmul, f32 unpack  (naive composition step)
  composebf  (OR,AND)-matmul, bf16 unpack (halved traffic, same result)

plus the Pallas bitplane kernel terms (repro.kernels).  The machine numbers
(peak FLOPs / HBM / VPU word-op rate) come from the cost model's active
:class:`~repro.core.costmodel.Constants` — the TPU-v5e defaults until a
calibration file overrides them — so this bench and the query router can
never disagree about the machine.

The ``kernels`` section is MEASURED, not analytic: the fused
:func:`repro.kernels.ops.batched_walk` against its per-hop unfused baseline
(``bitmatmul`` + ``bitset_rank`` + ``lineage_gather`` per hop) on a K-hop
chain, with the K×3 → 1 launch reduction asserted off the kernel layer's
dispatch counters.  ``--quick`` runs ONLY this measured section (no
512-device mesh lowering) and merges it into ``BENCH_query.json``.

    PYTHONPATH=src python -m benchmarks.bench_compose_roofline [--quick]
"""
import os
import sys

if "--quick" not in sys.argv:
    # full mode lowers against the 16x16 production mesh on host
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")

import json
import time

import numpy as np

from repro.core import costmodel

N_DOCS = 4_194_304        # 4M corpus documents
N_SEQ = 131_072           # packed sequences (the training set's row space)
DW = N_SEQ // 32          # packed words per doc row


def _spec(mesh, *axes):
    from jax.sharding import NamedSharding, PartitionSpec as P

    return NamedSharding(mesh, P(*axes))


def lower_audit(mesh):
    import jax
    import jax.numpy as jnp

    rel = jax.ShapeDtypeStruct((N_DOCS, DW), jnp.uint32)
    mask = jax.ShapeDtypeStruct((DW,), jnp.uint32)
    group = jax.ShapeDtypeStruct((N_DOCS,), jnp.int32)

    def audit(rel, group, mask):
        hit_words = rel & mask[None, :]
        hits = jax.lax.population_count(hit_words).astype(jnp.int32).sum(axis=1) > 0
        onehot = jax.nn.one_hot(group, 8, dtype=jnp.int32)
        return (hits.astype(jnp.int32)[:, None] * onehot).sum(axis=0)

    with jax.set_mesh(mesh):
        return jax.jit(
            audit,
            in_shardings=(_spec(mesh, "data", None), _spec(mesh, "data"),
                          _spec(mesh, None)),
            out_shardings=_spec(mesh, None),
        ).lower(rel, group, mask).compile()


def lower_compose(mesh, unpack_dtype):
    import jax
    import jax.numpy as jnp

    # one composition hop: sequences->batches relation applied to the
    # doc->sequence relation: (N_DOCS, N_SEQ) x (N_SEQ, N_BATCH)
    n_batch_w = 1024 // 32
    a = jax.ShapeDtypeStruct((N_DOCS, DW), jnp.uint32)
    b = jax.ShapeDtypeStruct((N_SEQ, n_batch_w), jnp.uint32)

    def compose(a_bits, b_bits):
        shifts = jnp.arange(32, dtype=jnp.uint32)
        au = ((a_bits[:, :, None] >> shifts) & 1).reshape(N_DOCS, DW * 32)
        bu = ((b_bits[:, :, None] >> shifts) & 1).reshape(N_SEQ, n_batch_w * 32)
        c = (au.astype(unpack_dtype) @ bu.astype(unpack_dtype)) > 0
        cw = (c.reshape(N_DOCS, n_batch_w, 32).astype(jnp.uint32)
              << shifts[None, None, :]).sum(axis=-1, dtype=jnp.uint32)
        return cw

    with jax.set_mesh(mesh):
        return jax.jit(
            compose,
            in_shardings=(_spec(mesh, "data", None), _spec(mesh, None, None)),
            out_shardings=_spec(mesh, "data", None),
        ).lower(a, b).compile()


def _pack(rng, rows, cols, density):
    import jax.numpy as jnp

    from repro.kernels import ref

    return np.asarray(ref.pack_bits(jnp.asarray(rng.random((rows, cols)) < density)))


def _median_ms(fn, reps=5, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


def bench_kernels(n: int = 512, batch: int = 64, hops: int = 6,
                  reps: int = 5) -> dict:
    """MEASURED fused-vs-unfused walk on a K-hop chain.

    Both paths run through their kernel-launch guard (``use_pallas=None``:
    Pallas on TPU, the jnp oracles on hosts); the K×3 → 1 launch reduction
    is asserted exactly off :func:`repro.kernels.ops.launch_counts`, and
    the two results are byte-compared before timing.
    """
    from repro.kernels import ops as K

    rng = np.random.default_rng(7)
    planes = [_pack(rng, n, n, 0.02) for _ in range(hops)]
    mask = _pack(rng, batch, n, 0.05)

    def run_fused():
        out, counts = K.batched_walk(mask, planes, use_pallas=None)
        return np.asarray(out), np.asarray(counts)

    def run_unfused():
        out, counts = K.batched_walk_unfused(mask, planes, use_pallas=None)
        return np.asarray(out), np.asarray(counts)

    # launch accounting: one probe each, counted exactly
    K.reset_launch_counts()
    fused_out = run_fused()
    lc = K.launch_counts()
    launches_fused = sum(lc.values())
    assert launches_fused == 1, lc
    K.reset_launch_counts()
    unfused_out = run_unfused()
    lc = K.launch_counts()
    launches_unfused = sum(lc.values())
    assert launches_unfused == 3 * hops, lc
    assert np.array_equal(fused_out[0], unfused_out[0])
    assert np.array_equal(fused_out[1], unfused_out[1])

    fused_ms = _median_ms(run_fused, reps=reps)
    unfused_ms = _median_ms(run_unfused, reps=reps)
    section = {
        "n": n, "batch": batch, "hops": hops,
        "fused_ms": fused_ms, "unfused_ms": unfused_ms,
        "speedup": unfused_ms / fused_ms if fused_ms else float("inf"),
        "launches_fused": launches_fused,
        "launches_unfused": launches_unfused,
        "constants": costmodel.constants_provenance(),
    }
    print(f"\n== Fused batched walk: n={n}, B={batch}, K={hops} hops ==")
    print(f"unfused (3 launches/hop): {unfused_ms:8.2f} ms  "
          f"({launches_unfused} launches)")
    print(f"fused   (1 launch total): {fused_ms:8.2f} ms  "
          f"({launches_fused} launch)   speedup {section['speedup']:.1f}x")
    return section


def run(quick: bool = False):
    costmodel.maybe_load_calibration()
    c = costmodel.active_constants()
    kernels = bench_kernels() if quick else bench_kernels(reps=7)
    if quick:
        # the mesh-lowered variants force a 512-device host platform and a
        # multi-minute compile; quick mode reports the measured section only
        return {"kernels": kernels}

    from repro.launch.hloanal import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    import jax.numpy as jnp

    mesh = make_production_mesh()
    n_chips = 256
    rows = []
    for name, builder in [
        ("audit", lambda: lower_audit(mesh)),
        ("compose_f32", lambda: lower_compose(mesh, jnp.float32)),
        ("compose_bf16", lambda: lower_compose(mesh, jnp.bfloat16)),
    ]:
        compiled = builder()
        h = analyze_hlo(compiled.as_text())
        t_c = h.dot_flops / c.peak_flops
        t_m = h.traffic_bytes / c.hbm_bw
        t_x = h.collective_bytes / c.link_bw
        rows.append({"variant": name, "t_compute_s": t_c, "t_memory_s": t_m,
                     "t_collective_s": t_x,
                     "dominant": max([("compute", t_c), ("memory", t_m),
                                      ("collective", t_x)], key=lambda kv: kv[1])[0]})

    # analytic Pallas bitplane kernel terms for the same compose hop
    word_ops = (N_DOCS / n_chips) * N_SEQ * (1024 // 32)   # m*k*nw per device
    t_vpu = word_ops / c.vpu_word_ops
    bytes_hbm = ((N_DOCS / n_chips) * DW * 4               # A shard read
                 + N_SEQ * (1024 // 32) * 4                # B read (fits VMEM? no: streamed)
                 + (N_DOCS / n_chips) * (1024 // 32) * 4)  # C write
    rows.append({"variant": "compose_pallas(analytic)",
                 "t_compute_s": t_vpu, "t_memory_s": bytes_hbm / c.hbm_bw,
                 "t_collective_s": 0.0,
                 "dominant": "compute" if t_vpu > bytes_hbm / c.hbm_bw else "memory"})

    print("\n== Paper-technique roofline: 4.2M docs x 131k sequences, 16x16 mesh ==")
    print(f"{'variant':26s} {'compute':>10s} {'memory':>10s} {'collective':>11s} {'dominant':>9s}")
    for r in rows:
        print(f"{r['variant']:26s} {r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:11.4f} {r['dominant']:>9s}")
    return {"table": "compose_roofline", "rows": rows, "kernels": kernels,
            "machine": {"peak_flops": c.peak_flops, "hbm_bw": c.hbm_bw,
                        "link_bw": c.link_bw, "vpu_word_ops": c.vpu_word_ops,
                        "source": c.source}}


def _merge_trajectory(results: dict) -> None:
    """``BENCH_query.json`` belongs to bench_query.py; this bench only
    merges its ``kernels`` section (creating the file if needed)."""
    path = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir,
        "BENCH_query.json"))
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["kernels"] = results["kernels"]
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"wrote {path} (kernels section)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="measured kernels section only (no 512-device mesh "
                    "lowering) — still merges into BENCH_query.json")
    args = ap.parse_args()
    _merge_trajectory(run(quick=args.quick))
