"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run report (reports/dryrun_baseline.json by default) and, per
cell:

    compute term    = HLO_dot_FLOPs(dev)   / peak_FLOP/s            [s]
    memory term     = HLO_traffic(dev)     / HBM_bw                 [s]
    collective term = collective_bytes(dev)/ link_bw                [s]

(The per-device HLO numbers already divide by the chip count — see
launch/hloanal.py; trips through lax.scan are multiplied back in.)

Also: MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference) and the
usefulness ratio MODEL/HLO, the dominant term, and a one-line 'what would
move it' note.  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs.registry import get_config
from repro.launch.shapes import SHAPES

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
LINK_BW = 50e9              # B/s / link

_MOVE_NOTES = {
    "compute": "raise per-chip utilization: fewer remat recomputes, larger "
               "microbatch, fused attention",
    "memory": "cut HBM traffic: tighter remat policy, fuse elementwise "
              "chains, bf16 intermediates, avoid resharded copies",
    "collective": "cut bytes over ICI: reduce-scatter instead of all-reduce, "
                  "overlap collectives with compute, shard so weights stay "
                  "resident (no per-layer all-gather)",
}


def model_flops_per_device(arch: str, shape: str, n_chips: int) -> float:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n_active = cfg.n_active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / n_chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = cell.global_batch              # decode: one token per request
    return 2.0 * n_active * tokens / n_chips


def analyze(report_path: str = "reports/dryrun_baseline.json",
            mesh: Optional[str] = None) -> List[Dict]:
    with open(report_path) as f:
        data = json.load(f)
    rows = []
    for rec in data["results"]:
        if rec["status"] != "ok" or "hlo" not in rec or "error" in rec.get("hlo", {}):
            if rec["status"] == "skip":
                rows.append({**rec, "dominant": "skip"})
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        n_chips = 512 if rec["mesh"] == "multi" else 256
        h = rec["hlo"]
        t_c = h["dot_flops"] / PEAK_FLOPS
        t_m = h["traffic_bytes"] / HBM_BW
        t_x = h["collective_bytes"] / LINK_BW
        dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                       key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(rec["arch"], rec["shape"], n_chips)
        bound = max(t_c, t_m, t_x)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dominant,
            "model_flops_dev": mf,
            "useful_ratio": mf / h["dot_flops"] if h["dot_flops"] else 0.0,
            "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
            "note": _MOVE_NOTES[dominant],
            "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def print_table(rows: List[Dict]) -> None:
    print("\n== Roofline (per device, seconds per step) ==")
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'compute':>9s} "
           f"{'memory':>9s} {'collect':>9s} {'domnt':>7s} {'useful':>7s} "
           f"{'roofl%':>7s} {'tempGB':>7s}")
    print(hdr)
    for r in rows:
        if r.get("dominant") == "skip":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
                  f"{'SKIP (' + r['reason'][:40] + ')':>40s}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['dominant'][:7]:>7s} "
              f"{r['useful_ratio']:7.3f} {100*r['roofline_fraction']:6.1f}% "
              f"{r['temp_gb']:7.1f}")


def run(quick: bool = False, report: Optional[str] = None):
    if report is None:
        for cand in ("reports/dryrun_optimized.json", "reports/dryrun_baseline.json"):
            if os.path.exists(cand):
                report = cand
                break
    if report is None or not os.path.exists(report):
        print("[roofline] no dry-run report found; "
              "run `python -m repro.launch.dryrun` first")
        return {"table": "roofline", "rows": []}
    print(f"[roofline] report: {report}")
    rows = analyze(report)
    print_table(rows)
    return {"table": "roofline", "rows": rows}


if __name__ == "__main__":
    import sys
    run(report=sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun_baseline.json")
