"""Benchmark harness entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Order: memory (Table IX), capture (Fig 3 / Table X), query (Fig 4/5),
join_scale (Table XI / Fig 6), roofline (assignment deliverable g — reads
the dry-run report if present).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import bench_memory, bench_capture, bench_query, bench_join_scale
from benchmarks import roofline

BENCHES = {
    "memory": bench_memory.run,
    "capture": bench_capture.run,
    "query": bench_query.run,
    "join_scale": bench_join_scale.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scale factors / fewer reps")
    ap.add_argument("--only", choices=list(BENCHES), default=None)
    ap.add_argument("--out", default="reports/bench_results.json")
    args = ap.parse_args()

    names = [args.only] if args.only else list(BENCHES)
    results = {}
    for name in names:
        print(f"\n######## bench: {name} ########")
        t0 = time.time()
        results[name] = BENCHES[name](quick=args.quick)
        print(f"[{name}] done in {time.time() - t0:.1f}s")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
