"""Serving-tier benchmark: cross-request fusion under concurrent load.

The scenario the async tier exists for — MANY independent lineage requests
(mixed Q1/Q2/Q4 against the deep chain, the same workload shape as
``bench_query.run_fused_batch``) arriving one at a time from many tenants:

* **saturation** — every request already queued (a burst): the sync
  per-request loop answers them one ``session.run`` at a time; the tier
  coalesces same-fuse-key plans into ``max_batch``-wide fused passes.  The
  headline is fused throughput / sync throughput at saturation.
* **open loop** — Poisson arrivals at a rate the sync loop can just about
  sustain: per-request latency (p50/p99) for the sync loop server vs the
  micro-batching tier.  The tier trades its ``max_wait_ms`` batching delay
  for immunity to queueing collapse.

Answers are asserted BYTE-IDENTICAL between the sequential session and the
tier before anything is timed.

Run as a script this merges a ``serving`` section into ``BENCH_query.json``
at the repo root (the perf-trajectory artifact bench_query.py owns).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import queue
import threading
import time

import numpy as np

try:
    from benchmarks.bench_query import build_deep_chain
except ImportError:                         # run as a script: sibling import
    from bench_query import build_deep_chain

from repro.core.hopcache import ComposedIndex
from repro.provenance import QuerySession, prov
from repro.serve import ServingTier


def make_plans(idx, sink, n_requests: int, seed: int = 11):
    """A mixed Q1/Q2/Q4 request stream (round-robin kinds, random probes)
    — three fuse keys, so the tier packs roughly ``n_requests / 3`` plans
    behind each.

    Each request probes ONE row (Q4: one row, one attr) — the serving
    shape: a request traces ITS response row, not a batch.  Single-probe
    calls are per-call-overhead-bound, which is exactly the regime the
    tier's fusion targets."""
    src = "chain_src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    c_sink = idx.datasets[sink].n_cols
    rng = np.random.default_rng(seed)
    plans = []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:
            plans.append(prov(idx).source(src)
                         .rows([int(rng.integers(n_src))])
                         .forward().to(sink).plan())
        elif kind == 1:
            plans.append(prov(idx).source(sink)
                         .rows([int(rng.integers(n_sink))])
                         .backward().to(src).plan())
        else:
            plans.append(prov(idx).source(sink)
                         .rows([int(rng.integers(n_sink))])
                         .attrs([int(rng.integers(c_sink))])
                         .backward().to(src).plan())
    return plans


class SyncLoopServer:
    """The baseline serving loop: one worker thread, one ``session.run``
    per request, strictly in arrival order — what ``ServeEngine`` offered
    before the tier existed, wrapped in the same future-based submit
    surface so the open-loop driver treats both servers identically."""

    def __init__(self, session) -> None:
        self.session = session
        self._q: "queue.Queue" = queue.Queue()
        self._thread = threading.Thread(target=self._worker,
                                        name="sync-loop", daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            plan, fut = item
            try:
                fut.set_result(self.session.run(plan))
            except Exception as exc:        # noqa: BLE001
                fut.set_exception(exc)

    def submit(self, tenant: str, plan) -> "concurrent.futures.Future":
        fut: "concurrent.futures.Future" = concurrent.futures.Future()
        self._q.put((plan, fut))
        return fut

    def close(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=30)


def _assert_parity(seq_results, tier_results) -> None:
    assert len(seq_results) == len(tier_results)
    for a, b in zip(seq_results, tier_results):
        la = a if isinstance(a, list) else [a]
        lb = b if isinstance(b, list) else [b]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _open_loop(submit, plans, rate_hz: float, seed: int):
    """Poisson arrivals at ``rate_hz``; per-request latency measured from
    submit to future completion (queueing + batching + execution)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=len(plans)))
    lat, lock = [], threading.Lock()

    def _done(fut, t_sub):
        dt = (time.perf_counter() - t_sub) * 1e3
        with lock:
            lat.append(dt)

    futs = []
    t0 = time.perf_counter()
    for i, (arr, plan) in enumerate(zip(arrivals, plans)):
        lag = arr - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        t_sub = time.perf_counter()
        fut = submit(f"tenant-{i % 8}", plan)
        fut.add_done_callback(lambda f, t=t_sub: _done(f, t))
        futs.append(fut)
    for f in futs:
        f.result(timeout=120)
    wall = time.perf_counter() - t0
    lat = np.array(sorted(lat))
    return {
        "rate_hz": float(rate_hz),
        "achieved_hz": len(plans) / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "mean_ms": float(lat.mean()),
    }


def run(quick: bool = False):
    # serving-shaped workload: MANY small per-request probes against a
    # moderate chain — the regime where per-request overhead (plan routing,
    # per-call walk/probe setup) dominates and fusion pays.  Request count
    # scales with mode; the chain does not (a serving tier fronts one
    # pipeline, load is the variable).
    n, n_ops = 1000, 24
    n_requests = 192 if quick else 384     # 3 fuse keys x full max_batch
    max_batch = 64
    reps = 1 if quick else 3
    idx, sink = build_deep_chain(n=n, n_ops=n_ops)

    def fresh_session():
        return QuerySession(idx, ComposedIndex(idx,
                                               memory_budget_bytes=256 << 20))

    plans = make_plans(idx, sink, n_requests)

    # -- parity: the tier's fused answers == the sequential session's -------
    ref_sess = fresh_session()
    seq_results = [ref_sess.run(p) for p in plans]
    with ServingTier(fresh_session(), max_batch=max_batch,
                     max_wait_ms=2.0, max_queue=4 * n_requests) as tier:
        futs = [tier.submit_nowait(f"tenant-{i % 8}", p)
                for i, p in enumerate(plans)]
        _assert_parity(seq_results, [f.result(timeout=120) for f in futs])
    print(f"parity OK: {n_requests} mixed Q1/Q2/Q4 requests, tier == "
          f"sequential session, byte-identical")

    # -- saturation: burst throughput, sync loop vs fused tier --------------
    # fresh warmed sessions per contender; the warm pass composes whatever
    # each cost model chooses, so the timed reps measure probe cost.
    # Medians over paired reps keep the ratio robust to host-load drift.
    sync_sess = fresh_session()
    tier_sess = fresh_session()
    sync_raw, tier_raw = [], []
    with ServingTier(tier_sess, max_batch=max_batch, max_wait_ms=2.0,
                     max_queue=4 * n_requests) as tier:
        for p in plans:                                      # warm passes
            sync_sess.run(p)
        for f in tier.submit_many_nowait("burst", plans):
            f.result(timeout=120)
        for _ in range(reps * 3):
            t0 = time.perf_counter()
            for p in plans:
                sync_sess.run(p)
            sync_raw.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            for f in tier.submit_many_nowait("burst", plans):
                f.result(timeout=120)
            tier_raw.append(time.perf_counter() - t0)
        tier_stats = tier.stats()
    sync_hz = n_requests / max(float(np.median(sync_raw)), 1e-9)
    tier_hz = n_requests / max(float(np.median(tier_raw)), 1e-9)
    speedup = float(np.median(np.array(sync_raw) / np.array(tier_raw)))
    print(f"saturation: sync loop {sync_hz:8.0f} req/s | tier "
          f"{tier_hz:8.0f} req/s ({speedup:.1f}x, max fused width "
          f"{tier_stats['tier']['max_batch_seen']})")

    # -- open loop: Poisson arrivals swept across the sync loop's capacity --
    # (the saturation curve: below sync capacity both serve; above it the
    # sync loop's queue grows without bound while the tier keeps fusing)
    fractions = [0.7] if quick else [0.4, 0.7, 1.0, 1.3]
    curve = []
    for frac in fractions:
        rate = frac * sync_hz
        sync_server = SyncLoopServer(fresh_session())
        sync_server.submit("warm", plans[0]).result(timeout=120)
        open_sync = _open_loop(sync_server.submit, plans, rate, seed=3)
        sync_server.close()
        with ServingTier(fresh_session(), max_batch=max_batch,
                         max_wait_ms=2.0,
                         max_queue=4 * n_requests) as tier:
            tier.submit_sync("warm", plans[0], timeout=120)
            open_tier = _open_loop(tier.submit_nowait, plans, rate, seed=3)
        curve.append({"fraction_of_sync_saturation": frac,
                      "sync_loop": open_sync, "tier": open_tier})
        print(f"open loop @ {rate:6.0f}/s ({frac:.1f}x sync sat): "
              f"sync p50 {open_sync['p50_ms']:6.2f} p99 "
              f"{open_sync['p99_ms']:7.2f} ms | tier p50 "
              f"{open_tier['p50_ms']:6.2f} p99 {open_tier['p99_ms']:7.2f} ms")

    return {
        "n": n, "n_ops": n_ops, "n_requests": n_requests,
        "max_batch": max_batch,
        "parity": "byte-identical",
        "saturation": {
            "sync_loop_req_per_s": sync_hz,
            "tier_req_per_s": tier_hz,
            "speedup_fused": speedup,
            "max_fused_width": tier_stats["tier"]["max_batch_seen"],
            "batches": tier_stats["tier"]["batches"],
        },
        "open_loop_curve": curve,
        "tier_counters": tier_stats["tier"],
    }


def _merge_trajectory(section: dict) -> None:
    """``BENCH_query.json`` belongs to bench_query.py; this bench only
    extends it with the ``serving`` section (creating the file when the
    query bench has not run yet)."""
    path = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir,
        "BENCH_query.json"))
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["serving"] = section
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"wrote {path} (serving section)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced configuration (CI smoke) — still merges "
                    "the serving section into BENCH_query.json")
    args = ap.parse_args()
    _merge_trajectory(run(quick=args.quick))
