"""Impact-analysis benchmark: erasure closure, what-if replay, federated cells.

Three scenarios for the ``repro.provenance.impact`` subsystem:

* **closure** — one ``erasure_plan`` over a deep chain (ONE multi-seed
  forward pass covering every downstream dataset) vs the naive GDPR
  handler: one forward record query PER (erased row, downstream dataset).
  Parity is asserted per dataset before anything is timed.
* **whatif** — ``whatif_replay`` with a small perturbation set against the
  honest alternative: rebuilding the WHOLE pipeline with the patched
  source and reading the same sink rows.  Replay answers are asserted
  equal to the full re-run.  Headline: the rerun/replay ratio
  (acceptance: >= 5x at n=100k with a handful of perturbed rows).
* **federated cells** — the same cells+attrs query through a two-member
  catalog (stitched per-member term walks across a boundary link) vs the
  merged single index, byte-identical answers asserted, cold + warm
  timings reported.

Run as a script this merges an ``impact`` section into ``BENCH_query.json``
at the repo root (the perf-trajectory artifact bench_query.py owns).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import fetch_rows
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import ProvCatalog, erasure_plan, prov, whatif_replay


def _median_ms(fn, reps=5):
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


# ===========================================================================
# (a) erasure closure vs the naive per-row loop
# ===========================================================================
def _chain(n, hops, seed=0, name="cl"):
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex(name)
    t = track(Table.from_columns({
        "k": np.arange(n, dtype=np.float32),
        "x": rng.normal(size=n).astype(np.float32)}), idx, "src")
    for i in range(hops):
        kind = i % 3
        if kind == 0:
            t = t.value_transform("x", "scale", factor=1.0 + i)
        elif kind == 1:
            t = t.filter_rows(rng.random(t.table.n_rows) > 0.05)
        else:
            t = t.oversample(frac=0.04, seed=i, noise=0.0)
    t.mark_sink()
    return idx, t.dataset_id


def run_closure(quick: bool = False):
    n = 5_000 if quick else 50_000
    hops = 9
    n_erase = 8 if quick else 32
    idx, sink = _chain(n, hops)
    rng = np.random.default_rng(1)
    rows = np.unique(rng.integers(0, n, size=n_erase))
    targets = [ds for ds in idx.datasets if ds != "src"]

    # -- parity: plan closure == union of per-row forward queries -----------
    plan = erasure_plan(idx, "src", rows)
    sess = idx.session()

    def naive():
        out = {}
        for ds in targets:
            acc = []
            for r in rows:
                acc.append(prov(idx).source("src").rows([int(r)])
                           .forward().to(ds).run(sess))
            out[ds] = np.unique(np.concatenate(acc))
        return out

    per_row = naive()
    for ds in targets:
        imp = plan.impact(ds)
        got = imp.rows if imp is not None else np.empty(0, np.int64)
        assert np.array_equal(got, per_row[ds]), ds
    print(f"parity: erasure closure == {len(rows)}x{len(targets)} per-row "
          "queries (exact)")

    plan_ms = _median_ms(lambda: erasure_plan(idx, "src", rows))
    naive_ms = _median_ms(naive, reps=3)
    ratio = naive_ms / plan_ms
    print(f"\n== closure: n={n}, {hops} hops, {len(rows)} erased rows ==")
    print(f"erasure_plan (one multi-seed pass) p50 {plan_ms:8.2f} ms")
    print(f"naive per-(row,dataset) loop       p50 {naive_ms:8.2f} ms")
    print(f"speedup: {ratio:.1f}x")
    return {"n": n, "hops": hops, "n_erased": int(len(rows)),
            "plan_ms_p50": plan_ms, "naive_ms_p50": naive_ms,
            "speedup": float(ratio)}


# ===========================================================================
# (b) what-if replay vs full pipeline re-run
# ===========================================================================
def _build_whatif(src_cols, dims_cols, n, name):
    """Frozen-choice pipeline (filter masks drawn from a fixed rng, never
    from data; jitter seeds stored) so a re-run with a patched source is
    EXACTLY comparable to the surgical replay.  A join + a dozen ops make
    the re-run arm representative of a real preparation pipeline."""
    rng = np.random.default_rng(7)
    idx = ProvenanceIndex(name)
    t = track(Table.from_columns({c: v.copy() for c, v in src_cols.items()}),
              idx, "src")
    dims = track(Table.from_columns(
        {c: v.copy() for c, v in dims_cols.items()}), idx)
    t = t.value_transform("x", "scale", factor=1e-2)
    t = t.filter_rows(rng.random(t.table.n_rows) > 0.03)
    t = t.join(dims, on="k", how="inner")
    t = t.value_transform("w", "scale", factor=2.0)
    t = t.oversample(frac=0.02, seed=5, noise=0.1)
    for i in range(5):
        t = t.value_transform("y", "scale", factor=1.0 + 0.1 * i)
    t = t.filter_rows(rng.random(t.table.n_rows) > 0.02)
    t = t.value_transform("x", "clip", lo=-1e6, hi=1e6)
    t.mark_sink()
    return idx, t.dataset_id


def run_whatif(quick: bool = False):
    n = 20_000 if quick else 100_000
    n_perturb = 4
    rng = np.random.default_rng(3)
    src_cols = {
        "k": np.arange(n, dtype=np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    }
    dims_cols = {
        "k": np.arange(n, dtype=np.float32),
        "w": rng.normal(size=n).astype(np.float32),
    }
    idx, sink = _build_whatif(src_cols, dims_cols, n, "wf")
    rows = np.unique(rng.integers(0, n, size=n_perturb)).tolist()
    patch = {"x": [100.0 + i for i in range(len(rows))]}

    res = whatif_replay(idx, "src", rows, patch, sink)

    def full_rerun():
        cols = {c: v.copy() for c, v in src_cols.items()}
        cols["x"][rows] = np.asarray(patch["x"], dtype=np.float32)
        ridx, rsink = _build_whatif(cols, dims_cols, n, "wf-rerun")
        return fetch_rows(ridx, rsink, res.sink_rows)

    # -- parity: surgical replay == patched full re-run ---------------------
    truth = full_rerun()
    ok = ~truth.null
    np.testing.assert_array_equal(res.after.null, truth.null)
    np.testing.assert_allclose(res.after.data[ok], truth.data[ok],
                               rtol=1e-5, atol=1e-6)
    print(f"parity: what-if replay == full re-run on {len(res.sink_rows)} "
          "affected sink rows (exact)")

    replay_ms = _median_ms(
        lambda: whatif_replay(idx, "src", rows, patch, sink))
    rerun_ms = _median_ms(full_rerun, reps=3)
    ratio = rerun_ms / replay_ms
    n_sink = idx.datasets[sink].n_rows
    print(f"\n== what-if: n={n}, {len(rows)} perturbed rows -> "
          f"{len(res.sink_rows)}/{n_sink} sink rows ==")
    print(f"whatif_replay (affected rows only) p50 {replay_ms:8.2f} ms")
    print(f"full pipeline re-run               p50 {rerun_ms:8.2f} ms")
    print(f"speedup: {ratio:.1f}x (acceptance >= 5x at n=100k)")
    if not quick:     # the quick config is too small for the fixed bar
        assert ratio >= 5.0, \
            f"what-if replay only {ratio:.1f}x over full re-run"
    return {"n": n, "n_perturbed": len(rows),
            "n_sink_rows_recomputed": int(len(res.sink_rows)),
            "replay_ms_p50": replay_ms, "rerun_ms_p50": rerun_ms,
            "speedup": float(ratio)}


# ===========================================================================
# (c) federated cells vs merged single index
# ===========================================================================
def _cells_pipelines(n, seed=0):
    """One frozen op list applied to a merged index AND to a prep/serve
    catalog cut at the midpoint (identity boundary link)."""
    rng = np.random.default_rng(seed)
    cols = {"a": rng.normal(size=n).astype(np.float32),
            "b": rng.normal(size=n).astype(np.float32),
            "c": rng.normal(size=n).astype(np.float32)}
    mask = rng.random(n) > 0.1

    def _front(idx):
        t = track(Table.from_columns({c: v.copy() for c, v in cols.items()}),
                  idx, "src")
        t = t.value_transform("a", "scale", factor=2.0)
        t = t.filter_rows(mask)
        return t

    def _back(t):
        t = t.normalize(["b"], kind="zscore")
        t = t.oversample(frac=0.1, seed=2, noise=0.0)
        t.mark_sink()
        return t.dataset_id

    merged = ProvenanceIndex("merged")
    m_sink = _back(_front(merged))

    prep = ProvenanceIndex("prep")
    cut = _front(prep)
    cut.mark_sink()
    serve = ProvenanceIndex("serve")
    s_sink = _back(track(cut.table, serve, "ingest"))
    catalog = ProvCatalog("bench")
    catalog.register("prep", prep).register("serve", serve)
    catalog.link(f"prep/{cut.dataset_id}", "serve/ingest")
    return merged, m_sink, catalog, f"serve/{s_sink}"


def run_federated_cells(quick: bool = False):
    n = 1_000 if quick else 8_000
    merged, m_sink, catalog, f_sink = _cells_pipelines(n)
    rng = np.random.default_rng(9)
    rows = sorted(rng.integers(0, n, size=6).tolist())
    attrs = [0, 1]

    def _merged():
        return (prov(merged).source("src").rows(rows).attrs(attrs)
                .forward().to(m_sink).how().run())

    def _federated():
        return (prov(catalog).source("prep/src").rows(rows).attrs(attrs)
                .forward().to(f_sink).how().run())

    t0 = time.perf_counter()
    want, want_hops = _merged()
    merged_cold = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    got, got_hops = _federated()
    fed_cold = (time.perf_counter() - t0) * 1e3
    np.testing.assert_array_equal(np.asarray(want.todense()) if hasattr(
        want, "todense") else np.asarray(want),
        np.asarray(got.todense()) if hasattr(got, "todense")
        else np.asarray(got))
    # merged trace == federated trace minus the synthetic link crossings
    assert len([h for h in got_hops if h.category != "link"]) == len(want_hops)
    print("parity: federated cells+how == merged (link hops excluded)")

    merged_ms = _median_ms(_merged)
    fed_ms = _median_ms(_federated)
    print(f"\n== federated cells: n={n}, {len(rows)} rows x {len(attrs)} attrs ==")
    print(f"merged single index  cold {merged_cold:7.2f} ms  warm p50 {merged_ms:7.2f} ms")
    print(f"federated (stitched) cold {fed_cold:7.2f} ms  warm p50 {fed_ms:7.2f} ms")
    print(f"federated/merged warm ratio: {fed_ms / merged_ms:.2f}x")
    return {"n": n, "merged_cold_ms": merged_cold, "federated_cold_ms": fed_cold,
            "merged_ms_p50": merged_ms, "federated_ms_p50": fed_ms,
            "ratio_warm": float(fed_ms / merged_ms)}


def run(quick: bool = False):
    return {"closure": run_closure(quick=quick),
            "whatif": run_whatif(quick=quick),
            "federated_cells": run_federated_cells(quick=quick)}


def _merge_trajectory(section: dict) -> None:
    """``BENCH_query.json`` belongs to bench_query.py; this bench only
    extends it with the ``impact`` section (creating the file when the
    query bench has not run yet)."""
    path = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir,
        "BENCH_query.json"))
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["impact"] = section
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"wrote {path} (impact section)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced configuration (CI smoke) — still merges "
                    "the impact section into BENCH_query.json")
    args = ap.parse_args()
    out = run(quick=args.quick)
    _merge_trajectory(out)
