"""Fig 4 + Fig 5: query latency Q1-Q11 on the Census pipeline.

Fig 4: all queries against MATERIALIZED endpoints (the default policy keeps
source + sink).  Fig 5: the same queries when the answer must RETURN values
from a NON-materialized intermediate -> per-record recomputation (§III-E).

Census is extended with a join (as the paper does) so Q10/Q11 are defined.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import query as Q
from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import recompute_rows
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.dataprep.usecases import make_census


def build_census_with_join(seed=0):
    idx = ProvenanceIndex("census+join")
    t = make_census(seed)
    d = track(t, idx, "census_src")
    # reference table joined on the a0 category (the paper modified Census
    # to include a join for Q10/Q11)
    ref = Table.from_columns({
        "a0": np.arange(9, dtype=np.float32),
        "region": np.arange(9, dtype=np.float32) % 4,
    })
    r = track(ref, idx, "region_ref")
    d = d.impute([f"a{j}" for j in range(9, 15)], strategy="mean")
    d = d.normalize([f"a{j}" for j in range(9, 15)], kind="zscore")
    d = d.join(r, on="a0", how="inner")
    d = d.onehot("a1", n_values=16)
    d = d.onehot("a2", n_values=64)
    d.mark_sink()
    return idx, d


def _time_ms(fn, reps=3):
    vals = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        vals.append((time.perf_counter() - t0) * 1e3)
    return float(np.mean(vals))


def run(quick: bool = False):
    idx, sink = build_census_with_join()
    src, ref, out = "census_src", "region_ref", sink.dataset_id
    mid = idx.ops[2].output_id      # join output (non-materialized)
    rows = [5]
    attrs = [3]

    queries = {
        "Q1": lambda: Q.q1_forward(idx, src, rows, out),
        "Q2": lambda: Q.q2_backward(idx, out, rows, src),
        "Q3": lambda: Q.q3_forward_attr(idx, src, rows, attrs, out),
        "Q4": lambda: Q.q4_backward_attr(idx, out, rows, attrs, src),
        "Q5": lambda: Q.q5_forward_how(idx, src, rows, out),
        "Q6": lambda: Q.q6_backward_how(idx, out, rows, src),
        "Q7": lambda: Q.q7_forward_attr_how(idx, src, rows, attrs, out),
        "Q8": lambda: Q.q8_backward_attr_how(idx, out, rows, attrs, src),
        "Q9": lambda: Q.q9_all_transformations(idx, out),
        "Q10": lambda: Q.q10_co_contributory(idx, src, rows, ref),
        "Q11": lambda: Q.q11_co_dependency(idx, mid, rows, src, out),
    }
    reps = 1 if quick else 3
    fig4 = {name: _time_ms(fn, reps) for name, fn in queries.items()}

    # Fig 5: same lineage + VALUES from the non-materialized join output
    def recomputing(name, fn):
        def wrapped():
            res = fn()
            lineage = res[0] if isinstance(res, tuple) else res
            arr = np.asarray(lineage).reshape(-1)
            take = [int(x) for x in arr[:4] if np.issubdtype(arr.dtype, np.integer)]
            recompute_rows(idx, mid, take or [0])
        return wrapped

    fig5 = {}
    for name, fn in queries.items():
        if name == "Q9":
            fig5[name] = fig4[name]     # metadata-only: unaffected (paper)
            continue
        mid_q = {
            "Q1": lambda: Q.q1_forward(idx, src, rows, mid),
            "Q2": lambda: Q.q2_backward(idx, mid, rows, src),
            "Q3": lambda: Q.q3_forward_attr(idx, src, rows, attrs, mid),
            "Q4": lambda: Q.q4_backward_attr(idx, mid, rows, attrs, src),
            "Q5": lambda: Q.q5_forward_how(idx, src, rows, mid),
            "Q6": lambda: Q.q6_backward_how(idx, mid, rows, src),
            "Q7": lambda: Q.q7_forward_attr_how(idx, src, rows, attrs, mid),
            "Q8": lambda: Q.q8_backward_attr_how(idx, mid, rows, attrs, src),
            "Q10": lambda: Q.q10_co_contributory(idx, src, rows, ref),
            "Q11": lambda: Q.q11_co_dependency(idx, mid, rows, src, out),
        }[name]
        fig5[name] = _time_ms(recomputing(name, mid_q), reps)

    print("\n== Fig 4: query latency, materialized (ms) ==")
    print("  " + "  ".join(f"{k}={v:.2f}" for k, v in fig4.items()))
    print("== Fig 5: query latency with recomputation (ms) ==")
    print("  " + "  ".join(f"{k}={v:.2f}" for k, v in fig5.items()))
    return {"table": "Fig4/5", "fig4_ms": fig4, "fig5_ms": fig5}


if __name__ == "__main__":
    run()
