"""Fig 4 + Fig 5: query latency Q1-Q11 on the Census pipeline, plus the
batched multi-hop comparison (per-hop walk vs batch walk vs composed
hop-cache) on a deep chain, plus the FUSED-BATCH scenario (N mixed
Q1/Q2/Q4 plans submitted to one ``QuerySession.run_many`` vs the legacy
per-query loop).

Fig 4: all queries against MATERIALIZED endpoints (the default policy keeps
source + sink).  Fig 5: the same queries when the answer must RETURN values
from a NON-materialized intermediate -> per-record recomputation (§III-E).

Census is extended with a join (as the paper does) so Q10/Q11 are defined.

Run as a script this also writes ``BENCH_query.json`` at the repo root —
the perf-trajectory artifact.
"""
from __future__ import annotations

import itertools
import json
import os
import time
import warnings

import numpy as np

from repro.core import query as Q
from repro.core.hopcache import ComposedIndex
from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import recompute_rows
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.dataprep.usecases import make_census
from repro.provenance import QuerySession, prov


def build_census_with_join(seed=0):
    idx = ProvenanceIndex("census+join")
    t = make_census(seed)
    d = track(t, idx, "census_src")
    # reference table joined on the a0 category (the paper modified Census
    # to include a join for Q10/Q11)
    ref = Table.from_columns({
        "a0": np.arange(9, dtype=np.float32),
        "region": np.arange(9, dtype=np.float32) % 4,
    })
    r = track(ref, idx, "region_ref")
    d = d.impute([f"a{j}" for j in range(9, 15)], strategy="mean")
    d = d.normalize([f"a{j}" for j in range(9, 15)], kind="zscore")
    d = d.join(r, on="a0", how="inner")
    d = d.onehot("a1", n_values=16)
    d = d.onehot("a2", n_values=64)
    d.mark_sink()
    return idx, d


def _time_ms(fn, reps=3):
    vals = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        vals.append((time.perf_counter() - t0) * 1e3)
    return float(np.mean(vals))


def _time_ms_r(fn, reps=3):
    """Like :func:`_time_ms` but also returns the last run's result, so
    sanity checks reuse the answers the timed reps already computed instead
    of re-running every contender untimed afterwards."""
    vals, res = [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fn()
        vals.append((time.perf_counter() - t0) * 1e3)
    return float(np.mean(vals)), res


def run(quick: bool = False):
    idx, sink = build_census_with_join()
    src, ref, out = "census_src", "region_ref", sink.dataset_id
    mid = idx.ops[2].output_id      # join output (non-materialized)
    rows = [5]
    attrs = [3]

    queries = {
        "Q1": lambda: Q.q1_forward(idx, src, rows, out),
        "Q2": lambda: Q.q2_backward(idx, out, rows, src),
        "Q3": lambda: Q.q3_forward_attr(idx, src, rows, attrs, out),
        "Q4": lambda: Q.q4_backward_attr(idx, out, rows, attrs, src),
        "Q5": lambda: Q.q5_forward_how(idx, src, rows, out),
        "Q6": lambda: Q.q6_backward_how(idx, out, rows, src),
        "Q7": lambda: Q.q7_forward_attr_how(idx, src, rows, attrs, out),
        "Q8": lambda: Q.q8_backward_attr_how(idx, out, rows, attrs, src),
        "Q9": lambda: Q.q9_all_transformations(idx, out),
        "Q10": lambda: Q.q10_co_contributory(idx, src, rows, ref),
        "Q11": lambda: Q.q11_co_dependency(idx, mid, rows, src, out),
    }
    reps = 1 if quick else 3
    fig4 = {name: _time_ms(fn, reps) for name, fn in queries.items()}

    # Fig 5: same lineage + VALUES from the non-materialized join output
    def recomputing(name, fn):
        def wrapped():
            res = fn()
            lineage = res[0] if isinstance(res, tuple) else res
            arr = np.asarray(lineage).reshape(-1)
            take = [int(x) for x in arr[:4] if np.issubdtype(arr.dtype, np.integer)]
            recompute_rows(idx, mid, take or [0])
        return wrapped

    fig5 = {}
    for name, fn in queries.items():
        if name == "Q9":
            fig5[name] = fig4[name]     # metadata-only: unaffected (paper)
            continue
        mid_q = {
            "Q1": lambda: Q.q1_forward(idx, src, rows, mid),
            "Q2": lambda: Q.q2_backward(idx, mid, rows, src),
            "Q3": lambda: Q.q3_forward_attr(idx, src, rows, attrs, mid),
            "Q4": lambda: Q.q4_backward_attr(idx, mid, rows, attrs, src),
            "Q5": lambda: Q.q5_forward_how(idx, src, rows, mid),
            "Q6": lambda: Q.q6_backward_how(idx, mid, rows, src),
            "Q7": lambda: Q.q7_forward_attr_how(idx, src, rows, attrs, mid),
            "Q8": lambda: Q.q8_backward_attr_how(idx, mid, rows, attrs, src),
            "Q10": lambda: Q.q10_co_contributory(idx, src, rows, ref),
            "Q11": lambda: Q.q11_co_dependency(idx, mid, rows, src, out),
        }[name]
        fig5[name] = _time_ms(recomputing(name, mid_q), reps)

    print("\n== Fig 4: query latency, materialized (ms) ==")
    print("  " + "  ".join(f"{k}={v:.2f}" for k, v in fig4.items()))
    print("== Fig 5: query latency with recomputation (ms) ==")
    print("  " + "  ".join(f"{k}={v:.2f}" for k, v in fig5.items()))
    batch = run_batch_vs_walk(quick=quick)
    fused = run_fused_batch(quick=quick)
    costmodel = run_costmodel(quick=quick)
    federation = run_federation(quick=quick)
    structured = run_structured(quick=quick)
    sharded = run_sharded(quick=quick)
    # capture/memory trajectory (Fig 3 / Table IX) rides the same artifact,
    # so the CI smoke step records the representation-layer numbers too
    try:
        from benchmarks import bench_capture, bench_memory
    except ImportError:                     # run as a script: sibling import
        import bench_capture, bench_memory
    capture_res = bench_capture.run(quick=quick)
    memory_res = bench_memory.run(quick=quick)
    return {"table": "Fig4/5", "fig4_ms": fig4, "fig5_ms": fig5, "batch": batch,
            "fused_batch": fused, "costmodel": costmodel,
            "federation": federation, "structured": structured,
            "sharded": sharded, "capture": capture_res, "memory": memory_res}


# ---------------------------------------------------------------------------
# Batched multi-hop Q1/Q2: per-hop walk vs batch walk vs composed hop-cache
# ---------------------------------------------------------------------------
def _chain_step(d, i):
    """Op ``i`` of the deterministic deep chain (replayable: the same step
    sequence builds the merged AND the federated variants identically)."""
    kind = i % 4
    if kind == 0:
        return d.value_transform("x", "scale", factor=1.01)
    if kind == 1:
        mask = np.ones(d.table.n_rows, dtype=bool)
        mask[i :: 17] = False                         # drop a sliver per hop
        return d.filter_rows(mask)
    if kind == 2:
        return d.normalize(["x"], kind="zscore")
    return d.oversample(frac=0.05, seed=i)


def _chain_table(seed, n):
    rng = np.random.default_rng(seed)
    return Table.from_columns({
        "k": rng.integers(0, n // 2, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "g": rng.integers(0, 4, n).astype(np.float32),
    })


def build_deep_chain(seed=0, n=4000, n_ops=12):
    """A >=10-op chain so multi-hop composition has distance to amortize."""
    idx = ProvenanceIndex("deep-chain")
    d = track(_chain_table(seed, n), idx, "chain_src")
    for i in range(n_ops):
        d = _chain_step(d, i)
    d.mark_sink()
    return idx, d.dataset_id


def run_batch_vs_walk(quick: bool = False, n_probes: int = 64):
    idx, sink = build_deep_chain(n=1000 if quick else 4000,
                                 n_ops=10 if quick else 14)
    src = "chain_src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    rng = np.random.default_rng(7)
    probes_f = [sorted(rng.choice(n_src, size=4, replace=False).tolist())
                for _ in range(8 if quick else n_probes)]
    probes_b = [sorted(rng.choice(n_sink, size=4, replace=False).tolist())
                for _ in range(8 if quick else n_probes)]
    reps = 1 if quick else 3

    # strategy-PINNED session so each contender measures its own engine (the
    # adaptive planner would otherwise route batches through the hop-cache)
    walk_sess = QuerySession(idx, ComposedIndex(idx), use_hopcache=False)

    def q1_walk(p, batched=False):
        qb = prov(idx).source(src)
        qb = qb.rows_batch(p) if batched else qb.rows(p)
        return walk_sess.run(qb.forward().to(sink).plan())

    def q2_walk(p, batched=False):
        qb = prov(idx).source(sink)
        qb = qb.rows_batch(p) if batched else qb.rows(p)
        return walk_sess.run(qb.backward().to(src).plan())

    # warm the CSR halves so every contender measures probe cost, not build
    q1_walk(probes_f[0])
    q2_walk(probes_b[0])

    walk_f, walk_res = _time_ms_r(lambda: [q1_walk(p) for p in probes_f], reps)
    batch_f, batch_res = _time_ms_r(lambda: q1_walk(probes_f, batched=True),
                                    reps)
    ci = ComposedIndex(idx, memory_budget_bytes=256 << 20)
    t0 = time.perf_counter()
    ci.q1_forward(src, probes_f[:1], sink)            # composes the relation
    compose_ms = (time.perf_counter() - t0) * 1e3
    cache_f, cache_res = _time_ms_r(lambda: ci.q1_forward(src, probes_f, sink),
                                    reps)

    walk_b = _time_ms(lambda: [q2_walk(p) for p in probes_b], reps)
    batch_b = _time_ms(lambda: q2_walk(probes_b, batched=True), reps)
    cache_b = _time_ms(lambda: ci.q2_backward(sink, probes_b, src), reps)

    # sanity: all three contenders answer identically (reusing the answers
    # the timed reps produced — no untimed re-run of every contender)
    for a, b, c in zip(walk_res, batch_res, cache_res):
        assert (a == b).all() and (a == c).all()

    out = {
        "n_ops": len(idx.ops), "n_probes": len(probes_f),
        "q1_perhop_walk_ms": walk_f, "q1_batch_walk_ms": batch_f,
        "q1_hopcache_ms": cache_f, "q1_compose_cold_ms": compose_ms,
        "q2_perhop_walk_ms": walk_b, "q2_batch_walk_ms": batch_b,
        "q2_hopcache_ms": cache_b,
        "q1_speedup_batch": walk_f / max(batch_f, 1e-9),
        "q1_speedup_hopcache": walk_f / max(cache_f, 1e-9),
        "q2_speedup_batch": walk_b / max(batch_b, 1e-9),
        "q2_speedup_hopcache": walk_b / max(cache_b, 1e-9),
        "hopcache_stats": ci.stats(),
    }
    print(f"\n== batched multi-hop Q1/Q2 ({len(idx.ops)}-op chain, "
          f"{len(probes_f)} probe sets) ==")
    print(f"  Q1  per-hop walk {walk_f:8.2f} ms | batch walk {batch_f:8.2f} ms "
          f"({out['q1_speedup_batch']:.1f}x) | hop-cache {cache_f:8.2f} ms "
          f"({out['q1_speedup_hopcache']:.1f}x; cold compose {compose_ms:.2f} ms)")
    print(f"  Q2  per-hop walk {walk_b:8.2f} ms | batch walk {batch_b:8.2f} ms "
          f"({out['q2_speedup_batch']:.1f}x) | hop-cache {cache_b:8.2f} ms "
          f"({out['q2_speedup_hopcache']:.1f}x)")
    return out


# ---------------------------------------------------------------------------
# Fused batch: N mixed Q1/Q2/Q4 plans, session.run_many vs legacy loop
# ---------------------------------------------------------------------------
def run_fused_batch(quick: bool = False, n_plans: int = 60):
    """The query-plan API's headline scenario: a mixed workload of Q1, Q2
    and Q4 plans over the same deep chain.  The legacy loop answers them one
    free-function call at a time; ``run_many`` fuses the plans sharing a
    (kind, src, dst) key into one packed pass each — Q1s become one
    composed-relation probe, Q2s another, Q4s one batched bitplane walk."""
    idx, sink = build_deep_chain(n=1000 if quick else 4000,
                                 n_ops=10 if quick else 14)
    src = "chain_src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    c_sink = idx.datasets[sink].n_cols
    rng = np.random.default_rng(11)
    n_plans = 12 if quick else n_plans
    reps = 1 if quick else 3

    specs = []
    for i in range(n_plans):
        kind = i % 3
        if kind == 0:       # Q1 forward record
            p = sorted(rng.choice(n_src, size=4, replace=False).tolist())
            specs.append(("q1", p, None))
        elif kind == 1:     # Q2 backward record
            p = sorted(rng.choice(n_sink, size=4, replace=False).tolist())
            specs.append(("q2", p, None))
        else:               # Q4 backward attr (cells)
            p = sorted(rng.choice(n_sink, size=2, replace=False).tolist())
            a = sorted(rng.choice(c_sink, size=2, replace=False).tolist())
            specs.append(("q4", p, a))

    def make_plans():
        plans = []
        for kind, p, a in specs:
            if kind == "q1":
                plans.append(prov(idx).source(src).rows(p).forward().to(sink).plan())
            elif kind == "q2":
                plans.append(prov(idx).source(sink).rows(p).backward().to(src).plan())
            else:
                plans.append(prov(idx).source(sink).rows(p).attrs(a)
                             .backward().to(src).plan())
        return plans

    def legacy_loop():
        out = []
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            for kind, p, a in specs:
                if kind == "q1":
                    out.append(Q.q1_forward(idx, src, p, sink))
                elif kind == "q2":
                    out.append(Q.q2_backward(idx, sink, p, src))
                else:
                    out.append(Q.q4_backward_attr(idx, sink, p, a, src))
        return out

    # warm the CSR halves + tensors so both contenders measure query cost
    legacy_loop()
    legacy_ms = _time_ms(legacy_loop, reps)

    session = QuerySession(idx, ComposedIndex(idx, memory_budget_bytes=256 << 20))
    plans = make_plans()
    t0 = time.perf_counter()
    fused_first = session.run_many(plans)     # includes cold relation compose
    fused_cold_ms = (time.perf_counter() - t0) * 1e3
    fused_ms = _time_ms(lambda: session.run_many(make_plans()), reps)

    # sanity: fused results == the legacy loop's, element for element
    for a, b in zip(legacy_loop(), fused_first):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    out = {
        "n_plans": n_plans, "n_ops": len(idx.ops),
        "legacy_loop_ms": legacy_ms,
        "session_run_many_ms": fused_ms,
        "session_run_many_cold_ms": fused_cold_ms,
        "speedup_fused": legacy_ms / max(fused_ms, 1e-9),
        "speedup_fused_cold": legacy_ms / max(fused_cold_ms, 1e-9),
        "session_stats": session.stats(),
    }
    print(f"\n== fused batch: {n_plans} mixed Q1/Q2/Q4 plans "
          f"({len(idx.ops)}-op chain) ==")
    print(f"  legacy per-query loop {legacy_ms:8.2f} ms | session.run_many "
          f"{fused_ms:8.2f} ms ({out['speedup_fused']:.1f}x; cold "
          f"{fused_cold_ms:.2f} ms, {out['speedup_fused_cold']:.1f}x)")
    return out


# ---------------------------------------------------------------------------
# Cost-model routing: auto vs forced strategies vs the legacy min-batch
# heuristic, plus the vectorized bitplane backward-probe microbench
# ---------------------------------------------------------------------------
def _strategy_sessions(idx):
    """One session per routing policy, each over its OWN hop-cache."""
    auto = QuerySession(idx, ComposedIndex(idx, memory_budget_bytes=256 << 20))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        heuristic = QuerySession(
            idx, ComposedIndex(idx, memory_budget_bytes=256 << 20),
            hopcache_min_batch=8)
        forced_hc = QuerySession(
            idx, ComposedIndex(idx, memory_budget_bytes=256 << 20),
            hopcache_min_batch=1)
    forced_walk = QuerySession(idx, ComposedIndex(idx), use_hopcache=False)
    return {"auto": auto, "heuristic_minbatch8": heuristic,
            "forced_hopcache": forced_hc, "forced_walk": forced_walk}


def run_costmodel(quick: bool = False):
    """Three workloads × four routing policies, measured at steady state
    (one warm-up pass lets the cost model's demand amortization settle and
    lets every policy compose whatever it chooses to compose):

    * ``small_batch_stream`` — N single-probe Q1s to one far pair.  The
      ``hopcache_min_batch`` heuristic walks EVERY one (B=1 < 8, and the
      relation is never composed, so the cached-pair check never fires) —
      the mis-routing the cost model fixes by amortizing demand.
    * ``large_batch`` — one B=64 batched Q1 + one B=64 batched Q2.
    * ``mixed`` — interleaved singles and batches, fwd and bwd.
    """
    idx, sink = build_deep_chain(n=1000 if quick else 4000,
                                 n_ops=10 if quick else 14)
    src = "chain_src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    rng = np.random.default_rng(23)
    n_stream = 16 if quick else 60
    reps = 1 if quick else 3

    singles_f = [sorted(rng.choice(n_src, size=4, replace=False).tolist())
                 for _ in range(n_stream)]
    singles_b = [sorted(rng.choice(n_sink, size=4, replace=False).tolist())
                 for _ in range(n_stream)]
    batch_f = singles_f[: (8 if quick else 64)]
    batch_b = singles_b[: (8 if quick else 64)]

    def wl_small(sess):
        return [sess.run(prov(idx).source(src).rows(p).forward().to(sink).plan())
                for p in singles_f]

    def wl_large(sess):
        a = sess.run(prov(idx).source(src).rows_batch(batch_f)
                     .forward().to(sink).plan())
        b = sess.run(prov(idx).source(sink).rows_batch(batch_b)
                     .backward().to(src).plan())
        return a + b

    def wl_mixed(sess):
        out = []
        for i in range(0, n_stream, 4):
            out.append(sess.run(prov(idx).source(src).rows(singles_f[i])
                                .forward().to(sink).plan()))
            out.append(sess.run(prov(idx).source(sink).rows(singles_b[i])
                                .backward().to(src).plan()))
        out.append(sess.run(prov(idx).source(src).rows_batch(batch_f)
                            .forward().to(sink).plan()))
        return out

    def _assert_same(a, b):
        if isinstance(a, list) and not isinstance(a, np.ndarray):
            assert len(a) == len(b)
            for x, y in zip(a, b):
                _assert_same(x, y)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    workloads = {"small_batch_stream": wl_small, "large_batch": wl_large,
                 "mixed": wl_mixed}
    out = {"n_ops": len(idx.ops), "n_stream": n_stream, "workloads": {}}
    print("\n== cost-model routing (steady state, ms) ==")
    for wname, wl in workloads.items():
        sessions = _strategy_sessions(idx)
        answers = {}
        for sname, sess in sessions.items():
            # warm-up twice: the first pass accumulates demand and pays any
            # cold compose the policy chooses; the second confirms routing
            # has settled, so the timed reps measure steady state
            answers[sname] = wl(sess)
            wl(sess)
        # sanity: every policy answers identically
        base = answers["forced_walk"]
        for sname, ans in answers.items():
            _assert_same(base, ans)
        # PAIRED rounds: every policy runs once per round, and the headline
        # ratios are medians of PER-ROUND ratios — machine-load drift on
        # this shared host swings absolute times by tens of percent across
        # seconds, but within one ~10ms round it cancels.  Round order
        # cycles through ALL permutations (cyclic rotation alone preserves
        # adjacency, so one policy would always inherit the allocator state
        # the 20ms forced-walk workload leaves behind).
        raw = {sname: [] for sname in sessions}
        perms = list(itertools.permutations(sessions))
        # stride coprime to len(perms): any PREFIX of rounds (quick mode runs
        # only 8 of the 24 permutations) already spreads leading positions,
        # where lexicographic order would hand one policy most first slots
        stride = 7
        for r in range(reps * 8):
            for sname in perms[(r * stride) % len(perms)]:
                t0 = time.perf_counter()
                wl(sessions[sname])
                raw[sname].append((time.perf_counter() - t0) * 1e3)
        times = {sname: float(np.median(v)) for sname, v in raw.items()}
        best_forced_r = np.minimum(np.array(raw["forced_walk"]),
                                   np.array(raw["forced_hopcache"]))
        ratio_best = float(np.median(np.array(raw["auto"]) / best_forced_r))
        ratio_heur = float(np.median(
            np.array(raw["heuristic_minbatch8"]) / np.array(raw["auto"])))
        entry = {
            **{f"{s}_ms": t for s, t in times.items()},
            "auto_vs_best_forced": ratio_best,
            "speedup_vs_heuristic": ratio_heur,
            "auto_planner": sessions["auto"].counters,
        }
        out["workloads"][wname] = entry
        print(f"  {wname:20s} auto {times['auto']:8.2f} | heuristic "
              f"{times['heuristic_minbatch8']:8.2f} | walk "
              f"{times['forced_walk']:8.2f} | hopcache "
              f"{times['forced_hopcache']:8.2f}  "
              f"(auto/best {entry['auto_vs_best_forced']:.2f}x, "
              f"vs heuristic {entry['speedup_vs_heuristic']:.1f}x)")

    out["backward_probe"] = run_backward_probe_microbench(idx, src, sink,
                                                         quick=quick)
    return out


# ---------------------------------------------------------------------------
# Structured representations: composed-chain build + probes + entry memory,
# structured capture (implicit tensors, closed-form compose) vs forced COO
# ---------------------------------------------------------------------------
def run_structured(quick: bool = False, n_probes: int = 64):
    """The representation-layer headline: the SAME identity/selection-heavy
    deep chain captured structured (implicit tensors -> closed-form gather
    composition in the hop-cache) vs forced explicit COO (CSR mirrors ->
    spmm composition).  Reports cold composed-chain build time, batched
    probe latency at steady state, and the cached relation's byte footprint.
    """
    from repro.core.capture import force_coo_capture

    n = 8000 if quick else 100_000
    n_ops = 10 if quick else 14
    B = 8 if quick else n_probes
    reps = 1 if quick else 3

    idx_s, sink_s = build_deep_chain(n=n, n_ops=n_ops)
    with force_coo_capture():
        idx_c, sink_c = build_deep_chain(n=n, n_ops=n_ops)
    src = "chain_src"
    n_src = idx_s.datasets[src].n_rows
    n_sink = idx_s.datasets[sink_s].n_rows
    rng = np.random.default_rng(17)
    probes_f = [sorted(rng.choice(n_src, size=4, replace=False).tolist())
                for _ in range(B)]
    probes_b = [sorted(rng.choice(n_sink, size=4, replace=False).tolist())
                for _ in range(B)]

    def cold_build(idx, sink):
        ci = ComposedIndex(idx, memory_budget_bytes=512 << 20)
        t0 = time.perf_counter()
        ci.relation(src, sink)
        return ci, (time.perf_counter() - t0) * 1e3

    # CSR mirrors for the COO world are part of the honest cold cost, so
    # time the FIRST build.  The warm re-build (fresh cache, tensors warm)
    # is a full extra compose per world — skipped under --quick, where it
    # used to redundantly re-run work the cold pass just measured.
    ci_s, build_s_cold = cold_build(idx_s, sink_s)
    ci_c, build_c_cold = cold_build(idx_c, sink_c)
    build_s_warm = build_c_warm = None
    if not quick:
        _, build_s_warm = cold_build(idx_s, sink_s)
        _, build_c_warm = cold_build(idx_c, sink_c)

    probe_f_s, res_f_s = _time_ms_r(
        lambda: ci_s.q1_forward(src, probes_f, sink_s), reps)
    probe_f_c, res_f_c = _time_ms_r(
        lambda: ci_c.q1_forward(src, probes_f, sink_c), reps)
    probe_b_s, res_b_s = _time_ms_r(
        lambda: ci_s.q2_backward(sink_s, probes_b, src), reps)
    probe_b_c, res_b_c = _time_ms_r(
        lambda: ci_c.q2_backward(sink_c, probes_b, src), reps)

    # parity: structured answers == forced-COO answers, element for element
    # (the answers the timed reps computed — no extra probe pass)
    for a, b in zip(res_f_s, res_f_c):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(res_b_s, res_b_c):
        np.testing.assert_array_equal(a, b)

    entry_s = ci_s._relation_entry(src, sink_s)
    entry_c = ci_c._relation_entry(src, sink_c)
    tensors_s = sum(op.tensor.nbytes() for op in idx_s.ops)
    tensors_c = sum(op.tensor.nbytes() for op in idx_c.ops)
    out = {
        "n": n, "n_ops": n_ops, "n_probes": B,
        "build_structured_cold_ms": build_s_cold,
        "build_coo_cold_ms": build_c_cold,
        "build_structured_warm_ms": build_s_warm,
        "build_coo_warm_ms": build_c_warm,
        "speedup_build_cold": build_c_cold / max(build_s_cold, 1e-9),
        "speedup_build_warm": (build_c_warm / max(build_s_warm, 1e-9)
                              if build_s_warm is not None else None),
        "q1_probe_structured_ms": probe_f_s,
        "q1_probe_coo_ms": probe_f_c,
        "q2_probe_structured_ms": probe_b_s,
        "q2_probe_coo_ms": probe_b_c,
        "entry_backend_structured": entry_s.backend,
        "entry_backend_coo": entry_c.backend,
        "entry_bytes_structured": entry_s.nbytes(),
        "entry_bytes_coo": entry_c.nbytes(),
        "entry_bytes_ratio": entry_c.nbytes() / max(entry_s.nbytes(), 1),
        "tensor_bytes_structured": tensors_s,
        "tensor_bytes_coo": tensors_c,
        "tensor_bytes_ratio": tensors_c / max(tensors_s, 1),
        "hopcache_stats": ci_s.stats(),
    }
    print(f"\n== structured representations ({n_ops}-op chain, n={n}) ==")
    warm_note = (f", {out['speedup_build_warm']:.1f}x warm"
                 if out["speedup_build_warm"] is not None else "")
    print(f"  composed-chain build  structured {build_s_cold:8.2f} ms | "
          f"COO+spmm {build_c_cold:8.2f} ms "
          f"({out['speedup_build_cold']:.1f}x cold{warm_note})")
    print(f"  batched probes (B={B})  Q1 {probe_f_s:.2f} vs {probe_f_c:.2f} ms | "
          f"Q2 {probe_b_s:.2f} vs {probe_b_c:.2f} ms")
    print(f"  relation entry  {entry_s.backend} {entry_s.nbytes()/1e3:.1f} KB vs "
          f"{entry_c.backend} {entry_c.nbytes()/1e3:.1f} KB "
          f"({out['entry_bytes_ratio']:.1f}x); op tensors "
          f"{tensors_s/1e3:.1f} KB vs {tensors_c/1e3:.1f} KB "
          f"({out['tensor_bytes_ratio']:.1f}x)")
    return out


# ---------------------------------------------------------------------------
# Federation: batched cross-index trace-to-source vs the merged baseline
# ---------------------------------------------------------------------------
def build_split_chain(seed=0, n=4000, n_ops=12):
    """The SAME deep chain split at the midpoint into a prep index and a
    serve index glued by an identity catalog link — the federated twin of
    :func:`build_deep_chain`."""
    from repro.provenance import ProvCatalog

    cut = n_ops // 2
    prep = ProvenanceIndex("prep")
    d = track(_chain_table(seed, n), prep, "chain_src")
    for i in range(cut):
        d = _chain_step(d, i)
    boundary = d.dataset_id
    serve = ProvenanceIndex("serve")
    s = track(d.table, serve, "ingest")
    for i in range(cut, n_ops):
        s = _chain_step(s, i)
    s.mark_sink()
    catalog = ProvCatalog("bench-fed")
    catalog.register("prep", prep).register("serve", serve)
    catalog.link(f"prep/{boundary}", "serve/ingest")
    return catalog, f"serve/{s.dataset_id}", "prep/chain_src"


def run_federation(quick: bool = False, n_probes: int = 64):
    """The redesign's headline scenario: a BATCH of cross-index
    trace-to-source queries (serve sink rows -> prep raw rows) through the
    FederatedSession — plan split at the boundary, one cost-model-routed
    pass per side, mask stitch between — against the merged-single-index
    baseline answering the identical batch with one composed-relation
    probe.  PAIRED per-round ratios (contender order alternating) keep the
    headline number robust to shared-host load drift."""
    n = 1000 if quick else 4000
    n_ops = 10 if quick else 14
    B = 8 if quick else n_probes
    reps = 8 if quick else 24
    merged_idx, merged_sink = build_deep_chain(n=n, n_ops=n_ops)
    catalog, fed_sink, fed_src = build_split_chain(n=n, n_ops=n_ops)

    n_sink = merged_idx.datasets[merged_sink].n_rows
    rng = np.random.default_rng(13)
    probes = [sorted(rng.choice(n_sink, size=4, replace=False).tolist())
              for _ in range(B)]

    merged_sess = QuerySession(merged_idx,
                               ComposedIndex(merged_idx,
                                             memory_budget_bytes=256 << 20))
    fed_sess = catalog.session()

    def run_merged():
        return merged_sess.run(prov(merged_idx).source(merged_sink)
                               .rows_batch(probes).backward()
                               .to("chain_src").plan())

    def run_fed():
        return fed_sess.run(prov(catalog).source(fed_sink)
                            .rows_batch(probes).backward()
                            .to(fed_src).plan())

    # warm-up: both sides compose whatever their cost models choose, and
    # the sanity check pins byte-identical answers
    t0 = time.perf_counter()
    fed_first = run_fed()
    fed_cold_ms = (time.perf_counter() - t0) * 1e3
    for a, b in zip(run_merged(), fed_first):
        np.testing.assert_array_equal(a, b)
    run_merged(), run_fed()

    raw = {"merged": [], "federated": []}
    for r in range(reps):
        order = (("merged", run_merged), ("federated", run_fed))
        if r % 2:
            order = order[::-1]
        for name, fn in order:
            t0 = time.perf_counter()
            fn()
            raw[name].append((time.perf_counter() - t0) * 1e3)
    merged_ms = float(np.median(raw["merged"]))
    fed_ms = float(np.median(raw["federated"]))
    overhead = float(np.median(np.array(raw["federated"])
                               / np.array(raw["merged"])))
    out = {
        "n_ops": n_ops, "n_probes": B,
        "merged_ms": merged_ms, "federated_ms": fed_ms,
        "federated_cold_ms": fed_cold_ms,
        "overhead_ratio": overhead,
        "federation_stats": fed_sess.stats()["federation"],
    }
    print(f"\n== federation: batched trace-to-source, B={B} "
          f"({n_ops}-op chain split at the midpoint) ==")
    print(f"  merged single index {merged_ms:8.2f} ms | federated "
          f"{fed_ms:8.2f} ms ({overhead:.2f}x; cold {fed_cold_ms:.2f} ms)")
    return out


# ---------------------------------------------------------------------------
# Sharded index: batched Q1/Q2 probe throughput vs shard count
# ---------------------------------------------------------------------------
def run_sharded(quick: bool = False):
    """Batched Q1/Q2 probes through the row-range-sharded hop-cache at
    S in {1, 2, 4, 8} shards (n=1M under ``--quick``, n=10M in the full
    bench).  Each shard's block probe is timed individually; ``total_ms``
    sums them (what one host running every shard sequentially pays) and
    ``critical_ms`` takes the max (the mesh-parallel critical path — what
    an S-device mesh pays, since the blocks are independent until the
    final concat/OR join).  Throughput derives from the critical path and
    is labeled as such."""
    from repro.provenance.sharded import (
        ShardedComposedIndex,
        ShardedProvenanceIndex,
    )

    n = 1_000_000 if quick else 10_000_000
    n_ops = 6 if quick else 8
    B = 8 if quick else 16
    reps = 1 if quick else 3
    shard_counts = [1, 2, 4, 8]

    idx, sink = build_deep_chain(n=n, n_ops=n_ops)
    src = "chain_src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    rng = np.random.default_rng(29)
    masks_f = np.zeros((B, n_src), dtype=bool)
    masks_b = np.zeros((B, n_sink), dtype=bool)
    for b in range(B):
        masks_f[b, rng.choice(n_src, size=4, replace=False)] = True
        masks_b[b, rng.choice(n_sink, size=4, replace=False)] = True

    # merged baseline answers pin parity for every shard count
    ci = ComposedIndex(idx, memory_budget_bytes=1 << 30)
    want_f = ci.probe_forward(masks_f, src, sink)
    want_b = ci.probe_backward(masks_b, sink, src)
    merged_f_ms = _time_ms(lambda: ci.probe_forward(masks_f, src, sink), reps)
    merged_b_ms = _time_ms(lambda: ci.probe_backward(masks_b, sink, src), reps)

    out = {"n": n, "n_ops": len(idx.ops), "n_probes": B,
           "merged_q1_ms": merged_f_ms, "merged_q2_ms": merged_b_ms,
           "shards": {}}
    print(f"\n== sharded index: batched Q1/Q2 probes, n={n}, B={B} ==")
    print(f"  merged baseline  Q1 {merged_f_ms:8.2f} ms | "
          f"Q2 {merged_b_ms:8.2f} ms")
    for S in shard_counts:
        sv = ShardedProvenanceIndex(idx, S, engine="numpy")
        sc = sv.composed(memory_budget_bytes=1 << 30)
        t0 = time.perf_counter()
        got_f = sc.probe_forward(masks_f, src, sink)
        compose_ms = (time.perf_counter() - t0) * 1e3
        got_b = sc.probe_backward(masks_b, sink, src)
        np.testing.assert_array_equal(got_f, want_f)
        np.testing.assert_array_equal(got_b, want_b)
        entry = sc._entry(src, sink)
        # per-block timings take the pre-transposed float32 masks the probe
        # surface hoists — each device converts its replicated input once,
        # so the per-block cost is the spmm alone
        mT_f = np.ascontiguousarray(masks_f.T, dtype=np.float32)
        mT_b = np.ascontiguousarray(masks_b.T, dtype=np.float32)
        per_f = [_time_ms(lambda blk=blk: ShardedComposedIndex._block_forward(
            blk, mT_f), reps) for blk in entry.blocks]
        per_b = [_time_ms(lambda blk=blk: ShardedComposedIndex._block_backward(
            blk, mT_b[blk.lo: blk.hi]), reps) for blk in entry.blocks]
        crit_f, crit_b = max(per_f), max(per_b)
        row = {
            "q1_total_ms": float(sum(per_f)),
            "q1_critical_ms": crit_f,
            "q2_total_ms": float(sum(per_b)),
            "q2_critical_ms": crit_b,
            "compose_cold_ms": compose_ms,
            # probes/s on the mesh critical path (S devices, one per shard)
            "q1_critical_path_probes_per_s": B * 1e3 / max(crit_f, 1e-9),
            "q2_critical_path_probes_per_s": B * 1e3 / max(crit_b, 1e-9),
            "blocks": [{"rows": int(blk.hi - blk.lo), "nnz": int(blk.nnz),
                        "kind": blk.kind} for blk in entry.blocks],
        }
        out["shards"][str(S)] = row
        print(f"  S={S}  Q1 critical {crit_f:8.2f} ms "
              f"({row['q1_critical_path_probes_per_s']:10.0f} probes/s) | "
              f"Q2 critical {crit_b:8.2f} ms "
              f"({row['q2_critical_path_probes_per_s']:10.0f} probes/s) | "
              f"total {row['q1_total_ms']:.2f}/{row['q2_total_ms']:.2f} ms")
    return out


def run_backward_probe_microbench(idx, src, sink, quick: bool = False):
    """Old per-probe Python loop over relation rows vs the vectorized
    transposed-plane scatter-OR, on the bitplane backend."""
    from repro.core.provtensor import pack_bitplane

    ci = ComposedIndex(idx, backend="bitplane", memory_budget_bytes=256 << 20)
    entry = ci._relation_entry(src, sink)
    rel = entry.rel
    n_sink = idx.datasets[sink].n_rows
    rng = np.random.default_rng(5)
    B = 64 if quick else 256
    masks = np.zeros((B, n_sink), dtype=bool)
    for b in range(B):
        masks[b, rng.choice(n_sink, size=4, replace=False)] = True
    reps = 1 if quick else 3

    def old_loop():
        words = pack_bitplane(masks)
        return np.stack([(rel & w[None, :]).any(axis=1) for w in words], axis=0)

    new = ci.probe_backward(masks, sink, src)       # warms the relT plane
    np.testing.assert_array_equal(new, old_loop())  # exact parity
    old_ms = _time_ms(old_loop, reps)
    new_ms = _time_ms(lambda: ci.probe_backward(masks, sink, src), reps)
    out = {"n_probes": B, "old_loop_ms": old_ms, "vectorized_ms": new_ms,
           "speedup": old_ms / max(new_ms, 1e-9)}
    print(f"  backward-probe microbench (B={B}): loop {old_ms:.2f} ms | "
          f"vectorized {new_ms:.2f} ms ({out['speedup']:.1f}x)")
    return out


def _write_trajectory(results: dict) -> None:
    """``BENCH_query.json`` is shared: sibling benches (serving / stream /
    impact / kernels) merge their own sections into it, so carry over any
    section this bench does not produce instead of overwriting the file
    wholesale (which silently dropped ``serving`` whenever this bench ran
    last)."""
    path = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir,
        "BENCH_query.json"))
    merged: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except ValueError:
            merged = {}
    merged.update(results)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=float)
    print(f"wrote {path}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced configuration (CI smoke: small chain, "
                    "1 rep) — still writes BENCH_query.json")
    args = ap.parse_args()
    _write_trajectory(run(quick=args.quick))
