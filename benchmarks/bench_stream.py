"""Streaming-capture benchmark: bounded RSS + incremental hop-cache extension.

The scenario the spill tier and the incremental extension exist for — a
pipeline that never stops appending ops (a long-running preparation service,
a feature-store backfill) while lineage probes keep arriving:

* **extend micro** — a warm composed relation over a deep structured chain;
  each trial appends ONE op and compares the warm probe (eager one-step
  ``extend_tail``) against a cold ``ComposedIndex`` rebuild of the whole
  chain (the seed's invalidate+recompose behavior).  Headline: the median
  recompose/extend ratio (acceptance: >= 5x).
* **stream** — a continuous append stream (identity / filter / shuffle /
  append block mix, row count self-stabilizing around ~270) against a
  spill-tiered index + hop-cache vs the unbounded seed path.  Per sample:
  process RSS (psutil, when available), payload-resident bytes (op tensors +
  composed relations), batched Q1/Q2 probe p50/p99 through the QuerySession,
  and the extend/recompose counters.  The spill arm asserts payload
  residency stays under the configured budgets the whole run; the baseline
  arm recomposes from scratch at every sample and is CAPPED (logged) —
  that's the point.

Answers are asserted byte-identical between the spilled and the unbounded
index before anything is timed.

Run as a script this merges a ``stream`` section into ``BENCH_query.json``
at the repo root (the perf-trajectory artifact bench_query.py owns).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

try:
    import psutil
except ImportError:                          # degrade to payload accounting
    psutil = None

from repro.core.hopcache import ComposedIndex
from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.core.spill import SpillPolicy
from repro.dataprep.table import Table
from repro.provenance import QuerySession, prov


# ===========================================================================
# Fast append-stream driver (hand-built CaptureInfo, minimal table cost)
# ===========================================================================
def _table(n, c=2):
    data = np.zeros((n, c), dtype=np.float32)
    return Table(columns=[f"c{j}" for j in range(c)], data=data,
                 null=np.zeros((n, c), dtype=bool),
                 index=np.arange(n, dtype=np.int64), vocab={})


def _identity_info(n):
    return CaptureInfo(op_name="transform:scale", category=OpCategory.TRANSFORM,
                       contextual=False, n_out=n, n_in=[n],
                       params={"col": "c0", "fn": "scale",
                               "fn_params": {"factor": 1.0}},
                       attr_maps=[AttrMap("identity")])


def _filter_info(kept, n_in):
    return CaptureInfo(op_name="filter_rows", category=OpCategory.HREDUCE,
                       contextual=False, n_out=len(kept), n_in=[n_in],
                       kept_rows=kept, attr_maps=[AttrMap("identity")])


def _gather_info(src_rows, n_in):
    return CaptureInfo(op_name="shuffle", category=OpCategory.HAUGMENT,
                       contextual=False, n_out=len(src_rows), n_in=[n_in],
                       src_rows=src_rows, attr_maps=[AttrMap("identity")])


def _append_info(n_l, n_r):
    return CaptureInfo(op_name="append_rows", category=OpCategory.APPEND,
                       contextual=False, n_out=n_l + n_r, n_in=[n_l, n_r],
                       attr_maps=[AttrMap("identity"), AttrMap("identity")])


class StreamDriver:
    """Deterministic op stream: i%4 -> identity / ~3%-drop filter / shuffle
    gather / +8-row append block.  Row count stabilizes near drop/growth
    equilibrium (~270 from n0=256), so per-op cost stays flat and the ONLY
    thing growing without bound on the seed path is provenance."""

    BLOCK = 8

    def __init__(self, idx: ProvenanceIndex, n0: int = 256, seed: int = 0):
        self.idx = idx
        self.rng = np.random.default_rng(seed)
        idx.add_source("d0", _table(n0))
        self.cur, self.n = "d0", n0
        self.i = 0
        self._blocks = 0

    def step(self):
        i, n = self.i, self.n
        out = f"d{i + 1}"
        kind = i % 4
        if kind == 0:
            self.idx.record([self.cur], out, _table(n), _identity_info(n))
        elif kind == 1:
            kept = np.flatnonzero(self.rng.random(n) > 0.03).astype(np.int32)
            if len(kept) == 0:
                kept = np.array([0], dtype=np.int32)
            self.idx.record([self.cur], out, _table(len(kept)),
                            _filter_info(kept, n))
            n = len(kept)
        elif kind == 2:
            perm = self.rng.permutation(n).astype(np.int32)
            self.idx.record([self.cur], out, _table(n), _gather_info(perm, n))
        else:
            blk = f"blk{self._blocks}"
            self._blocks += 1
            self.idx.add_source(blk, _table(self.BLOCK))
            self.idx.record([self.cur, blk], out, _table(n + self.BLOCK),
                            _append_info(n, self.BLOCK))
            n += self.BLOCK
        self.cur, self.n, self.i = out, n, i + 1


def _probe_latency(sess, idx, cur, reps=7, batch=16, seed=1):
    """Batched Q1 (src->cur forward) + Q2 (cur->src backward) wall times."""
    rng = np.random.default_rng(seed)
    n_src = idx.datasets["d0"].n_rows
    n_cur = idx.datasets[cur].n_rows
    times = []
    for _ in range(reps):
        fwd = [rng.integers(0, n_src, size=4).tolist() for _ in range(batch)]
        bwd = [rng.integers(0, n_cur, size=4).tolist() for _ in range(batch)]
        t0 = time.perf_counter()
        sess.run(prov(idx).source("d0").rows_batch(fwd).forward().to(cur).plan())
        sess.run(prov(idx).source(cur).rows_batch(bwd).backward().to("d0").plan())
        times.append(time.perf_counter() - t0)
    a = np.sort(np.asarray(times))
    return float(a[len(a) // 2]), float(a[min(len(a) - 1, int(len(a) * 0.99))])


def _rss_mb():
    if psutil is None:
        return None
    return psutil.Process().memory_info().rss / 1e6


# ===========================================================================
# (a) extend micro: warm one-step extension vs cold chain recompose
# ===========================================================================
def run_extend_micro(quick: bool = False):
    hops = 12 if quick else 24
    n = 1024 if quick else 4096
    trials = 5 if quick else 9
    rng = np.random.default_rng(3)
    idx = ProvenanceIndex("extmicro")
    idx.add_source("d0", _table(n))
    cur, cn = "d0", n
    for i in range(hops):
        kept = np.flatnonzero(rng.random(cn) > 0.02).astype(np.int32)
        out = f"d{i + 1}"
        idx.record([cur], out, _table(len(kept)), _filter_info(kept, cn))
        cur, cn = out, len(kept)

    ci = ComposedIndex(idx)
    ci.relation("d0", cur)                   # warm the composed chain
    ratios, ext_ns, rec_ns = [], [], []
    for t in range(trials):
        kept = np.flatnonzero(rng.random(cn) > 0.02).astype(np.int32)
        out = f"x{t}"
        idx.record([cur], out, _table(len(kept)), _filter_info(kept, cn))
        cur, cn = out, len(kept)
        t0 = time.perf_counter()
        ci.relation("d0", cur)               # eager sync + warm probe
        te = time.perf_counter() - t0
        t0 = time.perf_counter()
        ComposedIndex(idx).relation("d0", cur)   # invalidate+recompose
        tr = time.perf_counter() - t0
        ratios.append(tr / te)
        ext_ns.append(te)
        rec_ns.append(tr)
    med = float(np.median(ratios))
    print(f"\n== extend micro: {hops}-hop chain, n={n} ==")
    print(f"warm extend   p50 {np.median(ext_ns) * 1e3:8.3f} ms")
    print(f"cold recompose p50 {np.median(rec_ns) * 1e3:8.3f} ms")
    print(f"recompose/extend ratio (median of {trials}): {med:.1f}x")
    assert ci.stats()["extends"] >= trials, ci.stats()
    return {"hops": hops, "n": n, "trials": trials,
            "extend_ms_p50": float(np.median(ext_ns) * 1e3),
            "recompose_ms_p50": float(np.median(rec_ns) * 1e3),
            "ratio_median": med}


# ===========================================================================
# (b) the append stream: bounded residency vs unbounded growth
# ===========================================================================
def run_stream(quick: bool = False, ops: int = 0):
    ops = ops or (2000 if quick else 1_000_000)
    n_samples = 8 if quick else 20
    base_cap = 2000 if quick else 20_000     # cold-recompose arm cap
    # op-tensor / composed-relation residency budgets, sized so the spill
    # tier actually engages within the run length
    tensor_budget = (256 << 10) if quick else (1 << 20)
    cache_budget = (512 << 10) if quick else (4 << 20)
    sample_every = max(1, ops // n_samples)

    # -- spill arm: bounded residency, eager extension ----------------------
    idx = ProvenanceIndex("stream",
                          spill=SpillPolicy(budget_bytes=tensor_budget))
    # spilled relations are rebuildable, so THEIR store may drop oldest
    # segments under a disk budget (op-tensor stores must never drop)
    ci = idx.composed(memory_budget_bytes=cache_budget,
                      spill=SpillPolicy(disk_budget_bytes=256 << 20))
    sess = QuerySession(idx, composed=ci)
    drv = StreamDriver(idx)

    # -- parity spot-check BEFORE timing: spilled == unbounded --------------
    ref_idx = ProvenanceIndex("streamref")
    ref_drv = StreamDriver(ref_idx)
    warm = min(ops, 400)
    for _ in range(warm):
        drv.step()
        ref_drv.step()
    want = ComposedIndex(ref_idx).relation("d0", ref_drv.cur)
    got = ci.relation("d0", drv.cur)
    w = np.asarray(want.todense()) if hasattr(want, "todense") else np.asarray(want)
    g = np.asarray(got.todense()) if hasattr(got, "todense") else np.asarray(got)
    assert np.array_equal(w, g), "spilled arm diverged from unbounded reference"
    print(f"parity: spilled == unbounded at op {warm} (byte-identical)")
    del ref_idx, ref_drv, want, got, w, g

    samples = []
    t_start = time.perf_counter()
    while drv.i < ops:
        drv.step()
        if drv.i % sample_every == 0 or drv.i == ops:
            # the one-time incremental drain of the appended tail (one
            # closed-form extension per absorbed op), separated out so the
            # probe numbers show the steady state
            t0 = time.perf_counter()
            ci.contains("d0", drv.cur)
            sync_s = time.perf_counter() - t0
            p50, p99 = _probe_latency(sess, idx, drv.cur)
            sp = idx.stats()["spill"]
            cs = ci.stats()
            payload = sp["resident_bytes"] + cs["bytes"]
            assert sp["resident_bytes"] <= tensor_budget, sp
            assert cs["bytes"] <= cache_budget * ci._spill.high_watermark, cs
            samples.append({
                "op": drv.i, "rss_mb": _rss_mb(),
                "payload_resident_mb": payload / 1e6,
                "tensor_resident_mb": sp["resident_bytes"] / 1e6,
                "cache_resident_mb": cs["bytes"] / 1e6,
                "spilled_ops": sp["spilled_ops"],
                "sync_ms": sync_s * 1e3,
                "probe_p50_ms": p50 * 1e3, "probe_p99_ms": p99 * 1e3,
                "extends": cs["extends"], "recomposes": cs["recomposes"],
            })
    stream_s = time.perf_counter() - t_start
    spilled_disk_mb = idx.stats()["spill"]["store"]["disk_bytes"] / 1e6

    # -- baseline arm: no spill, cold recompose per sample (seed path) ------
    if base_cap < ops:
        print(f"baseline arm CAPPED at {base_cap} of {ops} ops "
              "(unbounded growth + per-sample recompose would dominate the run)")
    bidx = ProvenanceIndex("streambase")
    bdrv = StreamDriver(bidx)
    bsamples = []
    bevery = max(1, base_cap // n_samples)
    while bdrv.i < base_cap:
        bdrv.step()
        if bdrv.i % bevery == 0 or bdrv.i == base_cap:
            bci = ComposedIndex(bidx)        # invalidate: cold every sample
            bsess = QuerySession(bidx, composed=bci)
            t0 = time.perf_counter()
            bci.relation("d0", bdrv.cur)     # the from-scratch recompose
            rebuild_s = time.perf_counter() - t0
            p50, p99 = _probe_latency(bsess, bidx, bdrv.cur)
            bsamples.append({
                "op": bdrv.i, "rss_mb": _rss_mb(),
                "payload_resident_mb": bidx.prov_nbytes() / 1e6,
                "rebuild_ms": rebuild_s * 1e3,
                "probe_p50_ms": p50 * 1e3, "probe_p99_ms": p99 * 1e3,
            })

    print(f"\n== stream: {ops} ops, tensor budget {tensor_budget / 1e6:.2f} MB, "
          f"cache budget {cache_budget / 1e6:.2f} MB ==")
    print(f"{'op':>9s} {'resident MB':>12s} {'RSS MB':>9s} {'sync ms':>9s} "
          f"{'p50 ms':>8s} {'p99 ms':>8s} {'extends':>8s} {'recomp':>7s}")
    for s in samples:
        rss = f"{s['rss_mb']:9.1f}" if s["rss_mb"] is not None else "      n/a"
        print(f"{s['op']:9d} {s['payload_resident_mb']:12.2f} {rss} "
              f"{s['sync_ms']:9.1f} "
              f"{s['probe_p50_ms']:8.2f} {s['probe_p99_ms']:8.2f} "
              f"{s['extends']:8d} {s['recomposes']:7d}")
    last, blast = samples[-1], bsamples[-1]
    print(f"stream wall {stream_s:.1f}s; spilled {spilled_disk_mb:.1f} MB to disk; "
          f"payload-resident bounded at {last['payload_resident_mb']:.2f} MB")
    print(f"baseline at op {blast['op']}: resident "
          f"{blast['payload_resident_mb']:.2f} MB (unbounded), "
          f"rebuild {blast['rebuild_ms']:.1f} ms (cold recompose), "
          f"warm p50 {blast['probe_p50_ms']:.2f} ms")
    return {
        "ops": ops, "tensor_budget_mb": tensor_budget / 1e6,
        "cache_budget_mb": cache_budget / 1e6,
        "parity": "byte-identical",
        "stream_wall_s": stream_s, "spilled_disk_mb": spilled_disk_mb,
        "samples": samples,
        "baseline_cap": base_cap, "baseline_samples": bsamples,
    }


def run(quick: bool = False, ops: int = 0):
    return {"extend_micro": run_extend_micro(quick=quick),
            "stream": run_stream(quick=quick, ops=ops)}


def _merge_trajectory(section: dict) -> None:
    """``BENCH_query.json`` belongs to bench_query.py; this bench only
    extends it with the ``stream`` section (creating the file when the
    query bench has not run yet)."""
    path = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir,
        "BENCH_query.json"))
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["stream"] = section
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"wrote {path} (stream section)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced configuration (CI smoke) — still merges "
                    "the stream section into BENCH_query.json")
    ap.add_argument("--ops", type=int, default=0,
                    help="override the append-stream length")
    args = ap.parse_args()
    out = run(quick=args.quick, ops=args.ops)
    _merge_trajectory(out)
