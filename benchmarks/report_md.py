"""Emit the EXPERIMENTS.md roofline tables from dry-run reports.

    PYTHONPATH=src python -m benchmarks.report_md \
        reports/dryrun_baseline.json reports/dryrun_optimized.json
"""
import json
import sys

from benchmarks.roofline import analyze


def emit(path: str, mesh: str) -> str:
    rows = analyze(path, mesh=None)
    out = []
    out.append(f"\n### {mesh}-pod mesh ({path})\n")
    out.append("| arch | shape | compute s | memory s | collective s | dominant "
               "| useful | roofline | temp GB |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("mesh") != mesh and r.get("dominant") != "skip":
            continue
        if r.get("dominant") == "skip":
            if mesh == "single" and r.get("mesh") == "single":
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| {r['dominant']} | {r['useful_ratio']:.3f} "
            f"| {100*r['roofline_fraction']:.2f}% | {r['temp_gb']:.1f} |")
    return "\n".join(out)


def main():
    for path in sys.argv[1:]:
        for mesh in ("single", "multi"):
            print(emit(path, mesh))


if __name__ == "__main__":
    main()
