"""Table XI + Fig 6: the join operator at TPC-DI scale factors.

Per scale factor: dataset sizes, TensProv provenance size + capture time +
why-query time, Chapman-style size + capture time (up to SF 9 — beyond that
the baseline does not scale; the paper reports the same cut-off).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.core.query import q2_backward
from repro.dataprep import ops as P
from repro.dataprep.usecases import TPCDI_SCALES, make_tpcdi_join_inputs

_CHAPMAN_MAX_SF = 9       # paper: '-' at SF 15/20 (does not scale)


def run(quick: bool = False):
    scales = [3, 5] if quick else [3, 5, 9, 15, 20]
    rows = []
    for sf in scales:
        left, right = make_tpcdi_join_inputs(sf)

        # --- TensProv: active capture through the merge --------------------
        t0 = time.perf_counter()
        out, info = P.join(left, right, on="key", how="inner")
        idx = ProvenanceIndex(f"tpcdi-{sf}")
        idx.add_source("L", left)
        idx.add_source("R", right)
        idx.record(["L", "R"], "J", out, info, keep_output=False,
                   input_tables=[left, right])
        # build both CSR directions (the queryable index structure)
        idx.ops[0].tensor.bwd(0)
        idx.ops[0].tensor.bwd(1)
        t_capture = time.perf_counter() - t0
        size_mb = idx.prov_nbytes() / 1e6

        # why-provenance query on the captured join
        qrows = np.linspace(0, out.n_rows - 1, 16).astype(int).tolist()
        t0 = time.perf_counter()
        for r in qrows:
            q2_backward(idx, "J", [r], "L")
        t_query = (time.perf_counter() - t0) / len(qrows)

        # --- Chapman baseline ----------------------------------------------
        if sf <= _CHAPMAN_MAX_SF and not quick:
            ch = ChapmanIndex()
            t0 = time.perf_counter()
            ch.capture(["L", "R"], [left, right], "J", out, info)
            c_capture = time.perf_counter() - t0
            c_mb = ch.total_nbytes() / 1e6
        elif sf <= 5:
            ch = ChapmanIndex()
            t0 = time.perf_counter()
            ch.capture(["L", "R"], [left, right], "J", out, info)
            c_capture = time.perf_counter() - t0
            c_mb = ch.total_nbytes() / 1e6
        else:
            c_capture, c_mb = None, None

        rows.append({
            "sf": sf, "n_left": left.n_rows, "n_right": right.n_rows,
            "n_out": out.n_rows, "tensprov_mb": size_mb,
            "tensprov_capture_s": t_capture, "query_s": t_query,
            "chapman_mb": c_mb, "chapman_capture_s": c_capture,
        })

    print("\n== Table XI / Fig 6: TPC-DI join provenance ==")
    hdr = f"{'sf':>3s} {'left/right':>18s} {'TensProv':>9s} {'cap(s)':>7s} " \
          f"{'query(s)':>9s} {'Chapman':>9s} {'cap(s)':>7s}"
    print(hdr)
    for r in rows:
        cm = f"{r['chapman_mb']:.0f}MB" if r["chapman_mb"] else "-"
        cc = f"{r['chapman_capture_s']:.1f}" if r["chapman_capture_s"] else "-"
        print(f"{r['sf']:3d} {r['n_left']:>8d}/{r['n_right']:<9d} "
              f"{r['tensprov_mb']:7.2f}MB {r['tensprov_capture_s']:7.2f} "
              f"{r['query_s']:9.4f} {cm:>9s} {cc:>7s}")
    return {"table": "XI/Fig6", "rows": rows}


if __name__ == "__main__":
    run()
