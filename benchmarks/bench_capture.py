"""Fig 3 + Table X: provenance-capture overhead — TensProv vs Chapman.

Per use case: pipeline wall time with structured TensProv capture (the
default: implicit identity/gather/range tensors, no COO allocation), with
the legacy eager-COO TensProv capture, and with Chapman-style cell-level
capture.  The Chapman mirror rides the supported ``add_record_hook``
capture-observer API — no ``idx.record`` monkeypatching.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.capture import force_coo_capture
from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.usecases import USECASES


def _time(fn, reps=3):
    fn()  # warm-up: allocator + lazy imports, so reps=1 (quick) stays honest
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False):
    reps = 1 if quick else 3
    rows = []
    for name in USECASES:
        mk, runner = USECASES[name]

        def tens():
            runner(ProvenanceIndex(name), mk(0))

        def tens_coo():
            with force_coo_capture():
                runner(ProvenanceIndex(name), mk(0))

        def chap():
            idx = ProvenanceIndex(name)
            ch = ChapmanIndex()
            hook = idx.add_record_hook(
                lambda input_ids, output_id, out_table, info, input_tables:
                ch.capture(input_ids, input_tables, output_id, out_table, info))
            try:
                runner(idx, mk(0))
            finally:
                idx.remove_record_hook(hook)

        t_tens = _time(tens, reps)
        t_coo = _time(tens_coo, reps)
        t_chap = _time(chap, reps)
        rows.append((name, t_tens, t_coo, t_chap,
                     t_chap / t_tens, t_coo / t_tens))
    print("\n== Fig 3 / Table X: capture time (s) and speedup ==")
    print(f"{'usecase':10s} {'TensProv':>10s} {'Tens-COO':>10s} {'Chapman':>10s} "
          f"{'vs Chap':>8s} {'vs COO':>7s}")
    for name, t, c, ch, s, sc in rows:
        print(f"{name:10s} {t:10.3f} {c:10.3f} {ch:10.3f} {s:8.1f}x {sc:6.2f}x")
    return {"table": "Fig3/X", "rows": [
        {"usecase": n, "tensprov_s": t, "tensprov_coo_s": c, "chapman_s": ch,
         "speedup": s, "speedup_vs_coo": sc}
        for n, t, c, ch, s, sc in rows]}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="1 rep per system")
    run(quick=ap.parse_args().quick)
