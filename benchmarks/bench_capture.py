"""Fig 3 + Table X: provenance-capture overhead — TensProv vs Chapman.

Per use case: pipeline wall time without capture, with TensProv capture,
with Chapman-style capture; overheads and the Table-X speedup column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep import ops as P
from repro.dataprep.usecases import USECASES


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False):
    reps = 1 if quick else 3
    rows = []
    for name in USECASES:
        mk, runner = USECASES[name]

        def tens():
            runner(ProvenanceIndex(name), mk(0))

        def chap():
            idx = ProvenanceIndex(name)
            ch = ChapmanIndex()
            orig = idx.record

            def record(input_ids, output_id, out_table, info,
                       keep_output=False, input_tables=None):
                ch.capture(input_ids, input_tables, output_id, out_table, info)
                return orig(input_ids, output_id, out_table, info,
                            keep_output=keep_output, input_tables=input_tables)

            idx.record = record
            runner(idx, mk(0))

        t_tens = _time(tens, reps)
        t_chap = _time(chap, reps)
        rows.append((name, t_tens, t_chap, t_chap / t_tens))
    print("\n== Fig 3 / Table X: capture time (s) and speedup ==")
    print(f"{'usecase':10s} {'TensProv':>10s} {'Chapman':>10s} {'speedup':>8s}")
    for name, t, c, s in rows:
        print(f"{name:10s} {t:10.3f} {c:10.3f} {s:8.1f}x")
    return {"table": "Fig3/X", "rows": [
        {"usecase": n, "tensprov_s": t, "chapman_s": c, "speedup": s}
        for n, t, c, s in rows]}


if __name__ == "__main__":
    run()
