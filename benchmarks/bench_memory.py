"""Table IX: memory required for storing provenance — TensProv vs Chapman.

Prints one row per use case: structured TensProv bytes (implicit tensors,
the capture default), legacy eager-COO TensProv bytes, Chapman cell-level
bytes, and the two Table-IX ratios.  The Chapman mirror uses the supported
``add_record_hook`` capture-observer API (no monkeypatching), so it sees
exactly the record stream the real index sees.
"""
from __future__ import annotations

from repro.core.capture import force_coo_capture
from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.usecases import USECASES


def _capture_usecase(name: str, mirror_chapman: bool = False):
    """Run one use case into a fresh index; optionally mirror the capture
    stream into a ChapmanIndex through the record-hook API."""
    mk, runner = USECASES[name]
    idx = ProvenanceIndex(name)
    ch = ChapmanIndex() if mirror_chapman else None
    hook = None
    if ch is not None:
        hook = idx.add_record_hook(
            lambda input_ids, output_id, out_table, info, input_tables:
            ch.capture(input_ids, input_tables, output_id, out_table, info))
    try:
        runner(idx, mk(0))
    finally:
        if hook is not None:
            idx.remove_record_hook(hook)
    return idx, ch


def run(quick: bool = False):
    rows = []
    for name in USECASES:
        idx, ch = _capture_usecase(name, mirror_chapman=True)
        with force_coo_capture():
            coo_idx, _ = _capture_usecase(name)
        tens_mb = idx.prov_nbytes() / 1e6
        coo_mb = coo_idx.prov_nbytes() / 1e6
        chap_mb = ch.total_nbytes() / 1e6
        rows.append((name, tens_mb, coo_mb, chap_mb,
                     chap_mb / tens_mb, chap_mb / coo_mb, coo_mb / tens_mb))
    print("\n== Table IX: provenance memory (MB) ==")
    print(f"{'usecase':10s} {'TensProv':>10s} {'Tens-COO':>10s} {'Chapman':>10s} "
          f"{'ratio':>8s} {'ratioCOO':>8s} {'improve':>8s}")
    for name, t, c, ch, r, rc, imp in rows:
        print(f"{name:10s} {t:10.3f} {c:10.3f} {ch:10.2f} "
              f"{r:7.1f}x {rc:7.1f}x {imp:7.1f}x")
    return {"table": "IX", "rows": [
        {"usecase": n, "tensprov_mb": t, "tensprov_coo_mb": c,
         "chapman_mb": ch, "ratio": r, "ratio_coo": rc,
         "improvement_vs_coo": imp}
        for n, t, c, ch, r, rc, imp in rows]}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="accepted for harness uniformity (already cheap)")
    run(quick=ap.parse_args().quick)
