"""Table IX: memory required for storing provenance — TensProv vs Chapman.

Prints one row per use case:  usecase, tensprov_mb, chapman_mb, ratio.
"""
from __future__ import annotations

import numpy as np

from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.usecases import USECASES


class _DualRecorder:
    """ProvenanceIndex that mirrors every record() into a ChapmanIndex."""

    def __init__(self):
        self.tens = ProvenanceIndex("dual")
        self.chap = ChapmanIndex()
        self._tables = {}

    def run(self, name: str):
        mk, run = USECASES[name]
        t = mk(0)
        orig_record = self.tens.record
        tables = self._tables

        def record(input_ids, output_id, out_table, info, keep_output=False,
                   input_tables=None):
            self.chap.capture(input_ids, input_tables, output_id, out_table, info)
            tables[output_id] = out_table
            return orig_record(input_ids, output_id, out_table, info,
                               keep_output=keep_output, input_tables=input_tables)

        self.tens.record = record
        out = run(self.tens, t)
        return out


def run(quick: bool = False):
    rows = []
    for name in USECASES:
        d = _DualRecorder()
        d.run(name)
        tens_mb = d.tens.prov_nbytes() / 1e6
        chap_mb = d.chap.total_nbytes() / 1e6
        rows.append((name, tens_mb, chap_mb, chap_mb / tens_mb))
    print("\n== Table IX: provenance memory (MB) ==")
    print(f"{'usecase':10s} {'TensProv':>10s} {'Chapman':>10s} {'ratio':>8s}")
    for name, t, c, r in rows:
        print(f"{name:10s} {t:10.2f} {c:10.2f} {r:8.1f}x")
    return {"table": "IX", "rows": [
        {"usecase": n, "tensprov_mb": t, "chapman_mb": c, "ratio": r}
        for n, t, c, r in rows]}


if __name__ == "__main__":
    run()
