PY ?= python
TIMEOUT ?= 900

.PHONY: test test-fast test-sharded test-kernels bench-query bench-quick \
        bench-serving bench-serving-quick bench-stream bench-stream-quick \
        bench-impact bench-impact-quick bench-roofline bench-roofline-quick ci

# tier-1 verify (ROADMAP.md): the whole suite, stop at first failure
test:
	timeout $(TIMEOUT) env PYTHONPATH=src $(PY) -m pytest -x -q

# quick signal: the provenance core only (no model/trainer substrate) —
# incl. the structured-representation parity suite, so representation-layer
# regressions fail in this cheap lane before the full suite runs
test-fast:
	timeout 300 env PYTHONPATH=src $(PY) -m pytest -x -q \
	  tests/test_provtensor.py tests/test_schema.py tests/test_queries.py \
	  tests/test_query_parity.py tests/test_structured.py \
	  tests/test_compose.py tests/test_recompute.py

# kernel lane: Pallas-vs-oracle parity (interpret mode), the fused
# batched-walk grid, launch accounting, and calibration round-trips
test-kernels:
	timeout $(TIMEOUT) env PYTHONPATH=src $(PY) -m pytest -x -q \
	  tests/test_kernels.py tests/test_backend_parity.py

# the CI multi-device lane locally: 8 forced host CPU devices so the
# shard_map collective walkers and mesh integration paths really execute
test-sharded:
	timeout $(TIMEOUT) env PYTHONPATH=src JAX_PLATFORMS=cpu \
	  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  $(PY) -m pytest -x -q tests/test_sharded_parity.py \
	  tests/test_federation.py tests/test_integration_sharded.py

bench-query:
	env PYTHONPATH=src $(PY) benchmarks/bench_query.py

# reduced configuration (small chain, 1 rep) — the CI smoke step; still
# exercises every section incl. cost-model routing and writes BENCH_query.json
bench-quick:
	env PYTHONPATH=src $(PY) benchmarks/bench_query.py --quick

# serving tier vs sync per-request loop (saturation + Poisson open loop);
# merges the `serving` section into BENCH_query.json
bench-serving:
	env PYTHONPATH=src $(PY) benchmarks/bench_serving.py

bench-serving-quick:
	env PYTHONPATH=src $(PY) benchmarks/bench_serving.py --quick

# streaming capture: incremental extension vs recompose + bounded-residency
# append stream; merges the `stream` section into BENCH_query.json
bench-stream:
	env PYTHONPATH=src $(PY) benchmarks/bench_stream.py

bench-stream-quick:
	env PYTHONPATH=src $(PY) benchmarks/bench_stream.py --quick

# impact analysis: erasure closure vs per-row loop, what-if replay vs full
# re-run (>= 5x at n=100k), federated cells vs merged; merges the `impact`
# section into BENCH_query.json
bench-impact:
	env PYTHONPATH=src $(PY) benchmarks/bench_impact.py

bench-impact-quick:
	env PYTHONPATH=src $(PY) benchmarks/bench_impact.py --quick

# pod-scale roofline (512 forced host devices) + the MEASURED fused-walk
# kernels section; --quick skips the mesh lowering and merges only the
# `kernels` section into BENCH_query.json
bench-roofline:
	env PYTHONPATH=src $(PY) -m benchmarks.bench_compose_roofline

bench-roofline-quick:
	env PYTHONPATH=src $(PY) -m benchmarks.bench_compose_roofline --quick

# mirrors .github/workflows/ci.yml
ci:
	$(PY) -m compileall -q src
	$(MAKE) test
