"""Federation: catalog + boundary handles + FederatedSession.

Four pillars, mirroring the acceptance criteria of the redesign:

* **Parity** — randomized pipelines are replayed TWICE from one spec list:
  once into a single merged index (the baseline the paper assumes), once
  split at a random cut into a ``prep`` index and a ``serve`` index glued by
  a catalog link.  Every federated answer must be byte-identical to the
  seed reference on the merged index — forward, backward, batched,
  co-queries, empty masks, ``-1`` sentinels (outer joins / appends), and
  diamonds whose branches cross the boundary over TWO links.
* **Capability isolation** — a :class:`BoundaryHandle` cannot mutate the
  exporting index or resolve non-ancestor datasets (typed
  :class:`CapabilityError`), and a :class:`ServeEngine` attached via
  ``upstream=`` holds no reference to the prep index object.
* **Explain / stats** — ``FederatedSession.explain`` surfaces per-segment
  strategy/cost (never just a stitched total) and ``stats`` aggregates
  per-index counters under the registered index name.
* **Back-compat** — ``ServeEngine(prov_index=...)`` warns once per process
  and answers identical lineage.
"""
import warnings

import numpy as np
import pytest

import pipegen
import test_query_parity as tqp
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import (
    BoundaryHandle,
    CapabilityError,
    FederatedSession,
    FederationError,
    ProvCatalog,
    QueryPlan,
    prov,
)
from repro.provenance.catalog import Link, qualify, split_ref
from repro.serve import engine as serve_engine
from repro.serve.engine import GenerationResult, ServeEngine


# ===========================================================================
# Spec-replay pipelines — shared generators in tests/pipegen.py
# ===========================================================================
_random_specs = pipegen.random_specs
_apply = pipegen.apply_spec
_build_merged = pipegen.build_merged
_build_federated = pipegen.build_federated

SEEDS = list(range(8))


@pytest.mark.parametrize("seed", SEEDS)
def test_federated_record_parity_vs_merged(seed):
    base, specs = _random_specs(seed)
    merged, ids = _build_merged(base, specs)
    rng = np.random.default_rng(seed + 1000)
    cut = int(rng.integers(1, len(specs)))
    catalog, refs, sink_ref = _build_federated(base, specs, cut)
    src_ref = refs[0]
    n_src = merged.datasets["src"].n_rows
    n_sink = merged.datasets[ids[-1]].n_rows

    # forward src -> every dataset (both sides of the boundary)
    for rows in tqp._row_probes(rng, n_src):
        for j, ref in enumerate(refs):
            want = tqp.ref_q1(merged, "src", rows, ids[j])
            got = prov(catalog).source(src_ref).rows(rows).forward().to(ref).run()
            np.testing.assert_array_equal(got, want)
    # backward sink -> every dataset
    for rows in tqp._row_probes(rng, n_sink):
        for j, ref in enumerate(refs):
            want = tqp.ref_q2(merged, ids[-1], rows, ids[j])
            got = (prov(catalog).source(sink_ref).rows(rows)
                   .backward().to(ref).run())
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_federated_batch_and_co_queries_parity(seed):
    base, specs = _random_specs(seed)
    merged, ids = _build_merged(base, specs)
    rng = np.random.default_rng(seed + 2000)
    cut = int(rng.integers(1, len(specs)))
    catalog, refs, sink_ref = _build_federated(base, specs, cut)
    src_ref = refs[0]
    n_src = merged.datasets["src"].n_rows
    n_sink = merged.datasets[ids[-1]].n_rows

    # batched backward with empty probes interleaved
    probes = [[], [0], sorted(set(rng.integers(0, n_sink, 4).tolist())), []]
    got = (prov(catalog).source(sink_ref).rows_batch(probes)
           .backward().to(src_ref).run())
    for p, g in zip(probes, got):
        np.testing.assert_array_equal(g, tqp.ref_q2(merged, ids[-1], p, "src"))

    # co_dependency across the boundary: probe a serve-side dataset, anchor
    # at prep/src, answer at the sink
    mid_j = max(cut, 1)
    mid_ref, mid_id = refs[mid_j], ids[mid_j]
    n_mid = merged.datasets[mid_id].n_rows
    rows = [int(rng.integers(0, n_mid))]
    want = tqp.ref_q11(merged, mid_id, rows, "src", ids[-1])
    got = (prov(catalog).source(mid_ref).rows(rows)
           .co_dependency(src_ref, sink_ref).run())
    np.testing.assert_array_equal(got, want)

    # co_contributory with explicit via at the sink
    d2_j = 1
    want = tqp.ref_q10(merged, "src", [0], ids[d2_j], via=ids[-1])
    got = (prov(catalog).source(src_ref).rows([0])
           .co_contributory(refs[d2_j], via=sink_ref).run())
    np.testing.assert_array_equal(got, want)


def test_empty_batch_and_no_path():
    base, specs = _random_specs(3)
    catalog, refs, sink_ref = _build_federated(base, specs, 1)
    got = (prov(catalog).source(sink_ref).rows_batch([])
           .backward().to(refs[0]).run())
    assert got == []
    # no dataflow path (src -> src never crosses back): answers empty, not
    # an error — matching the walking engine
    got = (prov(catalog).source(sink_ref).rows([0]).forward()
           .to(refs[0]).run())
    assert got.size == 0


# ===========================================================================
# Diamond ACROSS the boundary: two links carry two branches of one source
# ===========================================================================
_cross_boundary_diamond = pipegen.cross_boundary_diamond


@pytest.mark.parametrize("seed", range(4))
def test_cross_boundary_diamond_parity(seed):
    """BOTH links must contribute: either branch alone under-counts, exactly
    like the single-index diamond the multi-path hop-cache composes."""
    merged, sink_id, catalog, sink_ref = _cross_boundary_diamond(seed)
    n_src = merged.datasets["src"].n_rows
    n_sink = merged.datasets[sink_id].n_rows
    for rows in ([], [0], [2, 7], list(range(n_src))):
        want = tqp.ref_q1(merged, "src", rows, sink_id)
        got = (prov(catalog).source("prep/src").rows(rows)
               .forward().to(sink_ref).run())
        np.testing.assert_array_equal(got, want)
    probes = [[i] for i in range(n_sink)]
    got = (prov(catalog).source(sink_ref).rows_batch(probes)
           .backward().to("prep/src").run())
    for b, g in enumerate(got):
        np.testing.assert_array_equal(g, tqp.ref_q2(merged, sink_id, [b], "src"))
    sess = catalog.session()
    assert sess.counters["links_crossed"] >= 2


# ===========================================================================
# Alignment stitching (the ServeEngine request_ids path, in isolation)
# ===========================================================================
def test_alignment_stitch_duplicates_and_unlinked():
    prep = ProvenanceIndex("prep")
    t = track(Table.from_columns({"k": np.arange(6, dtype=np.float32),
                                  "x": np.ones(6, np.float32)}), prep, "raw")
    t.mark_sink()
    serve = ProvenanceIndex("serve")
    s = track(Table.from_columns({"k": np.zeros(4, np.float32),
                                  "x": np.ones(4, np.float32)}), serve, "req")
    out = s.value_transform("x", "scale", factor=3.0).mark_sink()
    catalog = ProvCatalog("aligned")
    catalog.register("prep", prep).register("serve", serve)
    # req row j came from raw row align[j]; row 3 has no upstream origin
    catalog.link("prep/raw", "serve/req", alignment=[5, 2, 2, -1])

    ref = qualify("serve", out.dataset_id)
    # forward: raw row 2 feeds req rows {1, 2}
    got = prov(catalog).source("prep/raw").rows([2]).forward().to(ref).run()
    np.testing.assert_array_equal(got, [1, 2])
    # backward: duplicates OR-accumulate, unlinked rows vanish
    got = (prov(catalog).source(ref).rows_batch([[0], [1], [2], [3], [1, 2]])
           .backward().to("prep/raw").run())
    assert [g.tolist() for g in got] == [[5], [2], [2], [], [2]]


def test_link_validation_errors():
    prep = ProvenanceIndex("prep")
    t = track(Table.from_columns({"x": np.ones(4, np.float32)}), prep, "raw")
    derived = t.value_transform("x", "scale", factor=2.0)
    serve = ProvenanceIndex("serve")
    track(Table.from_columns({"x": np.ones(3, np.float32)}), serve, "req")
    catalog = ProvCatalog()
    catalog.register("prep", prep).register("serve", serve)
    with pytest.raises(FederationError, match="different members"):
        catalog.link("prep/raw", f"prep/{derived.dataset_id}")
    with pytest.raises(FederationError, match="equal row counts"):
        catalog.link("prep/raw", "serve/req")          # 4 vs 3, no alignment
    with pytest.raises(FederationError, match="shape"):
        catalog.link("prep/raw", "serve/req", alignment=[0, 1])
    with pytest.raises(FederationError, match=r"\[-1"):
        catalog.link("prep/raw", "serve/req", alignment=[0, 1, 9])
    with pytest.raises(FederationError, match="producer"):
        # can't land boundary rows on a dataset an op already produces
        serve2 = ProvenanceIndex("serve2")
        s2 = track(Table.from_columns({"x": np.ones(4, np.float32)}),
                   serve2, "req2")
        d2 = s2.value_transform("x", "scale", factor=2.0)
        catalog.register("serve2", serve2)
        catalog.link("prep/raw", f"serve2/{d2.dataset_id}")
    with pytest.raises(FederationError, match="qualified"):
        catalog.link("raw", "serve/req")
    with pytest.raises(FederationError, match="unknown index"):
        catalog.link("nope/raw", "serve/req")
    with pytest.raises(FederationError, match="already registered"):
        catalog.register("prep", prep)
    with pytest.raises(FederationError, match="member name"):
        catalog.register("a/b", prep)


def test_cyclic_link_graph_raises():
    a, b = ProvenanceIndex("a"), ProvenanceIndex("b")
    ta = track(Table.from_columns({"x": np.ones(3, np.float32)}), a, "sa")
    tb = track(Table.from_columns({"x": np.ones(3, np.float32)}), b, "sb")
    a2 = ta.value_transform("x", "scale", factor=2.0)
    b2 = tb.value_transform("x", "scale", factor=2.0)
    catalog = ProvCatalog()
    catalog.register("a", a).register("b", b)
    catalog.link(f"a/{a2.dataset_id}", "b/sb")
    catalog.link(f"b/{b2.dataset_id}", "a/sa")
    with pytest.raises(FederationError, match="cycle"):
        prov(catalog).source("a/sa").rows([0]).forward().to(f"b/{b2.dataset_id}").run()


# ===========================================================================
# Cross-index cells / how parity (the PR 9 lift), via-less Q10 stays loud
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_federated_cells_parity_vs_merged(seed):
    """Cross-boundary attribute lineage: byte-identical to the merged
    single-index walk — forward and backward, every dataset, empty probes."""
    base, specs = _random_specs(seed)
    merged, ids = _build_merged(base, specs)
    rng = np.random.default_rng(seed + 3000)
    cut = int(rng.integers(1, len(specs)))
    catalog, refs, sink_ref = _build_federated(base, specs, cut)
    src_ref = refs[0]
    n_src = merged.datasets["src"].n_rows
    c_src = merged.datasets["src"].n_cols
    n_sink = merged.datasets[ids[-1]].n_rows
    c_sink = merged.datasets[ids[-1]].n_cols

    for rows in tqp._row_probes(rng, n_src):
        attrs = sorted(set(rng.integers(0, c_src, 2).tolist()))
        want = tqp.ref_q3(merged, "src", rows, attrs, ids[-1])
        got = (prov(catalog).source(src_ref).rows(rows).attrs(attrs)
               .forward().to(sink_ref).run())
        np.testing.assert_array_equal(got, want)
    rows = [int(rng.integers(0, n_sink))]
    attrs = list(range(c_sink))
    for j, ref in enumerate(refs):
        want = tqp.ref_q4(merged, ids[-1], rows, attrs, ids[j])
        got = (prov(catalog).source(sink_ref).rows(rows).attrs(attrs)
               .backward().to(ref).run())
        np.testing.assert_array_equal(got, want)


def _strip_links(hops):
    return [(h.op_name, h.category, h.n_records) for h in hops
            if h.category != "link"]


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_federated_how_parity_vs_merged(seed):
    """Record+how and cells+how across the boundary: answers byte-identical
    to merged; hop traces match the merged walk op-for-op (op name,
    category, contribution count) once the synthetic boundary-crossing
    ``category="link"`` hop is dropped."""
    base, specs = _random_specs(seed)
    merged, ids = _build_merged(base, specs)
    rng = np.random.default_rng(seed + 4000)
    cut = int(rng.integers(1, len(specs)))
    catalog, refs, sink_ref = _build_federated(base, specs, cut)
    n_src = merged.datasets["src"].n_rows
    n_sink = merged.datasets[ids[-1]].n_rows

    rows = sorted(set(rng.integers(0, n_sink, 3).tolist()))
    want_recs, want_hops = (prov(merged).source(ids[-1]).rows(rows)
                            .backward().to("src").how().run())
    got_recs, got_hops = (prov(catalog).source(sink_ref).rows(rows)
                          .backward().to(refs[0]).how().run())
    np.testing.assert_array_equal(got_recs, want_recs)
    assert _strip_links(got_hops) == _strip_links(want_hops)
    assert sum(1 for h in got_hops if h.category == "link") == 1

    rows = [int(rng.integers(0, n_src))]
    want_recs, want_hops = (prov(merged).source("src").rows(rows)
                            .forward().to(ids[-1]).how().run())
    got_recs, got_hops = (prov(catalog).source(refs[0]).rows(rows)
                          .forward().to(sink_ref).how().run())
    np.testing.assert_array_equal(got_recs, want_recs)
    assert _strip_links(got_hops) == _strip_links(want_hops)

    # cells + how: batched, empty probes interleaved
    c_src = merged.datasets["src"].n_cols
    probes = [[], [0], sorted(set(rng.integers(0, n_src, 3).tolist()))]
    want = (prov(merged).source("src").rows_batch(probes)
            .attrs(list(range(c_src))).forward().to(ids[-1]).how().run())
    got = (prov(catalog).source(refs[0]).rows_batch(probes)
           .attrs(list(range(c_src))).forward().to(sink_ref).how().run())
    for (wc, wh), (gc, gh) in zip(want, got):
        np.testing.assert_array_equal(gc, wc)
        assert _strip_links(gh) == _strip_links(wh)


def test_federated_cells_diamond_both_links_contribute():
    merged, sink_id, catalog, sink_ref = _cross_boundary_diamond(1)
    c_src = merged.datasets["src"].n_cols
    for rows in ([0], [2, 5]):
        want = tqp.ref_q3(merged, "src", rows, [0, 1], sink_id)
        got = (prov(catalog).source("prep/src").rows(rows).attrs([0, 1])
               .forward().to(sink_ref).run())
        np.testing.assert_array_equal(got, want)
    assert c_src == 2


def test_cross_index_co_contributory_needs_via():
    base, specs = _random_specs(5)
    catalog, refs, sink_ref = _build_federated(base, specs, 1)
    with pytest.raises(FederationError, match="via"):
        (prov(catalog).source(refs[0]).rows([0])
         .co_contributory(sink_ref).run())


def test_single_member_plans_delegate_with_full_kind_support():
    base, specs = _random_specs(6)
    merged, ids = _build_merged(base, specs)
    catalog, refs, sink_ref = _build_federated(base, specs, len(specs))
    # the whole chain lives in prep: every kind works through the catalog
    sink_prep = refs[-2] if refs[-1].startswith("serve") else refs[-1]
    # build the same spelling against the merged baseline
    j = refs.index(sink_prep)
    want = tqp.ref_q3(merged, "src", [0], [1], ids[j])
    got = (prov(catalog).source(refs[0]).rows([0]).attrs([1])
           .forward().to(sink_prep).run())
    np.testing.assert_array_equal(got, want)
    recs, hops = (prov(catalog).source(refs[0]).rows([0])
                  .forward().to(sink_prep).how().run())
    np.testing.assert_array_equal(recs, tqp.ref_q1(merged, "src", [0], ids[j]))
    assert all(h.op_id >= 0 for h in hops)
    meta = prov(catalog).source(sink_prep).transformations().run()
    assert len(meta) == len(merged.upstream_ops(ids[j]))
    sess = catalog.session()
    assert sess.counters["single_index"] >= 3
    assert sess.counters["federated"] == 0


# ===========================================================================
# run_many fusion across the boundary
# ===========================================================================
def test_run_many_fuses_federated_plans():
    base, specs = _random_specs(7)
    merged, ids = _build_merged(base, specs)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    n_sink = merged.datasets[ids[-1]].n_rows
    sess = FederatedSession(catalog)
    plans = [prov(catalog).source(sink_ref).rows([i % n_sink])
             .backward().to(refs[0]) for i in range(12)]
    out = sess.run_many(plans)
    assert len(out) == 12
    for i, g in enumerate(out):
        np.testing.assert_array_equal(
            g, tqp.ref_q2(merged, ids[-1], [i % n_sink], "src"))
    # ONE fused propagation: a single federated execution, the 12 plans
    # packed into one (B=12) pass per member segment
    assert sess.counters["fused_groups"] == 1
    assert sess.counters["fused_plans"] == 12
    assert sess.counters["federated"] == 1


# ===========================================================================
# Cross-boundary composed relations (the federation's own stitched cache)
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_cross_relation_cache_parity(seed):
    """With the demand threshold at 0 every cross route composes its
    stitched relation immediately — answers must stay byte-identical to
    the merged reference, and repeated probes hit the cache."""
    pytest.importorskip("scipy")
    base, specs = _random_specs(seed)
    merged, ids = _build_merged(base, specs)
    rng = np.random.default_rng(seed + 3000)
    cut = int(rng.integers(1, len(specs)))
    catalog, refs, sink_ref = _build_federated(base, specs, cut)
    sess = FederatedSession(catalog, cross_min_demand=0)
    n_src = merged.datasets["src"].n_rows
    n_sink = merged.datasets[ids[-1]].n_rows

    probes = [[], [0], sorted(set(rng.integers(0, n_sink, 4).tolist()))]
    got = sess.run(prov(catalog).source(sink_ref).rows_batch(probes)
                   .backward().to(refs[0]).plan())
    for p, g in zip(probes, got):
        np.testing.assert_array_equal(g, tqp.ref_q2(merged, ids[-1], p, "src"))
    assert sess.counters["cross_composes"] == 1
    assert sess.counters["cross_probes"] == 1
    # forward route composes its own relation; the backward one is reused
    fprobes = [[i] for i in range(min(6, n_src))]
    got = sess.run(prov(catalog).source(refs[0]).rows_batch(fprobes)
                   .forward().to(sink_ref).plan())
    for p, g in zip(fprobes, got):
        np.testing.assert_array_equal(g, tqp.ref_q1(merged, "src", p, ids[-1]))
    sess.run(prov(catalog).source(sink_ref).rows([0]).backward()
             .to(refs[0]).plan())
    assert sess.counters["cross_composes"] == 2      # one per route
    assert sess.counters["cross_probes"] == 3
    assert sess.counters["segments"] == 0            # never fell back


def test_cross_relation_cache_diamond_and_alignment():
    pytest.importorskip("scipy")
    merged, sink_id, catalog, sink_ref = _cross_boundary_diamond(1)
    sess = FederatedSession(catalog, cross_min_demand=0)
    n_src = merged.datasets["src"].n_rows
    got = sess.run(prov(catalog).source("prep/src")
                   .rows_batch([[i] for i in range(n_src)])
                   .forward().to(sink_ref).plan())
    for b, g in enumerate(got):
        np.testing.assert_array_equal(g, tqp.ref_q1(merged, "src", [b], sink_id))
    assert sess.counters["cross_composes"] == 1      # BOTH links in one relation

    # alignment matrix parity (duplicates + unlinked rows), both directions
    prep = ProvenanceIndex("prep")
    track(Table.from_columns({"x": np.ones(6, np.float32)}), prep, "raw")
    serve = ProvenanceIndex("serve")
    s = track(Table.from_columns({"x": np.ones(4, np.float32)}), serve, "req")
    out = s.value_transform("x", "scale", factor=3.0).mark_sink()
    cat = ProvCatalog()
    cat.register("prep", prep).register("serve", serve)
    cat.link("prep/raw", "serve/req", alignment=[5, 2, 2, -1])
    fsess = FederatedSession(cat, cross_min_demand=0)
    ref = qualify("serve", out.dataset_id)
    got = fsess.run(prov(cat).source(ref).rows_batch([[0], [1], [2], [3], [1, 2]])
                    .backward().to("prep/raw").plan())
    assert [g.tolist() for g in got] == [[5], [2], [2], [], [2]]
    got = fsess.run(prov(cat).source("prep/raw").rows([2]).forward()
                    .to(ref).plan())
    np.testing.assert_array_equal(got, [1, 2])
    assert fsess.counters["cross_composes"] == 2


def test_unroutable_cross_compose_memoized_as_failed():
    """A route with a member-level link path but NO dataset-level dataflow
    path must not re-pay the compose attempt on every probe."""
    pytest.importorskip("scipy")
    prep = ProvenanceIndex("prep")
    t = track(Table.from_columns({"x": np.ones(5, np.float32)}), prep, "raw")
    a = t.value_transform("x", "scale", factor=2.0)
    track(Table.from_columns({"x": np.ones(3, np.float32)}), prep, "orphan")
    serve = ProvenanceIndex("serve")
    s = track(a.table, serve, "ingest")
    out = s.value_transform("x", "scale", factor=3.0).mark_sink()
    catalog = ProvCatalog()
    catalog.register("prep", prep).register("serve", serve)
    catalog.link(qualify("prep", a.dataset_id), "serve/ingest")
    sess = FederatedSession(catalog, cross_min_demand=0)
    plan = (prov(catalog).source("prep/orphan").rows([0]).forward()
            .to(qualify("serve", out.dataset_id)).plan())
    got = sess.run(plan)
    assert got.size == 0
    assert sess.counters["cross_composes"] == 0      # nothing to stitch
    assert len(sess._cross_failed) == 1
    segments_after_first = sess.counters["segments"]
    got = sess.run(plan)                             # memoized: no re-attempt
    assert got.size == 0
    assert sess.counters["cross_composes"] == 0
    assert sess.counters["segments"] == segments_after_first
    # a routable query on the same catalog still composes + caches
    ok = sess.run(prov(catalog).source(qualify("serve", out.dataset_id))
                  .rows([0]).backward().to("prep/raw").plan())
    np.testing.assert_array_equal(ok, [0])
    assert sess.counters["cross_composes"] == 1


def test_cross_relation_survives_unrelated_links():
    """The serving pattern: one new link per recorded generation, landing
    on a brand-new requests@N dataset.  No cached route can reach it, so
    hot stitched relations must SURVIVE — wholesale invalidation would
    defeat the fast path in exactly the scenario it exists for."""
    pytest.importorskip("scipy")
    prep, exported, _, _ = _capability_fixture()
    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:cachetest",
                            upstream=prep.export(exported.dataset_id))
    sess = engine.federation
    sess.cross_min_demand = 0
    r1 = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                          request_ids=np.array([0, 1, 2]))
    engine._record_generation(r1, prompt_len=2, n_new=2, request_source=None)
    got1 = engine.response_lineage(r1, rows=[1], upstream="raw")
    assert sess.counters["cross_composes"] == 1
    # a second generation appends a new link; the cached route keeps
    r2 = GenerationResult(tokens=np.zeros((2, 2), np.int32),
                          request_ids=np.array([3, 0]))
    engine._record_generation(r2, prompt_len=2, n_new=2, request_source=None)
    again = engine.response_lineage(r1, rows=[1], upstream="raw")
    np.testing.assert_array_equal(again, got1)
    assert sess.counters["cross_composes"] == 1      # NOT recomposed
    assert sess.counters["cross_probes"] >= 2


def test_upstream_engine_requires_explicit_request_ids():
    """With an upstream attach the boundary link is a lineage assertion:
    the arange() default must never silently fabricate it, and a bad batch
    must fail BEFORE mutating the serving index."""
    prep, exported, _, _ = _capability_fixture()
    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:reqids",
                            upstream=prep.export(exported.dataset_id))
    r = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                         request_ids=np.arange(3))
    with pytest.raises(ValueError, match="explicit request_ids"):
        engine._record_generation(r, prompt_len=2, n_new=2,
                                  request_source=None,
                                  request_ids_given=False)
    # out-of-range rows fail before add_source: no orphan requests@N
    bad = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                          request_ids=np.array([0, 1, 99]))
    with pytest.raises(ValueError, match="boundary dataset"):
        engine._record_generation(bad, prompt_len=2, n_new=2,
                                  request_source=None)
    assert not any(d.startswith("requests@") for d in engine.prov.datasets)
    assert not engine.catalog.links
    # -1 = request with no upstream origin: records fine, traces to nothing
    ok = GenerationResult(tokens=np.zeros((2, 2), np.int32),
                          request_ids=np.array([2, -1]))
    engine._record_generation(ok, prompt_len=2, n_new=2, request_source=None)
    got = engine.response_lineage_batch(ok, [[0], [1]], upstream="raw")
    assert [g.tolist() for g in got] == [[3], []]


def test_upstream_tuple_attach_validates_dataset():
    prep, exported, _, _ = _capability_fixture()
    catalog = ProvCatalog()
    catalog.register("prep", prep)
    engine = object.__new__(ServeEngine)
    with pytest.raises(KeyError):
        engine._init_provenance(
            "serve:typo", upstream=(catalog, "prep/definitely-missing"))
    engine._init_provenance(
        "serve:ok", upstream=(catalog, qualify("prep", exported.dataset_id)))
    assert engine.catalog is catalog and "serve" in catalog.members


def test_cross_relation_invalidates_on_new_link():
    """A stitched relation must not survive a link-set change: adding the
    second branch link changes the answer, exactly to the merged one."""
    pytest.importorskip("scipy")
    merged, sink_id, catalog, sink_ref = _cross_boundary_diamond(2)
    # rebuild the same split world but register only branch_a's link first
    prep_member = catalog.members["prep"]
    serve_member = catalog.members["serve"]
    link_a, link_b = catalog.links
    partial = ProvCatalog("partial")
    partial.register("prep", prep_member._index)
    partial.register("serve", serve_member._index)
    partial.link(link_a.up, link_a.down)
    sess = FederatedSession(partial, cross_min_demand=0)
    n_src = merged.datasets["src"].n_rows
    all_rows = list(range(n_src))
    plan = (prov(partial).source("prep/src").rows(all_rows)
            .forward().to(sink_ref).plan())
    one_branch = sess.run(plan)
    both = tqp.ref_q1(merged, "src", all_rows, sink_id)
    assert sess.counters["cross_composes"] == 1
    # now declare the second boundary: the cached relation is stale
    partial.link(link_b.up, link_b.down)
    got = sess.run(plan)
    np.testing.assert_array_equal(got, both)
    assert sess.counters["cross_composes"] == 2      # recomposed after the link
    assert len(one_branch) <= len(both)


# ===========================================================================
# Capability isolation
# ===========================================================================
def _capability_fixture():
    prep = ProvenanceIndex("prep")
    s = track(Table.from_columns({"k": np.arange(8, dtype=np.float32),
                                  "x": np.ones(8, np.float32)}), prep, "raw")
    exported = s.filter_rows(np.array([1, 1, 0, 1, 1, 0, 1, 1], bool))
    sibling = s.value_transform("x", "scale", factor=2.0)  # NOT an ancestor
    downstream = exported.value_transform("x", "scale", factor=3.0)
    return prep, exported, sibling, downstream


def test_boundary_handle_denies_mutation_and_non_ancestors():
    prep, exported, sibling, downstream = _capability_fixture()
    handle = prep.export(exported.dataset_id)
    assert isinstance(handle, BoundaryHandle)
    # mutation verbs raise the typed error
    with pytest.raises(CapabilityError, match="read-only"):
        handle.record([], "x", None, None)
    with pytest.raises(CapabilityError, match="read-only"):
        handle.add_source("y", None)
    # ancestors resolve; the sibling branch and the downstream consumer don't
    assert exported.dataset_id in handle.datasets
    assert "raw" in handle.datasets
    assert sibling.dataset_id not in handle.datasets
    with pytest.raises(CapabilityError, match="not an ancestor"):
        handle.datasets[sibling.dataset_id]
    with pytest.raises(CapabilityError, match="not an ancestor"):
        handle.datasets[downstream.dataset_id]
    with pytest.raises(KeyError):
        handle.datasets["never-existed"]
    assert set(handle.datasets) == {"raw", exported.dataset_id}
    # plans touching non-ancestors are rejected before execution
    plan = QueryPlan(kind="record", source="raw",
                     target=sibling.dataset_id, direction="fwd",
                     rows=np.ones((1, 8), bool))
    with pytest.raises(CapabilityError, match="not an ancestor"):
        handle.run(plan)
    with pytest.raises(CapabilityError):
        handle.path_exists("raw", downstream.dataset_id)
    # ancestor-only plans answer through the exporting index's session
    ok = QueryPlan(kind="record", source="raw", target=exported.dataset_id,
                   direction="fwd", rows=np.ones((1, 8), bool))
    res = handle.run(ok)
    np.testing.assert_array_equal(
        res, tqp.ref_q1(prep, "raw", list(range(8)), exported.dataset_id))
    # attenuation: re-export narrows, never widens
    narrower = handle.export("raw")
    assert set(narrower.datasets) == {"raw"}
    with pytest.raises(CapabilityError):
        handle.export(sibling.dataset_id)


def test_catalog_resolution_respects_capabilities():
    prep, exported, sibling, _ = _capability_fixture()
    handle = prep.export(exported.dataset_id)
    catalog = ProvCatalog()
    catalog.register("up", handle)
    assert qualify("up", "raw") in catalog.datasets
    assert qualify("up", sibling.dataset_id) not in catalog.datasets
    with pytest.raises(CapabilityError):
        catalog.datasets[qualify("up", sibling.dataset_id)]
    # the builder refuses the ref before a plan even compiles
    with pytest.raises(KeyError):
        prov(catalog).source(qualify("up", sibling.dataset_id))


def test_serve_engine_upstream_holds_no_prep_index():
    prep, exported, sibling, _ = _capability_fixture()
    handle = prep.export(exported.dataset_id)
    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:captest", upstream=handle)
    assert all(v is not prep for v in vars(engine).values())
    assert engine.catalog.member_of(prep) is None
    # the registered upstream member is the read-only capability
    up = engine.catalog.members["prep"]
    assert up is handle
    with pytest.raises(CapabilityError):
        up.record([], "x", None, None)
    # lineage still reaches prep/raw through the federation
    r = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                         request_ids=np.array([0, 2, 2]))
    engine._record_generation(r, prompt_len=2, n_new=2, request_source=None)
    got = engine.response_lineage(r, rows=[1], upstream="raw")
    # request row 1 aligned to exported row 2, which is raw row 3
    np.testing.assert_array_equal(got, [3])
    got = engine.response_lineage_batch(r, [[0], [1], [2]], upstream="prep/raw")
    assert [g.tolist() for g in got] == [[0], [3], [3]]


def test_serve_engine_prov_index_shim_warns_once_and_matches():
    prep = ProvenanceIndex("prep-shim")
    s = track(Table.from_columns({"k": np.arange(6, dtype=np.float32),
                                  "x": np.ones(6, np.float32)}), prep, "raw")
    clean = s.filter_rows(np.array([1, 0, 1, 1, 0, 1], bool))
    clean.mark_sink()
    serve_engine._DEPRECATION_WARNED.discard("prov_index")
    e = object.__new__(ServeEngine)
    with pytest.warns(DeprecationWarning, match="prov_index"):
        e._init_provenance("serve:shim", prov_index=prep)
    # single-entry catalog wrap: the engine records INTO the passed index
    assert e.prov is prep
    assert list(e.catalog.members) == ["serve"]
    e2 = object.__new__(ServeEngine)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        e2._init_provenance("serve:shim2", prov_index=prep)   # silent now
    # identical lineage to the legacy merged-index behavior
    r = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                         request_ids=np.array([0, 2, 2]))
    e._record_generation(r, prompt_len=2, n_new=2,
                         request_source=clean.dataset_id)
    got = e.response_lineage(r, rows=[1], upstream="raw")
    np.testing.assert_array_equal(got, tqp.ref_q2(prep, r.response_dataset,
                                                  [1], "raw"))
    with pytest.raises(ValueError, match="not both"):
        e3 = object.__new__(ServeEngine)
        e3._init_provenance("serve:both", upstream=prep.export("raw"),
                            prov_index=prep)


# ===========================================================================
# explain / stats: per-segment visibility, per-index aggregation
# ===========================================================================
def test_explain_surfaces_per_segment_strategy_and_cost():
    base, specs = _random_specs(9)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    sess = catalog.session()
    plan = (prov(catalog).source(sink_ref).rows([0])
            .backward().to(refs[0]).plan())
    ex = sess.explain(plan)
    assert ex["federated"] is True
    assert ex["strategy"] == "federated"
    assert len(ex["segments"]) >= 2
    seen_indexes = set()
    for seg in ex["segments"]:
        assert seg["strategy"] in ("walk", "hopcache")
        assert "segment" in seg and "->" in seg["segment"]
        seen_indexes.add(seg["index"])
    assert seen_indexes == {"prep", "serve"}        # one+ segment PER side
    assert len(ex["links"]) == 1
    # single-member plans surface the inner planner verdict + owning index
    ex1 = sess.explain(prov(catalog).source(refs[0]).rows([0]).forward()
                       .to(refs[1]).plan())
    assert ex1["federated"] is False
    assert ex1["index"] == split_ref(refs[1])[0]
    assert ex1["strategy"] in ("walk", "hopcache")


def test_stats_aggregate_per_index_under_registered_name():
    base, specs = _random_specs(10)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    sess = catalog.session()
    (prov(catalog).source(sink_ref).rows([0]).backward().to(refs[0])
     .run(sess))
    st = sess.stats()
    assert set(st) == {"federation", "indexes"}
    assert set(st["indexes"]) == {"prep", "serve"}
    for name in ("prep", "serve"):
        inner = st["indexes"][name]
        assert inner["index"] == name                # registered == owning
        assert inner["planner"]["plans"] >= 1        # each side executed
        assert "hits" in inner["hopcache"]
    fed = st["federation"]
    assert fed["plans"] == 1 and fed["federated"] == 1
    assert fed["segments"] >= 2 and fed["links_crossed"] == 1
    # catalog.stats() is the same aggregation
    assert ProvCatalog.stats(catalog)["federation"]["plans"] == 1


def test_shared_session_on_catalog():
    base, specs = _random_specs(11)
    catalog, refs, sink_ref = _build_federated(base, specs, 1)
    s1 = catalog.session()
    assert catalog.session() is s1
    with pytest.raises(ValueError):
        catalog.session(nope=1)


# ===========================================================================
# IR plumbing
# ===========================================================================
def test_plan_refs_enumerate_footprint():
    p = QueryPlan(kind="record", source="a/x", target="b/y", direction="fwd",
                  rows=np.ones((1, 3), bool))
    assert p.refs() == ("a/x", "b/y")
    p = QueryPlan(kind="co_dependency", source="m", target="d3", anchor="d1",
                  rows=np.ones((1, 3), bool))
    assert set(p.refs()) == {"m", "d3", "d1"}


def test_split_ref_and_link_repr():
    assert split_ref("prep/a#1") == ("prep", "a#1")
    assert split_ref("prep/a/b") == ("prep", "a/b")
    with pytest.raises(FederationError):
        split_ref("unqualified")
    with pytest.raises(FederationError):
        split_ref("/ds")
    link = Link(up="a/x", down="b/y", alignment=None)
    up = np.zeros((2, 4), bool)
    up[0, 1] = True
    np.testing.assert_array_equal(link.stitch_down(up, 4), up)
    np.testing.assert_array_equal(link.stitch_up(up, 4), up)


# ===========================================================================
# Catalog-owned cross-relation store + the cost-model gate
# ===========================================================================
def test_cross_store_shared_across_sessions():
    """The stitched-relation store lives on the CATALOG: a second session
    over the same catalog reuses hot relations the first one composed —
    the serving-tier pattern, where short-lived sessions front one
    long-lived catalog."""
    pytest.importorskip("scipy")
    base, specs = _random_specs(21)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    a = FederatedSession(catalog, cross_min_demand=0)
    plan = (prov(catalog).source(sink_ref).rows([0])
            .backward().to(refs[0]).plan())
    got = a.run(plan)
    assert a.counters["cross_composes"] == 1
    b = FederatedSession(catalog, cross_min_demand=0)
    assert b._store is a._store is catalog._cross_store
    assert len(b._cross) == 1           # visible before b ever ran a plan
    np.testing.assert_array_equal(np.asarray(b.run(plan)), np.asarray(got))
    assert b.counters["cross_composes"] == 0         # reused, not recomposed
    assert b.counters["cross_probes"] == 1


def test_cost_gate_budget_zero_never_stitches():
    """Default gate (``cross_min_demand=None``): a stitched relation that
    cannot be retained under the byte budget never amortizes, so the gate
    keeps segment execution forever — and the segment answers must equal
    the stitched ones."""
    pytest.importorskip("scipy")
    base, specs = _random_specs(22)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    sess = FederatedSession(catalog, cross_budget_bytes=0)
    plan = (prov(catalog).source(sink_ref).rows([0])
            .backward().to(refs[0]).plan())
    got = sess.run(plan)
    for _ in range(40):                  # demand far past any fixed floor
        np.testing.assert_array_equal(np.asarray(sess.run(plan)),
                                      np.asarray(got))
    assert sess.counters["cross_composes"] == 0
    assert sess.counters["segments"] > 0
    # demand is still tracked (the gate re-evaluates as budget/stats move)
    assert any(v > 40 for v in sess._route_demand.values())
    # identical world, permissive budget: the stitched answer matches
    base2, specs2 = _random_specs(22)
    catalog2, _, sink_ref2 = _build_federated(base2, specs2, 2)
    stitch = FederatedSession(catalog2, cross_min_demand=0)
    plan2 = (prov(catalog2).source(sink_ref2).rows([0])
             .backward().to(refs[0]).plan())
    np.testing.assert_array_equal(np.asarray(stitch.run(plan2)),
                                  np.asarray(got))
    assert stitch.counters["cross_composes"] == 1


def test_cross_route_choose_stats_fallback_demand_floor():
    """A route with any unpriceable hop falls back to the legacy demand
    floor instead of a cost estimate."""
    from repro.core.costmodel import (
        CROSS_FALLBACK_MIN_DEMAND,
        cross_route_choose,
    )

    v = cross_route_choose([None], 0.0, 1, CROSS_FALLBACK_MIN_DEMAND - 1)
    assert (v["strategy"], v["estimated"]) == ("segments", False)
    v = cross_route_choose([None], 0.0, 1, CROSS_FALLBACK_MIN_DEMAND)
    assert (v["strategy"], v["estimated"]) == ("stitched", False)


def test_member_relation_stats_price_the_route():
    """The gate's inputs: every registered member (index or handle) prices
    a composed relation for the cost model without materializing it."""
    base, specs = _random_specs(23)
    catalog, refs, sink_ref = _build_federated(base, specs, 2)
    for name, member in catalog.members.items():
        local = [split_ref(r)[1] for r in (list(refs) + [sink_ref])
                 if split_ref(r)[0] == name]
        if len(local) < 2:
            continue
        rel, ns = member.relation_stats(local[0], local[-1])
        assert ns >= 0.0
        if rel is not None:
            assert rel.rows > 0 and rel.cols > 0 and rel.nnz >= 0
