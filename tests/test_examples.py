"""Subprocess smoke tests for the documented entry points.

API redesigns must not silently break the examples: each runs as a real
``python examples/<name>.py`` subprocess (CPU jax, tiny configs) and must
exit 0 with its landmark output present.  ``train_with_provenance.py`` is
excluded — it trains a real (if small) model and belongs to the manual
tier; the serving example covers the model-bearing path at smoke size.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = os.path.join(REPO, "examples")


def _run_example(name: str, timeout: int = 300) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, name)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, (
        f"{name} exited {proc.returncode}\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}"
    )
    return proc.stdout


def test_quickstart_example():
    out = _run_example("quickstart.py")
    assert "Q2  output record 0 derives from" in out
    assert "run_many fused" in out
    assert "session stats" in out


def test_fairness_audit_example():
    out = _run_example("fairness_audit.py")
    assert "all three methods agree" in out
    assert "impact closure matches the composed relation" in out


def test_erasure_audit_example():
    out = _run_example("erasure_audit.py")
    assert "RecomputePlan" in out
    assert "rebuild order:" in out
    assert "stale cached relations dropped:" in out
    assert "what-if: zero ingest row 0's income" in out
    assert "without rerunning the pipeline" in out


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_serve_with_lineage_example():
    out = _run_example("serve_with_lineage.py", timeout=600)
    assert "response row 2 derives from request row" in out
    assert "session stats (shared composed relations)" in out
    assert "federation stats (single-entry catalog)" in out


def test_streaming_lineage_example():
    out = _run_example("streaming_lineage.py")
    assert "after 40 appended ops: extends=" in out
    assert "spilled to disk" in out
    assert "faulted back: rehydrations=" in out
    assert "bounded: composed-relation residency stayed under" in out


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_federated_lineage_example():
    out = _run_example("federated_lineage.py", timeout=600)
    assert "capability: prep index is read-only from the serving tier" in out
    assert "traces to raw user row" in out
    assert "batch trace-to-source:" in out
    assert "federation stats:" in out
