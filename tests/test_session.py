"""The unified lazy query API: builder -> QueryPlan -> QuerySession.

Pins (a) builder compilation and its explicit single-vs-batch entry points,
(b) exact agreement between the legacy q1-q11 shims and the QuerySession
planner under BOTH physical strategies (forced walk / forced hop-cache) on
randomized pipelines, (c) the multi-path diamond DAG the old unique-chain
hop-cache could not compose, (d) run_many fusion (results + counters), and
(e) the cache-routing stats surfaced through ``QuerySession.stats()``.
"""
import warnings

import numpy as np
import pytest

import test_query_parity as tqp
from repro.core import query as Q
from repro.core.hopcache import ComposedIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import AmbiguousProbeWarning, QueryPlan, QuerySession, prov


def walk_session(idx) -> QuerySession:
    return QuerySession(idx, ComposedIndex(idx), use_hopcache=False)


def forced_hopcache_session(idx, composed=None, **kw) -> QuerySession:
    """Session pinned to the hop-cache strategy via the legacy min-batch
    knob — deliberately deprecated usage, so silence the warning here
    instead of spamming every suite run."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return QuerySession(
            idx, composed if composed is not None else ComposedIndex(idx, **kw),
            hopcache_min_batch=1)


def cache_session(idx, **kw) -> QuerySession:
    return forced_hopcache_session(idx, **kw)


# ===========================================================================
# Builder -> plan compilation
# ===========================================================================
def _tiny_index():
    idx = ProvenanceIndex("tiny")
    t = track(Table.from_columns({"k": np.arange(6, dtype=np.float32),
                                  "x": np.ones(6, dtype=np.float32)}), idx, "src")
    t = t.filter_rows(np.array([1, 0, 1, 1, 0, 1], bool))
    t.mark_sink()
    return idx, t.dataset_id


def test_builder_compiles_each_kind():
    idx, sink = _tiny_index()
    p = prov(idx).source("src").rows([0, 2]).forward().to(sink).plan()
    assert (p.kind, p.direction, p.batched, p.how) == ("record", "fwd", False, False)
    assert p.rows.shape == (1, 6) and p.rows.sum() == 2

    p = prov(idx).source(sink).rows([0]).attrs([1]).backward().to("src").how().plan()
    assert (p.kind, p.direction, p.how) == ("cells", "bwd", True)
    assert p.attrs.shape[1] == idx.datasets[sink].n_cols

    p = prov(idx).source(sink).transformations().plan()
    assert p.kind == "transformations" and p.rows is None

    p = prov(idx).source("src").rows([1]).co_contributory(sink, via=sink).plan()
    assert (p.kind, p.target, p.via) == ("co_contributory", sink, sink)

    p = prov(idx).source(sink).rows([0]).co_dependency("src", sink).plan()
    assert (p.kind, p.anchor, p.target) == ("co_dependency", "src", sink)

    # batch entry points are explicit; attr set broadcasts over the row batch
    p = (prov(idx).source("src").rows_batch([[0], [1, 2]]).attrs([0])
         .forward().to(sink).plan())
    assert p.batched and p.rows.shape == (2, 6) and p.attrs.shape[0] == 2


def test_builder_validation_errors():
    idx, sink = _tiny_index()
    with pytest.raises(ValueError, match="source"):
        prov(idx).rows([0]).forward().plan()
    with pytest.raises(ValueError, match="rows"):
        prov(idx).source("src").forward().to(sink).plan()
    with pytest.raises(ValueError, match="forward"):
        prov(idx).source("src").rows([0]).to(sink).plan()
    with pytest.raises(ValueError, match=r"\.to"):
        prov(idx).source("src").rows([0]).forward().plan()
    with pytest.raises(KeyError):
        prov(idx).source("nope")
    with pytest.raises(ValueError, match="rows_batch"):
        prov(idx).source("src").rows([0]).attrs_batch([[0]]).forward().to(sink).plan()
    # a 2-D stack is never a single probe, and vice versa
    with pytest.raises(ValueError, match="ONE probe"):
        prov(idx).source("src").rows(np.zeros((2, 6), bool)).forward().to(sink).plan()
    with pytest.raises(ValueError, match="batch"):
        prov(idx).source("src").rows_batch(np.zeros(6, bool)).forward().to(sink).plan()


def test_plan_ir_is_validated():
    with pytest.raises(ValueError, match="kind"):
        QueryPlan(kind="nope", source="a")
    with pytest.raises(ValueError, match="row probe"):
        QueryPlan(kind="record", source="a", target="b")
    with pytest.raises(ValueError, match="how"):
        QueryPlan(kind="co_dependency", source="a", target="b", anchor="c",
                  rows=np.ones((1, 2), bool), how=True)


# ===========================================================================
# Legacy shims == session planner, under BOTH strategies
# ===========================================================================
@pytest.mark.parametrize("seed", range(6))
def test_session_strategies_agree_with_shims(seed):
    idx, sink, rng = tqp._random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    walk, cache = walk_session(idx), cache_session(idx)
    for rows in tqp._row_probes(rng, n_src):
        pf = prov(idx).source("src").rows(rows).forward().to(sink).plan()
        want = tqp.ref_q1(idx, "src", rows, sink)
        np.testing.assert_array_equal(walk.run(pf), want)
        np.testing.assert_array_equal(cache.run(pf), want)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            np.testing.assert_array_equal(Q.q1_forward(idx, "src", rows, sink), want)
    for rows in tqp._row_probes(rng, n_sink):
        pb = prov(idx).source(sink).rows(rows).backward().to("src").plan()
        want = tqp.ref_q2(idx, sink, rows, "src")
        np.testing.assert_array_equal(walk.run(pb), want)
        np.testing.assert_array_equal(cache.run(pb), want)
    assert walk.counters["hopcache"] == 0
    assert cache.counters["hopcache"] > 0 and cache.counters["walk"] == 0


@pytest.mark.parametrize("seed", range(4))
def test_session_co_queries_agree_with_refs(seed):
    idx, sink, rng = tqp._random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    walk, cache = walk_session(idx), cache_session(idx)
    others = [d for d in idx.datasets if d not in ("src", sink)]
    for d2 in others[:2]:
        want = tqp.ref_q10(idx, "src", [0], d2)
        p = prov(idx).source("src").rows([0]).co_contributory(d2).plan()
        np.testing.assert_array_equal(walk.run(p), want)
        np.testing.assert_array_equal(cache.run(p), want)   # via=None -> walk
        want = tqp.ref_q10(idx, "src", [0], d2, via=sink)
        p = prov(idx).source("src").rows([0]).co_contributory(d2, via=sink).plan()
        np.testing.assert_array_equal(walk.run(p), want)
        np.testing.assert_array_equal(cache.run(p), want)
    mid = idx.ops[0].output_id
    n_mid = idx.datasets[mid].n_rows
    rows = [int(rng.integers(0, n_mid))]
    want = tqp.ref_q11(idx, mid, rows, "src", sink)
    p = prov(idx).source(mid).rows(rows).co_dependency("src", sink).plan()
    np.testing.assert_array_equal(walk.run(p), want)
    np.testing.assert_array_equal(cache.run(p), want)


# ===========================================================================
# Multi-path diamond DAG (the case the old unique-chain hop-cache missed)
# ===========================================================================
def _diamond(seed=0):
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex("diamond")
    t = Table.from_columns({
        "k": np.arange(10, dtype=np.float32),
        "x": rng.normal(size=10).astype(np.float32),
    })
    s = track(t, idx, "src")
    a = s.filter_rows(rng.random(10) < 0.8)                 # branch A
    b = s.value_transform("x", "scale", factor=2.0)          # branch B
    j = a.join(b, on="k", how="inner")                       # re-join: 2 paths
    keep = np.ones(j.table.n_rows, dtype=bool)
    keep[:: 3] = rng.random() < 0.5
    if not keep.any():
        keep[0] = True
    j = j.filter_rows(keep).mark_sink()
    return idx, j.dataset_id


@pytest.mark.parametrize("backend", ["csr", "bitplane"])
def test_multipath_diamond_hopcache_matches_walk(backend):
    if backend == "csr":
        pytest.importorskip("scipy")
    idx, sink = _diamond()
    ci = ComposedIndex(idx, backend=backend)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for rows in ([], [0], [3, 7], list(range(n_src))):
        np.testing.assert_array_equal(
            ci.q1_forward("src", rows, sink), tqp.ref_q1(idx, "src", rows, sink))
    for rows in ([], [0], list(range(n_sink))):
        np.testing.assert_array_equal(
            ci.q2_backward(sink, rows, "src"), tqp.ref_q2(idx, sink, rows, "src"))
    # the relation really is the sum over BOTH branch paths: each branch
    # alone under-counts the sink rows reached from a full-source probe
    sess = forced_hopcache_session(idx, composed=ci)
    full = sess.run(prov(idx).source("src").rows(list(range(n_src)))
                    .forward().to(sink).plan())
    assert sess.counters["hopcache"] > 0
    np.testing.assert_array_equal(full, tqp.ref_q1(idx, "src", list(range(n_src)), sink))


def test_multipath_diamond_session_strategies_agree():
    idx, sink = _diamond(seed=3)
    walk, cache = walk_session(idx), cache_session(idx)
    n_src = idx.datasets["src"].n_rows
    probes = [[i] for i in range(n_src)]
    pw = prov(idx).source("src").rows_batch(probes).forward().to(sink).plan()
    got_w, got_c = walk.run(pw), cache.run(pw)
    for b, (w, c) in enumerate(zip(got_w, got_c)):
        np.testing.assert_array_equal(w, tqp.ref_q1(idx, "src", [b], sink))
        np.testing.assert_array_equal(c, w)


# ===========================================================================
# Batched how-provenance (Q5-Q8 traces, one pass per batch)
# ===========================================================================
@pytest.mark.parametrize("seed", range(4))
def test_batched_how_matches_singles(seed):
    idx, sink, rng = tqp._random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    sess = walk_session(idx)

    probes = [[0], [], sorted(set(rng.integers(0, n_src, 3).tolist()))]
    batch = sess.run(prov(idx).source("src").rows_batch(probes)
                     .forward().to(sink).how().plan())
    assert len(batch) == len(probes)
    for p, (recs, hops) in zip(probes, batch):
        srecs, shops = sess.run(prov(idx).source("src").rows(p)
                                .forward().to(sink).how().plan())
        np.testing.assert_array_equal(recs, srecs)
        assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in hops] \
            == [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in shops]
        # and the single-probe trace equals the seed reference
        _, ref_hops = tqp.ref_forward_record_masks(idx, "src", p, collect_hops=True)
        assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in shops] \
            == ref_hops

    probes = [[0], [n_sink - 1]]
    batch = sess.run(prov(idx).source(sink).rows_batch(probes)
                     .backward().to("src").how().plan())
    for p, (recs, hops) in zip(probes, batch):
        _, ref_hops = tqp.ref_backward_record_masks(idx, sink, p, collect_hops=True)
        assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in hops] \
            == ref_hops
        np.testing.assert_array_equal(recs, tqp.ref_q2(idx, sink, p, "src"))


@pytest.mark.parametrize("seed", range(4))
def test_batched_attr_how_matches_single_q7_q8(seed):
    idx, sink, rng = tqp._random_pipeline(seed)
    n_src, c_src = idx.datasets["src"].n_rows, idx.datasets["src"].n_cols
    n_sink, c_sink = idx.datasets[sink].n_rows, idx.datasets[sink].n_cols
    sess = walk_session(idx)
    rprobes = [[0], sorted(set(rng.integers(0, n_src, 2).tolist())), []]
    batch = sess.run(prov(idx).source("src").rows_batch(rprobes).attrs([0])
                     .forward().to(sink).how().plan())
    for p, (cells, hops) in zip(rprobes, batch):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            scells, shops = Q.q7_forward_attr_how(idx, "src", p, [0], sink)
        np.testing.assert_array_equal(cells, scells)
        assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in hops] \
            == [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in shops]
    rprobes = [[0], [n_sink - 1]]
    aprobes = [[0], list(range(min(2, c_sink)))]
    batch = sess.run(prov(idx).source(sink).rows_batch(rprobes).attrs_batch(aprobes)
                     .backward().to("src").how().plan())
    for (p, a), (cells, hops) in zip(zip(rprobes, aprobes), batch):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            scells, shops = Q.q8_backward_attr_how(idx, sink, p, a, "src")
        np.testing.assert_array_equal(cells, scells)
        assert [(h.op_id, h.n_records) for h in hops] \
            == [(h.op_id, h.n_records) for h in shops]


# ===========================================================================
# run_many fusion
# ===========================================================================
@pytest.mark.parametrize("seed", range(4))
def test_run_many_fuses_and_matches_singles(seed):
    idx, sink, rng = tqp._random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    mid = idx.ops[0].output_id

    def plans():
        return [
            prov(idx).source("src").rows([0]).forward().to(sink).plan(),
            prov(idx).source(sink).rows([0]).backward().to("src").plan(),
            prov(idx).source("src").rows([1 % n_src, 2 % n_src])
                .forward().to(sink).plan(),
            prov(idx).source("src").rows_batch([[0], [3 % n_src]])
                .forward().to(sink).plan(),
            prov(idx).source(sink).rows([n_sink - 1]).attrs([0])
                .backward().to("src").plan(),
            prov(idx).source(sink).rows([0]).attrs([0]).backward().to("src").plan(),
            prov(idx).source(sink).transformations().plan(),
            prov(idx).source(mid).rows([0]).co_dependency("src", sink).plan(),
        ]
    sess = walk_session(idx)
    singles = [sess.run(p) for p in plans()]
    fsess = walk_session(idx)
    fused = fsess.run_many(plans())
    assert fsess.counters["fused_groups"] >= 2   # Q1 group + Q4 group
    assert fsess.counters["fused_plans"] >= 5
    assert len(fused) == len(singles)
    for s, f in zip(singles, fused):
        if isinstance(s, list) and not isinstance(s, np.ndarray) \
                and s and isinstance(s[0], dict):
            assert s == f                        # transformations
        elif isinstance(s, list):
            for a, b in zip(s, f):
                np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_array_equal(s, f)


def test_run_many_accepts_builders_and_routes_hopcache():
    idx, sink, rng = tqp._random_pipeline(1)
    n_src = idx.datasets["src"].n_rows
    sess = cache_session(idx)
    builders = [prov(idx).source("src").rows([i % n_src]).forward().to(sink)
                for i in range(10)]
    out = sess.run_many(builders)
    assert len(out) == 10
    for i, r in enumerate(out):
        np.testing.assert_array_equal(r, tqp.ref_q1(idx, "src", [i % n_src], sink))
    st = sess.stats()
    assert st["planner"]["fused_groups"] == 1
    assert st["planner"]["hopcache"] == 1        # ONE fused probe, not 10
    assert st["hopcache"]["misses"] >= 1         # composed the relation once


# ===========================================================================
# Stats plumbing: hop-cache counters surface through the session
# ===========================================================================
def test_session_stats_expose_hopcache_counters():
    idx, sink, rng = tqp._random_pipeline(2)
    n_src = idx.datasets["src"].n_rows
    sess = cache_session(idx, memory_budget_bytes=32 << 20)
    probes = [[i % n_src] for i in range(6)]
    p = prov(idx).source("src").rows_batch(probes).forward().to(sink).plan()
    assert sess.explain(p)["strategy"] == "hopcache"
    sess.run(p)
    st1 = sess.stats()
    assert st1["hopcache"]["misses"] >= 1 and st1["hopcache"]["entries"] >= 1
    assert st1["planner"]["hopcache"] == 1
    sess.run(p)                                   # relation now cached
    st2 = sess.stats()
    assert st2["hopcache"]["hits"] > st1["hopcache"]["hits"]
    assert st2["hopcache"]["misses"] == st1["hopcache"]["misses"]
    # a walk-only session never touches the cache — routing regressions
    # show up as misses moving where hits were expected
    w = walk_session(idx)
    w.run(p)
    assert w.stats()["hopcache"]["misses"] == 0
    assert w.stats()["planner"]["walk"] == 1


def test_shared_session_on_index():
    idx, sink, _ = tqp._random_pipeline(3)
    s1 = idx.session()
    assert idx.session() is s1
    assert s1.composed is idx.composed()
    with pytest.raises(ValueError):
        idx.session(hopcache_min_batch=3)


# ===========================================================================
# Legacy-shim ambiguity warnings (the is_probe_batch fix)
# ===========================================================================
def test_shims_warn_on_ambiguous_probes():
    idx, sink = _tiny_index()
    with pytest.warns(AmbiguousProbeWarning, match="empty probe"):
        res = Q.q1_forward(idx, "src", [], sink)
    assert res.size == 0                          # still the single-probe path
    with pytest.warns(AmbiguousProbeWarning, match="1-D integer"):
        res = Q.q2_backward(idx, sink, np.array([0, 1]), "src")
    assert isinstance(res, np.ndarray) and res.ndim == 1
    # unambiguous spellings stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", AmbiguousProbeWarning)
        Q.q1_forward(idx, "src", [0, 1], sink)               # index list
        Q.q1_forward(idx, "src", [[0], [1]], sink)           # batch of sets
        Q.q1_forward(idx, "src", np.ones(6, dtype=bool), sink)  # bool mask
    # ... and the builder never guesses at all
    with warnings.catch_warnings():
        warnings.simplefilter("error", AmbiguousProbeWarning)
        prov(idx).source("src").rows([]).forward().to(sink).run()
        prov(idx).source("src").rows_batch([]).forward().to(sink).plan()


def test_serve_engines_sharing_one_index_never_collide():
    """Two engines over ONE prov index (the documented pattern) must not
    overwrite each other's requests@N/responses@N datasets."""
    from repro.serve.engine import GenerationResult, ServeEngine

    idx = ProvenanceIndex("shared-serve")
    e1 = object.__new__(ServeEngine)   # skip model init: only the capture
    e2 = object.__new__(ServeEngine)   # path is under test
    for e in (e1, e2):
        e.prov, e._n_generations = idx, 0
    r1 = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                          request_ids=np.arange(3))
    e1._record_generation(r1, prompt_len=2, n_new=2, request_source=None)
    r2 = GenerationResult(tokens=np.zeros((4, 2), np.int32),
                          request_ids=np.arange(4))
    e2._record_generation(r2, prompt_len=2, n_new=2, request_source=None)
    assert r1.response_dataset != r2.response_dataset
    assert r1.request_dataset != r2.request_dataset
    np.testing.assert_array_equal(
        prov(idx).source(r2.response_dataset).rows([1])
        .backward().to(r2.request_dataset).run(), [1])
    # and the index itself rejects a duplicate producer
    with pytest.raises(ValueError, match="already exists"):
        idx.record([r1.request_dataset], r1.response_dataset,
                   Table.from_columns({"x": np.zeros(3, np.float32)}),
                   idx.ops[0].info)


def test_shims_emit_deprecation_once():
    idx, sink = _tiny_index()
    Q._DEPRECATION_WARNED.discard("q1_forward")
    with pytest.warns(DeprecationWarning, match="q1_forward"):
        Q.q1_forward(idx, "src", [0], sink)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Q.q1_forward(idx, "src", [0], sink)       # second call is silent
