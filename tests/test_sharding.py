"""Partition-rule unit tests: every param/cache leaf of every arch gets a
spec whose sharded dims actually divide (AbstractMesh — no devices needed)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # pragma: no cover - version-dependent
    pytest.skip("jax.sharding.AxisType unavailable on this JAX",
                allow_module_level=True)

from repro.configs.registry import ARCHS, get_config
from repro.launch import sharding as SD
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state

MESH_1POD = AbstractMesh((16, 16), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
MESH_2POD = AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)


def _axes_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _check_tree(shapes, specs, mesh):
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape)
        for dim, entry in enumerate(spec):
            n = _axes_size(mesh, entry)
            assert leaf.shape[dim] % n == 0, (leaf.shape, spec, dim)


@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD], ids=["1pod", "2pod"])
@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(functools.partial(model.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = SD.param_pspecs(shapes, mesh)
    _check_tree(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["llama3-405b", "gemma3-1b", "mamba2-370m",
                                  "jamba-1.5-large-398b", "whisper-small"])
def test_cache_specs_divisible(arch):
    from repro.launch.shapes import SHAPES, cell_skip_reason
    cfg = get_config(arch)
    model = get_model(cfg)
    for shape in ("decode_32k", "long_500k"):
        if cell_skip_reason(arch, shape):
            continue
        cell = SHAPES[shape]
        shapes = jax.eval_shape(
            lambda: model.init_cache(cfg, cell.global_batch, cell.seq_len,
                                     dtype=jnp.bfloat16))
        for mesh in (MESH_1POD, MESH_2POD):
            specs = SD.cache_pspecs(shapes, mesh)
            _check_tree(shapes, specs, mesh)


def test_whisper_vocab_falls_back_to_replicated():
    """51865 is not 16-divisible: the vocab dim must NOT be sharded, while
    the d_model dim still FSDPs (padding handles the logits side)."""
    cfg = get_config("whisper-small")
    assert cfg.padded_vocab == 51_968           # padded to 128
    spec = SD._param_rule(MESH_1POD, "embed", (cfg.vocab, cfg.d_model))
    assert spec[0] is None and spec[1] is not None


def test_tensorstate_spec_structure_matches():
    cfg = get_config("olmo-1b")
    opt = AdamWConfig()
    shapes = jax.eval_shape(
        functools.partial(init_train_state, cfg, opt_cfg=opt),
        jax.random.PRNGKey(0))
    specs = SD.state_pspecs(shapes, MESH_1POD)
    # moments mirror params 1:1
    assert jax.tree.structure(specs.opt.mu, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(specs.params, is_leaf=lambda x: isinstance(x, P))
    _check_tree(shapes.params, specs.params, MESH_1POD)
    _check_tree(shapes.opt.mu, specs.opt.mu, MESH_1POD)


def test_batch_specs():
    from repro.launch.shapes import input_specs
    ins = input_specs("llama3-405b", "train_4k")
    specs = SD.batch_pspecs(ins, MESH_2POD)
    assert specs["tokens"][0] == ("pod", "data")
    ins1 = input_specs("mamba2-370m", "long_500k")
    specs1 = SD.batch_pspecs(ins1, MESH_1POD)
    assert specs1["token"][0] is None           # batch 1: replicated
