"""Three-way composition-backend parity on CPU (tier-1 CI).

The cost model now routes compositions onto either the scipy-CSR backend or
the packed-bitplane backend (``kernels.ops.bitmatmul`` — the Pallas kernel
in interpret mode on CPU, the jnp oracle otherwise).  These tests pin all
three against each other on randomized small shapes, including
non-multiple-of-32 contraction and output dims, so the backend the planner
selects is exact regardless of representation.

Unlike :mod:`tests.test_kernels` this file needs no hypothesis — it must
always run in tier-1.
"""
import numpy as np
import pytest

pytest.importorskip("scipy")
import scipy.sparse as sp

from repro.core.compose import compose_pair_csr
from repro.kernels import ops as K
from repro.kernels import ref as R


def _csr(dense: np.ndarray):
    return sp.csr_matrix(dense.astype(np.float32))


def _three_way(A: np.ndarray, B: np.ndarray) -> None:
    m, k = A.shape
    k2, n = B.shape
    assert k == k2
    a_b = np.asarray(R.pack_bits(A))
    b_b = np.asarray(R.pack_bits(B))
    # 1. Pallas kernel (interpret mode on CPU), small blocks to hit the grid
    pallas = np.asarray(R.unpack_bits(
        K.bitmatmul(a_b, b_b, block_m=8, block_nw=8, block_k=32,
                    interpret=True, use_pallas=True), n))
    # 2. jnp oracle
    oracle = np.asarray(R.unpack_bits(R.bitmatmul_ref(a_b, b_b), n))
    # 3. scipy-CSR backend (the hop-cache's sparse compose path)
    csr = np.asarray(compose_pair_csr(_csr(A), _csr(B)).todense()) > 0
    want = (A.astype(np.int64) @ B.astype(np.int64)) > 0
    np.testing.assert_array_equal(pallas, want)
    np.testing.assert_array_equal(oracle, want)
    np.testing.assert_array_equal(csr, want)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),          # degenerate
    (5, 17, 9),         # nothing aligned
    (8, 32, 40),        # aligned contraction
    (3, 70, 33),        # k and n both off-lane
    (40, 31, 64),       # k one short of a word
])
@pytest.mark.parametrize("density", [0.03, 0.4, 0.9])
def test_bitmatmul_three_way_parity(m, k, n, density):
    rng = np.random.default_rng(m * 10_000 + k * 100 + n)
    A = rng.random((m, k)) < density
    B = rng.random((k, n)) < density
    _three_way(A, B)


@pytest.mark.parametrize("seed", range(6))
def test_bitmatmul_three_way_randomized(seed):
    rng = np.random.default_rng(1234 + seed)
    m, k, n = (int(rng.integers(1, 60)) for _ in range(3))
    A = rng.random((m, k)) < float(rng.uniform(0.05, 0.6))
    B = rng.random((k, n)) < float(rng.uniform(0.05, 0.6))
    _three_way(A, B)


def test_bitmatmul_empty_and_full():
    A = np.zeros((7, 19), dtype=bool)
    B = np.ones((19, 11), dtype=bool)
    _three_way(A, B)
    A[2, 3] = True
    _three_way(A, B)


def test_use_pallas_none_resolves_off_tpu_to_oracle():
    """The kernel-launch guard: use_pallas=None must answer exactly like the
    oracle (and, on this CPU container, route to it)."""
    rng = np.random.default_rng(0)
    A = rng.random((9, 37)) < 0.3
    B = rng.random((37, 21)) < 0.3
    a_b, b_b = np.asarray(R.pack_bits(A)), np.asarray(R.pack_bits(B))
    got = np.asarray(K.bitmatmul(a_b, b_b, use_pallas=None))
    want = np.asarray(R.bitmatmul_ref(a_b, b_b))
    np.testing.assert_array_equal(got, want)
