"""Parity: the vectorized / batched / hop-cached query engine vs the naive
per-hop reference.

The reference below is the SEED implementation of the query layer, kept
verbatim (per-element Python loops over Table-VI maps, set-based cell
materialization).  Every Q1-Q11 answer from the packed-bitset engine and the
ComposedIndex hop-cache must agree EXACTLY with it on randomized pipelines
covering identity, vreduce, vaugment, hreduce, haugment, join and append ops,
single and batch probes, empty masks and -1 sentinels.

Since the query-plan redesign, ``q1_forward`` … ``q11_co_dependency`` are
thin shims over :mod:`repro.provenance` — so every test in this file pins
the NEW planner/executor stack against the seed reference exactly; the
bottom section additionally pins shim-vs-QuerySession agreement under both
physical strategies and the multi-path diamond DAG the old unique-chain
hop-cache could not compose.
"""
import warnings

import numpy as np
import pytest

import pipegen
from repro.core import query as Q
from repro.core import schema as sc
from repro.core.hopcache import ComposedIndex
from repro.core.opcat import AttrMap
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import QuerySession, prov


# ===========================================================================
# Naive reference (the seed engine, verbatim)
# ===========================================================================
def _as_mask(rows, n):
    if isinstance(rows, np.ndarray) and rows.dtype == bool:
        return rows
    m = np.zeros(n, dtype=bool)
    m[np.asarray(list(rows), dtype=np.int64)] = True
    return m


def ref_forward_record_masks(index, src, rows, collect_hops=False):
    masks = {src: _as_mask(rows, index.datasets[src].n_rows)}
    hops = []
    for op in index.downstream_ops(src):
        out_n = op.tensor.n_out
        out_mask = masks.get(op.output_id, np.zeros(out_n, dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                contrib = op.tensor.forward_mask(k, masks[in_id])
                if collect_hops and contrib.any():
                    hops.append((op.op_id, in_id, op.output_id, int(contrib.sum())))
                out_mask |= contrib
        masks[op.output_id] = out_mask
    return masks, hops


def ref_backward_record_masks(index, dst, rows, collect_hops=False):
    masks = {dst: _as_mask(rows, index.datasets[dst].n_rows)}
    hops = []
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask(k, masks[op.output_id])
            if collect_hops and contrib.any():
                hops.append((op.op_id, op.output_id, in_id, int(contrib.sum())))
            prev = masks.get(in_id, np.zeros(index.datasets[in_id].n_rows, dtype=bool))
            masks[in_id] = prev | contrib
    return masks, hops


def ref_q1(index, src, rows, dst):
    masks, _ = ref_forward_record_masks(index, src, rows)
    if dst not in masks:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(masks[dst])


def ref_q2(index, dst, rows, src):
    masks, _ = ref_backward_record_masks(index, dst, rows)
    if src not in masks:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(masks[src])


def ref_attrs_forward(amap, attrs, n_out_attrs):
    out = np.zeros(n_out_attrs, dtype=bool)
    src = np.flatnonzero(attrs)
    if amap.kind == "identity":
        valid = src[src < n_out_attrs]
        out[valid] = True
        return out
    if amap.kind == "vreduce":
        b = amap.bitset
        if amap.perm is not None:
            for j, a in enumerate(amap.perm):
                if attrs[a]:
                    out[j] = True
            return out
        for a in src:
            j = sc.map_vr_f(b, int(a))
            if j is not None:
                out[j] = True
        return out
    if amap.kind == "vaugment":
        b, m = amap.bitset, amap.m
        new_attrs = [j for j in range(m, b.n) if b.test(j)]
        for a in src:
            out[sc.map_va_f(m, int(a))] = True
            if a < m and b.test(int(a)):
                for j in new_attrs:
                    out[j] = True
        return out
    if amap.kind == "join":
        if amap.perm is not None:
            for j, a in enumerate(amap.perm):
                if a >= 0 and attrs[a]:
                    out[j] = True
            return out
        for a in src:
            j = sc.map_join_f(amap.bitset, int(a))
            if j is not None:
                out[j] = True
        return out
    raise ValueError(amap.kind)


def ref_attrs_backward(amap, attrs, n_in_attrs):
    out = np.zeros(n_in_attrs, dtype=bool)
    src = np.flatnonzero(attrs)
    if amap.kind == "identity":
        valid = src[src < n_in_attrs]
        out[valid] = True
        return out
    if amap.kind == "vreduce":
        if amap.perm is not None:
            for j in src:
                out[amap.perm[j]] = True
            return out
        for j in src:
            out[sc.map_vr_b(amap.bitset, int(j))] = True
        return out
    if amap.kind == "vaugment":
        for j in src:
            for a in sc.map_va_b(amap.bitset, amap.m, int(j)):
                out[a] = True
        return out
    if amap.kind == "join":
        if amap.perm is not None:
            for j in src:
                if amap.perm[j] >= 0:
                    out[amap.perm[j]] = True
            return out
        for j in src:
            a = sc.map_join_b(amap.bitset, int(j))
            if a is not None:
                out[a] = True
        return out
    raise ValueError(amap.kind)


def ref_attr_propagate(index, start, rows, attrs, direction):
    ds0 = index.datasets[start]
    terms = {start: [(_as_mask(rows, ds0.n_rows), _as_mask(attrs, ds0.n_cols))]}
    ops = (
        index.downstream_ops(start)
        if direction == "fwd"
        else list(reversed(index.upstream_ops(start)))
    )
    for op in ops:
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                for (rm, am) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask(k, rm)
                    new_am = ref_attrs_forward(op.info.attr_maps[k], am, out_ds.n_cols)
                    if new_rm.any() and new_am.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_am))
        else:
            for (rm, am) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    new_rm = op.tensor.backward_mask(k, rm)
                    new_am = ref_attrs_backward(op.info.attr_maps[k], am, in_ds.n_cols)
                    if new_rm.any() and new_am.any():
                        terms.setdefault(in_id, []).append((new_rm, new_am))
    return terms


def ref_cells(terms):
    cells = set()
    for rm, am in terms:
        for r in np.flatnonzero(rm):
            for a in np.flatnonzero(am):
                cells.add((int(r), int(a)))
    return np.array(sorted(cells), dtype=np.int64).reshape(-1, 2)


def ref_q3(index, src, rows, attrs, dst):
    return ref_cells(ref_attr_propagate(index, src, rows, attrs, "fwd").get(dst, []))


def ref_q4(index, dst, rows, attrs, src):
    return ref_cells(ref_attr_propagate(index, dst, rows, attrs, "bwd").get(src, []))


def ref_q10(index, d1, rows, d2, via=None):
    fwd_masks, _ = ref_forward_record_masks(index, d1, rows)
    if via is None:
        candidates = [
            d for d, m in fwd_masks.items()
            if d != d1 and m.any() and index.path_exists(d2, d)
        ]
        if not candidates:
            return np.zeros(0, dtype=np.int64)
        via = candidates[-1]
    if via not in fwd_masks or not fwd_masks[via].any():
        return np.zeros(0, dtype=np.int64)
    back, _ = ref_backward_record_masks(index, via, fwd_masks[via])
    if d2 not in back:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(back[d2])


def ref_q11(index, d2, rows, d1, d3):
    back, _ = ref_backward_record_masks(index, d2, rows)
    if d1 not in back or not back[d1].any():
        return np.zeros(0, dtype=np.int64)
    fwd, _ = ref_forward_record_masks(index, d1, back[d1])
    if d3 not in fwd:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(fwd[d3])


# ===========================================================================
# Randomized pipelines over every op category — shared generators in
# tests/pipegen.py; the module-level aliases keep downstream suites
# (test_session, test_costmodel, test_structured) importing from here.
# ===========================================================================
_random_pipeline = pipegen.random_pipeline
_row_probes = pipegen.row_probes
_diamond_pipeline = pipegen.diamond_pipeline

SEEDS = list(range(10))


def _forced_hopcache_session(idx, ci) -> QuerySession:
    """Pin the hop-cache strategy via the legacy (deprecated) min-batch knob
    without spamming DeprecationWarnings through every suite run."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return QuerySession(idx, ci, hopcache_min_batch=1)


# ===========================================================================
# Record-level parity (Q1/Q2/Q5/Q6)
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_q1_q2_parity_all_datasets(seed):
    idx, sink, rng = _random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    for dst in idx.datasets:
        for rows in _row_probes(rng, n_src):
            want = ref_q1(idx, "src", rows, dst)
            got = Q.q1_forward(idx, "src", rows, dst)
            np.testing.assert_array_equal(got, want)
    n_sink = idx.datasets[sink].n_rows
    for src in idx.datasets:
        for rows in _row_probes(rng, n_sink):
            want = ref_q2(idx, sink, rows, src)
            got = Q.q2_backward(idx, sink, rows, src)
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", SEEDS[:5])
def test_q5_q6_hops_parity(seed):
    idx, sink, rng = _random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    rows = [0, n_src - 1]
    recs, hops = Q.q5_forward_how(idx, "src", rows, sink)
    _, ref_hops = ref_forward_record_masks(idx, "src", rows, collect_hops=True)
    assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in hops] \
        == ref_hops
    np.testing.assert_array_equal(recs, ref_q1(idx, "src", rows, sink))
    n_sink = idx.datasets[sink].n_rows
    rows = [0, n_sink - 1]
    recs, hops = Q.q6_backward_how(idx, sink, rows, "src")
    _, ref_hops = ref_backward_record_masks(idx, sink, rows, collect_hops=True)
    assert [(h.op_id, h.src_dataset, h.dst_dataset, h.n_records) for h in hops] \
        == ref_hops
    np.testing.assert_array_equal(recs, ref_q2(idx, sink, rows, "src"))


# ===========================================================================
# Attribute-level parity (Q3/Q4/Q7/Q8)
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_q3_q4_parity(seed):
    idx, sink, rng = _random_pipeline(seed)
    n_src, c_src = idx.datasets["src"].n_rows, idx.datasets["src"].n_cols
    n_sink, c_sink = idx.datasets[sink].n_rows, idx.datasets[sink].n_cols
    for trial in range(4):
        rows = sorted(set(rng.integers(0, n_src, size=3).tolist()))
        attrs = sorted(set(rng.integers(0, c_src, size=2).tolist()))
        want = ref_q3(idx, "src", rows, attrs, sink)
        got = Q.q3_forward_attr(idx, "src", rows, attrs, sink)
        np.testing.assert_array_equal(got, want)
        rows = sorted(set(rng.integers(0, n_sink, size=3).tolist()))
        attrs = sorted(set(rng.integers(0, c_sink, size=2).tolist()))
        want = ref_q4(idx, sink, rows, attrs, "src")
        got = Q.q4_backward_attr(idx, sink, rows, attrs, "src")
        np.testing.assert_array_equal(got, want)
    # empty masks answer empty
    assert Q.q3_forward_attr(idx, "src", [], [0], sink).shape == (0, 2)
    assert Q.q4_backward_attr(idx, sink, [0], [], "src").shape == (0, 2)


@pytest.mark.parametrize("seed", SEEDS[:5])
def test_q7_q8_cells_match_q3_q4(seed):
    idx, sink, rng = _random_pipeline(seed)
    n_src, c_src = idx.datasets["src"].n_rows, idx.datasets["src"].n_cols
    rows, attrs = [0, n_src - 1], list(range(min(2, c_src)))
    cells, hops = Q.q7_forward_attr_how(idx, "src", rows, attrs, sink)
    np.testing.assert_array_equal(cells, ref_q3(idx, "src", rows, attrs, sink))
    n_sink, c_sink = idx.datasets[sink].n_rows, idx.datasets[sink].n_cols
    rows, attrs = [0, n_sink - 1], list(range(min(2, c_sink)))
    cells, hops = Q.q8_backward_attr_how(idx, sink, rows, attrs, "src")
    np.testing.assert_array_equal(cells, ref_q4(idx, sink, rows, attrs, "src"))


# ===========================================================================
# Q10/Q11 parity
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_q10_q11_parity(seed):
    idx, sink, rng = _random_pipeline(seed)
    others = [d for d in idx.datasets if d not in ("src", sink)]
    n_src = idx.datasets["src"].n_rows
    for d2 in others[:3]:
        for rows in _row_probes(rng, n_src):
            want = ref_q10(idx, "src", rows, d2)
            got = Q.q10_co_contributory(idx, "src", rows, d2)
            np.testing.assert_array_equal(got, want)
    mids = [op.output_id for op in idx.ops]
    for mid in mids[:3]:
        n_mid = idx.datasets[mid].n_rows
        if n_mid == 0:
            continue
        rows = [int(rng.integers(0, n_mid))]
        want = ref_q11(idx, mid, rows, "src", sink)
        got = Q.q11_co_dependency(idx, mid, rows, "src", sink)
        np.testing.assert_array_equal(got, want)


# ===========================================================================
# Batch probes == singles, in one pass
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_batch_matches_singles(seed):
    idx, sink, rng = _random_pipeline(seed)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    probes = [_row_probes(rng, n_src)[i] for i in range(3)] + [[], [0]]
    singles = [Q.q1_forward(idx, "src", p, sink) for p in probes]
    batch = Q.q1_forward(idx, "src", probes, sink)
    assert isinstance(batch, list) and len(batch) == len(probes)
    for s, b in zip(singles, batch):
        np.testing.assert_array_equal(s, b)
    probes = [_row_probes(rng, n_sink)[i] for i in range(3)] + [[]]
    singles = [Q.q2_backward(idx, sink, p, "src") for p in probes]
    for s, b in zip(singles, Q.q2_backward(idx, sink, probes, "src")):
        np.testing.assert_array_equal(s, b)
    # attr-level batch, including a broadcast attr set
    c_src = idx.datasets["src"].n_cols
    rprobes = [[0], [], list(range(min(3, n_src)))]
    aprobes = [[0], [c_src - 1], list(range(min(2, c_src)))]
    singles = [Q.q3_forward_attr(idx, "src", r, a, sink)
               for r, a in zip(rprobes, aprobes)]
    for s, b in zip(singles, Q.q3_forward_attr(idx, "src", rprobes, aprobes, sink)):
        np.testing.assert_array_equal(s, b)
    singles = [Q.q3_forward_attr(idx, "src", r, [0], sink) for r in rprobes]
    for s, b in zip(singles, Q.q3_forward_attr(idx, "src", rprobes, [0], sink)):
        np.testing.assert_array_equal(s, b)
    n_sink_cols = idx.datasets[sink].n_cols
    rprobes = [[0], list(range(min(4, n_sink)))]
    aprobes = [[0], list(range(min(2, n_sink_cols)))]
    singles = [Q.q4_backward_attr(idx, sink, r, a, "src")
               for r, a in zip(rprobes, aprobes)]
    for s, b in zip(singles, Q.q4_backward_attr(idx, sink, rprobes, aprobes, "src")):
        np.testing.assert_array_equal(s, b)
    # q11 batch
    mid = idx.ops[0].output_id
    n_mid = idx.datasets[mid].n_rows
    probes = [[0], [], [min(1, n_mid - 1)]]
    singles = [Q.q11_co_dependency(idx, mid, p, "src", sink) for p in probes]
    for s, b in zip(singles, Q.q11_co_dependency(idx, mid, probes, "src", sink)):
        np.testing.assert_array_equal(s, b)


# ===========================================================================
# Hop-cache parity
# ===========================================================================
@pytest.mark.parametrize("backend", ["csr", "bitplane", "auto"])
@pytest.mark.parametrize("seed", SEEDS)
def test_hopcache_parity(seed, backend):
    idx, sink, rng = _random_pipeline(seed)
    if backend == "csr":
        pytest.importorskip("scipy")
    ci = ComposedIndex(idx, memory_budget_bytes=32 << 20, backend=backend)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for rows in _row_probes(rng, n_src):
        np.testing.assert_array_equal(
            ci.q1_forward("src", rows, sink), ref_q1(idx, "src", rows, sink))
    for rows in _row_probes(rng, n_sink):
        np.testing.assert_array_equal(
            ci.q2_backward(sink, rows, "src"), ref_q2(idx, sink, rows, "src"))
    # batched probe, one composed plane
    probes = [_row_probes(rng, n_src)[i] for i in range(3)]
    batch = ci.q1_forward("src", probes, sink)
    for p, b in zip(probes, batch):
        np.testing.assert_array_equal(b, ref_q1(idx, "src", p, sink))
    assert ci.stats()["hits"] > 0
    # intermediate datasets along the chain probe from the prefix cache
    for op in idx.ops[:3]:
        mid = op.output_id
        if not idx.path_exists("src", mid):
            continue
        rows = [0]
        np.testing.assert_array_equal(
            ci.q1_forward("src", rows, mid), ref_q1(idx, "src", rows, mid))


@pytest.mark.parametrize("seed", SEEDS)
def test_auto_backend_matches_both_forced_backends(seed):
    """``backend='auto'`` (per-pair cost-model selection, mixed entries in
    one cache) answers EXACTLY like both forced backends on the randomized
    pipeline suite — forward, backward, and batched probes."""
    pytest.importorskip("scipy")
    idx, sink, rng = _random_pipeline(seed)
    auto = ComposedIndex(idx, backend="auto")
    csr = ComposedIndex(idx, backend="csr")
    bp = ComposedIndex(idx, backend="bitplane")
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for rows in _row_probes(rng, n_src):
        a = auto.q1_forward("src", rows, sink)
        np.testing.assert_array_equal(a, csr.q1_forward("src", rows, sink))
        np.testing.assert_array_equal(a, bp.q1_forward("src", rows, sink))
    probes = [_row_probes(rng, n_sink)[i] for i in range(3)] + [[]]
    for a, c, b in zip(auto.q2_backward(sink, probes, "src"),
                       csr.q2_backward(sink, probes, "src"),
                       bp.q2_backward(sink, probes, "src")):
        np.testing.assert_array_equal(a, c)
        np.testing.assert_array_equal(a, b)
    st = auto.stats()
    assert st["entries"] == (st["entries_csr"] + st["entries_bitplane"]
                             + st["entries_structured"])


@pytest.mark.parametrize("seed", SEEDS[:5])
def test_hopcache_q10_q11_parity(seed):
    idx, sink, rng = _random_pipeline(seed)
    ci = ComposedIndex(idx)
    n_src = idx.datasets["src"].n_rows
    others = [d for d in idx.datasets
              if d not in ("src", sink) and not idx.path_exists("src", d)
              and idx.path_exists(d, sink)]
    for d2 in others[:2]:
        want = ref_q10(idx, "src", [0], d2, via=sink)
        got = ci.q10_co_contributory("src", [0], d2, via=sink)
        np.testing.assert_array_equal(got, want)
    mid = idx.ops[0].output_id
    if idx.path_exists("src", mid) and idx.path_exists(mid, sink):
        n_mid = idx.datasets[mid].n_rows
        rows = [n_mid - 1]
        np.testing.assert_array_equal(
            ci.q11_co_dependency(mid, rows, "src", sink),
            ref_q11(idx, mid, rows, "src", sink))


def test_hopcache_unreachable_pair_answers_empty():
    """No dataflow path: probes answer empty like the walk engine; only the
    relation-materializing API raises."""
    idx = ProvenanceIndex("unreach")
    a = track(Table.from_columns({"x": np.zeros(4, np.float32)}), idx, "A")
    b = track(Table.from_columns({"y": np.zeros(3, np.float32)}), idx, "B")
    a.filter_rows(np.array([True, False, True, True])).mark_sink()
    sink = idx.sinks()[0]
    ci = ComposedIndex(idx)
    np.testing.assert_array_equal(ci.q1_forward("B", [0], sink),
                                  ref_q1(idx, "B", [0], sink))
    np.testing.assert_array_equal(ci.q2_backward(sink, [0], "B"),
                                  ref_q2(idx, sink, [0], "B"))
    for got in ci.q1_forward("B", [[0], [1]], sink):
        assert got.size == 0
    with pytest.raises(KeyError):
        ci.relation("B", sink)


def test_hopcache_eviction_and_append_keeps_cache():
    idx, sink, rng = _random_pipeline(0)
    tiny = ComposedIndex(idx, memory_budget_bytes=256)  # forces eviction
    n_src = idx.datasets["src"].n_rows
    for rows in ([0], [1], [2]):
        np.testing.assert_array_equal(
            tiny.q1_forward("src", rows, sink), ref_q1(idx, "src", rows, sink))
    assert tiny.stats()["bytes"] <= 256 or tiny.stats()["entries"] <= 1
    # the DAG is append-only (one producer per dataset), so recording a new
    # op KEEPS cached relations — and queries to the new dataset stay exact
    ci = ComposedIndex(idx)
    before = ci.q1_forward("src", [0], sink)
    entries = ci.stats()["entries"]
    assert entries > 0
    tracked = track(
        Table.from_columns({"x": np.zeros(3, np.float32)}), idx, "late_src")
    assert idx.version == len(idx.ops)   # add_source does not bump the version
    # extend the pipeline past the old sink: version bumps, cache survives
    from repro.dataprep.tracked import TrackedTable
    n_sink = idx.datasets[sink].n_rows
    mask = np.zeros(n_sink, dtype=bool)
    mask[0] = True
    late = TrackedTable(idx.datasets[sink].table, idx, sink).filter_rows(mask)
    np.testing.assert_array_equal(
        ci.q1_forward("src", [0], sink), before)          # cache hit, still exact
    assert ci.stats()["entries"] >= entries and ci.stats()["hits"] > 0
    np.testing.assert_array_equal(                        # new suffix composes
        ci.q1_forward("src", [0], late.dataset_id),
        ref_q1(idx, "src", [0], late.dataset_id))


def test_record_rejects_duplicate_output_dataset():
    """One producer per dataset — the invariant the keep-on-append
    hop-cache policy rests on."""
    idx = ProvenanceIndex("dup")
    t = track(Table.from_columns({"x": np.zeros(4, np.float32)}), idx, "A")
    out = t.filter_rows(np.array([1, 0, 1, 1], bool))
    with pytest.raises(ValueError, match="already exists"):
        idx.record(["A"], out.dataset_id, out.table, idx.ops[0].info)


# ===========================================================================
# -1 sentinel edges: outer join dangles + append block structure
# ===========================================================================
def test_sentinel_outer_join_and_append_parity():
    idx = ProvenanceIndex("sentinel")
    l = Table.from_columns({"k": [1., 2, 3, 4], "a": [0., 1, 2, 3]})
    r = Table.from_columns({"k": [2., 4, 9], "b": [1., 2, 3]})
    e = Table.from_columns({"a": [9., 8], "c": [7., 6]})
    tl, tr, te = track(l, idx, "L"), track(r, idx, "R"), track(e, idx, "E")
    tj = tl.join(tr, on="k", how="outer")
    ta = tj.append(te).mark_sink()
    sink = ta.dataset_id
    for src in ("L", "R", "E"):
        n = idx.datasets[src].n_rows
        for rows in ([], [0], list(range(n))):
            np.testing.assert_array_equal(
                Q.q1_forward(idx, src, rows, sink), ref_q1(idx, src, rows, sink))
    n_sink = idx.datasets[sink].n_rows
    for src in ("L", "R", "E"):
        for rows in ([], [0], list(range(n_sink))):
            np.testing.assert_array_equal(
                Q.q2_backward(idx, sink, rows, src), ref_q2(idx, sink, rows, src))
    # attr-level through the sentinel ops
    for src in ("L", "R", "E"):
        c = idx.datasets[src].n_cols
        got = Q.q4_backward_attr(idx, sink, list(range(n_sink)),
                                 list(range(idx.datasets[sink].n_cols)), src)
        want = ref_q4(idx, sink, list(range(n_sink)),
                      list(range(idx.datasets[sink].n_cols)), src)
        np.testing.assert_array_equal(got, want)
    # hop-cache through sentinels
    ci = ComposedIndex(idx)
    for src in ("L", "R", "E"):
        np.testing.assert_array_equal(
            ci.q2_backward(sink, [0, n_sink - 1], src),
            ref_q2(idx, sink, [0, n_sink - 1], src))


# ===========================================================================
# Legacy shims == QuerySession planner, both strategies, exact
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS[:6])
def test_legacy_shims_match_session_everywhere(seed):
    """The old q1/q2/q10/q11 spellings and the new plan API answer from the
    same planner — pin them against each other AND the seed reference under
    forced-walk and forced-hopcache sessions."""
    idx, sink, rng = _random_pipeline(seed)
    walk = QuerySession(idx, ComposedIndex(idx), use_hopcache=False)
    cache = _forced_hopcache_session(idx, ComposedIndex(idx))
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for rows in _row_probes(rng, n_src):
        want = ref_q1(idx, "src", rows, sink)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            got_shim = Q.q1_forward(idx, "src", rows, sink)
        plan = prov(idx).source("src").rows(rows).forward().to(sink).plan()
        np.testing.assert_array_equal(got_shim, want)
        np.testing.assert_array_equal(walk.run(plan), want)
        np.testing.assert_array_equal(cache.run(plan), want)
    # batch probes fuse identically
    probes = [_row_probes(rng, n_sink)[i] for i in range(3)] + [[]]
    plan = prov(idx).source(sink).rows_batch(probes).backward().to("src").plan()
    for p, w, c in zip(probes, walk.run(plan), cache.run(plan)):
        want = ref_q2(idx, sink, p, "src")
        np.testing.assert_array_equal(w, want)
        np.testing.assert_array_equal(c, want)


@pytest.mark.parametrize("backend", ["csr", "bitplane", "auto"])
@pytest.mark.parametrize("seed", SEEDS[:5])
def test_multipath_diamond_parity(seed, backend):
    if backend == "csr":
        pytest.importorskip("scipy")
    idx, sink = _diamond_pipeline(seed)
    ci = ComposedIndex(idx, backend=backend)
    sess = _forced_hopcache_session(idx, ci)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for rows in ([], [0], [n_src - 1], list(range(n_src))):
        want = ref_q1(idx, "src", rows, sink)
        got = sess.run(prov(idx).source("src").rows(rows)
                       .forward().to(sink).plan())
        np.testing.assert_array_equal(got, want)
    for rows in ([], [0], list(range(n_sink))):
        want = ref_q2(idx, sink, rows, "src")
        got = sess.run(prov(idx).source(sink).rows(rows)
                       .backward().to("src").plan())
        np.testing.assert_array_equal(got, want)
    assert sess.counters["hopcache"] > 0         # really probed the relation


# ===========================================================================
# Attr-map properties: vectorized == naive; round-trips lose nothing
# ===========================================================================
def _random_amaps(rng):
    amaps = []
    n = int(rng.integers(2, 12))
    amaps.append((AttrMap(kind="identity"), n, int(rng.integers(2, 12))))
    bits = rng.random(n) < 0.6
    bset = sc.Bitset.from_bits(bits)
    amaps.append((AttrMap(kind="vreduce", bitset=bset), n, int(bits.sum())))
    k = int(bits.sum())
    if k:
        perm = rng.permutation(n)[:k].astype(np.int32)
        amaps.append((AttrMap(kind="vreduce", bitset=bset, perm=perm), n, k))
    m = int(rng.integers(1, 8))
    n_new = int(rng.integers(1, 5))
    eng = (rng.random(m) < 0.5)
    vbits = np.concatenate([eng, np.ones(n_new, dtype=bool)])
    amaps.append((AttrMap(kind="vaugment", bitset=sc.Bitset.from_bits(vbits), m=m),
                  m, m + n_new))
    n_out = int(rng.integers(2, 12))
    jbits = rng.random(n_out) < 0.5
    n_in = int(jbits.sum()) + int(rng.integers(0, 2))  # exercise select clipping
    amaps.append((AttrMap(kind="join", bitset=sc.Bitset.from_bits(jbits)),
                  max(n_in, 1), n_out))
    jperm = np.where(rng.random(n_out) < 0.5,
                     rng.integers(0, max(n_in, 1), size=n_out), -1).astype(np.int32)
    amaps.append((AttrMap(kind="join", bitset=sc.Bitset.from_bits(jbits), perm=jperm),
                  max(n_in, 1), n_out))
    return amaps


@pytest.mark.parametrize("seed", range(20))
def test_attr_maps_vectorized_equals_naive(seed):
    rng = np.random.default_rng(seed)
    for amap, n_in, n_out in _random_amaps(rng):
        for _ in range(3):
            attrs = rng.random(n_in) < 0.4
            np.testing.assert_array_equal(
                Q._attrs_forward(amap, attrs, n_out),
                ref_attrs_forward(amap, attrs, n_out), err_msg=amap.kind)
            attrs = rng.random(n_out) < 0.4
            np.testing.assert_array_equal(
                Q._attrs_backward(amap, attrs, n_in),
                ref_attrs_backward(amap, attrs, n_in), err_msg=amap.kind)


@pytest.mark.parametrize("seed", range(20))
def test_attr_roundtrip_never_loses_contributor(seed):
    """Forward-then-backward over any AttrMap kind keeps every contributing
    attribute; backward-then-forward keeps every derived attribute."""
    rng = np.random.default_rng(1000 + seed)
    for amap, n_in, n_out in _random_amaps(rng):
        for a in range(n_in):
            one = np.zeros(n_in, dtype=bool)
            one[a] = True
            fwd = Q._attrs_forward(amap, one, n_out)
            if fwd.any():
                back = Q._attrs_backward(amap, fwd, n_in)
                assert back[a], (amap.kind, a)
        for o in range(n_out):
            one = np.zeros(n_out, dtype=bool)
            one[o] = True
            back = Q._attrs_backward(amap, one, n_in)
            if back.any():
                fwd = Q._attrs_forward(amap, back, n_out)
                assert fwd[o], (amap.kind, o)
