"""Einsum composition chain == hop-by-hop queries; sharded == local."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.compose import compose_chain, dataset_lineage, plan_chain
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track


def _pipeline(seed=0, n=64):
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex("c")
    t = Table.from_columns({
        "k": rng.integers(0, n // 2, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "g": rng.integers(0, 2, n).astype(np.float32),
    })
    r = Table.from_columns({
        "k": np.arange(n // 2, dtype=np.float32),
        "y": rng.normal(size=n // 2).astype(np.float32),
    })
    tt, tr = track(t, idx, "src"), track(r, idx, "ref")
    tj = tt.join(tr, on="k", how="inner")
    tf = tj.filter_rows(np.asarray(tj.table.col("x")) > -0.5)
    tv = tf.value_transform("x", "scale", factor=2.0)
    to = tv.oversample(frac=0.3, seed=seed).mark_sink()
    return idx, to


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("optimize", [False, True])
def test_compose_matches_hops(seed, optimize):
    idx, to = _pipeline(seed)
    rel_bits = compose_chain(idx, "src", to.dataset_id, use_pallas=False,
                             optimize=optimize)
    from repro.core.provtensor import unpack_bitplane
    rel = unpack_bitplane(rel_bits, idx.datasets[to.dataset_id].n_rows)
    n_src = idx.datasets["src"].n_rows
    for row in range(0, n_src, 7):
        want = set(Q.q1_forward(idx, "src", [row], to.dataset_id).tolist())
        got = set(np.flatnonzero(rel[row]).tolist())
        assert got == want


def test_compose_with_pallas_interpret():
    idx, to = _pipeline(3, n=40)
    a = compose_chain(idx, "src", to.dataset_id, use_pallas=False)
    b = compose_chain(idx, "src", to.dataset_id, use_pallas=True)
    np.testing.assert_array_equal(a, np.asarray(b))


def test_plan_chain_is_optimal_order():
    # classic example: (10x100)(100x5)(5x50) -> ((A B) C) costs 7500 < 75000
    order = plan_chain([(10, 100), (100, 5), (5, 50)])
    assert order == [(0, 0), (0, 1)]


def test_dataset_lineage_identity_when_src_is_dst():
    idx, to = _pipeline(0)
    rel = dataset_lineage(idx, "src", "src", use_pallas=False)
    assert (rel == np.eye(rel.shape[0], dtype=bool)).all()


def test_sharded_compose_and_audit_match_local():
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import (
        compose_sharded, lineage_audit_sharded, backward_frontier_sharded,
        shard_relation)
    from repro.kernels.ref import pack_bits, unpack_bits
    from repro.launch.mesh import make_mesh_compat

    idx, to = _pipeline(1, n=48)
    sink = to.dataset_id
    n_src = idx.datasets["src"].n_rows
    n_dst = idx.datasets[sink].n_rows
    rel = dataset_lineage(idx, "src", sink, use_pallas=False)

    mesh = make_mesh_compat((1,), ("data",))
    bits = np.asarray(pack_bits(jnp.asarray(rel)))
    rb = shard_relation(bits, mesh)

    # audit: contributions per 'g' group to the first half of the output
    mask = np.zeros(n_dst, bool)
    mask[: n_dst // 2] = True
    mw = jnp.asarray(pack_bits(jnp.asarray(mask[None]))[0])
    grp = jnp.asarray(idx.datasets["src"].table.col("g").astype(np.int32))
    counts = np.asarray(lineage_audit_sharded(rb[:n_src], grp, mw, 2, mesh))
    # local oracle
    hits = (rel[:, mask]).any(axis=1)
    want = np.array([np.sum(hits & (np.asarray(grp) == g)) for g in range(2)])
    np.testing.assert_array_equal(counts, want)

    frontier = np.asarray(backward_frontier_sharded(rb[:n_src], mw, mesh))
    np.testing.assert_array_equal(frontier, hits)
