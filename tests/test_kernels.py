"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

All kernels are integer/boolean — assertions are EXACT equality.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:  # hypothesis is a dev extra: only the property tests skip without it
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - environment-dependent
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.kernels import ops as K
from repro.kernels import ref as R


# ---------------------------------------------------------------------------
# bitmatmul: (OR,AND) boolean-semiring matmul on packed bitplanes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 32, 32), (7, 33, 9), (40, 70, 50),
    (64, 256, 128), (130, 300, 257),
])
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_bitmatmul_sweep(m, k, n, density):
    rng = np.random.default_rng(m * 1000 + k + n)
    A = rng.random((m, k)) < density
    B = rng.random((k, n)) < density
    a_b = R.pack_bits(jnp.asarray(A))
    b_b = R.pack_bits(jnp.asarray(B))
    got = K.bitmatmul(a_b, b_b, block_m=8, block_nw=8, block_k=32, interpret=True)
    want = R.bitmatmul_ref(a_b, b_b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # semantic check against dense boolean matmul
    dense = R.unpack_bits(want, n)
    np.testing.assert_array_equal(np.asarray(dense),
                                  (A.astype(int) @ B.astype(int)) > 0)


def test_bitmatmul_identity():
    n = 96
    eye = np.eye(n, dtype=bool)
    rng = np.random.default_rng(0)
    Bm = rng.random((n, 40)) < 0.2
    a_b = R.pack_bits(jnp.asarray(eye))
    b_b = R.pack_bits(jnp.asarray(Bm))
    got = K.bitmatmul(a_b, b_b, block_m=8, block_nw=8, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(R.unpack_bits(got, 40)), Bm)


@given(st.integers(1, 40), st.integers(1, 70), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bitmatmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = rng.random((m, k)) < 0.2
    B = rng.random((k, n)) < 0.2
    got = K.bitmatmul(R.pack_bits(jnp.asarray(A)), R.pack_bits(jnp.asarray(B)),
                      block_m=8, block_nw=8, block_k=32, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(R.unpack_bits(got, n)), (A.astype(int) @ B.astype(int)) > 0)


# ---------------------------------------------------------------------------
# lineage_gather: batched CSR probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_rows,n_cols,nnz,max_deg", [
    (4, 5, 6, 3), (16, 16, 40, 8), (100, 50, 300, 16), (33, 7, 90, 33),
])
def test_lineage_gather_sweep(n_rows, n_cols, nnz, max_deg):
    rng = np.random.default_rng(nnz)
    rows = np.sort(rng.integers(0, n_rows, nnz)).astype(np.int32)
    cols = rng.integers(0, n_cols, nnz).astype(np.int32)
    counts = np.bincount(rows, minlength=n_rows)
    row_ptr = np.zeros(n_rows + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    md = int(max(counts.max(), 1))
    md = min(md, max_deg) if max_deg else md
    queries = rng.integers(0, n_rows, 37).astype(np.int32)
    got = K.lineage_gather(row_ptr, cols, queries, max_deg=md,
                           block_q=16, interpret=True)
    colp = jnp.concatenate([jnp.asarray(cols), jnp.full((md,), -1, jnp.int32)])
    want = R.lineage_gather_ref(jnp.asarray(queries), jnp.asarray(row_ptr),
                                colp, max_deg=md)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lineage_gather_matches_host_csr():
    from repro.core.provtensor import CSR
    rng = np.random.default_rng(5)
    rows = rng.integers(0, 20, 60)
    cols = rng.integers(0, 30, 60)
    csr = CSR.from_pairs(rows, cols, 20, 30)
    qs = np.arange(20, dtype=np.int32)
    md = int(np.diff(csr.row_ptr).max())
    got = np.asarray(K.lineage_gather(csr.row_ptr, csr.col_idx, qs,
                                      max_deg=md, block_q=4, interpret=True))
    for i, q in enumerate(qs):
        want = sorted(csr.neighbors(q).tolist())
        have = sorted(x for x in got[i].tolist() if x >= 0)
        assert have == want


# ---------------------------------------------------------------------------
# bitset_rank: batched inclusive rank
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_bits", [1, 31, 32, 33, 100, 1000])
def test_bitset_rank_sweep(n_bits):
    from repro.core.schema import Bitset
    rng = np.random.default_rng(n_bits)
    bits = rng.random(n_bits) < 0.4
    b = Bitset.from_bits(bits)
    pos = np.concatenate([np.arange(n_bits), [-1]]).astype(np.int32)
    got = np.asarray(K.bitset_rank(b.words, pos, block_q=8, interpret=True))
    want = np.array([b.rank(int(p)) if p >= 0 else 0 for p in pos])
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.booleans(), min_size=1, max_size=200), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_bitset_rank_property(bits, seed):
    from repro.core.schema import Bitset
    b = Bitset.from_bits(bits)
    rng = np.random.default_rng(seed)
    pos = rng.integers(-1, len(bits), 17).astype(np.int32)
    got = np.asarray(K.bitset_rank(b.words, pos, block_q=8, interpret=True))
    cum = np.concatenate([[0], np.cumsum(np.asarray(bits, int))])
    want = np.where(pos >= 0, cum[np.clip(pos, -1, len(bits) - 1) + 1], 0)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# batched_walk: fused K-hop record probe (ROADMAP item 4)
# ---------------------------------------------------------------------------
def _random_chain(rng, n0, hops, density=0.1):
    """Packed planes for a K-hop chain with NON-multiple-of-32 random dims."""
    dims = [n0] + [int(rng.integers(5, 90)) for _ in range(hops)]
    planes = [R.pack_bits(jnp.asarray(
        rng.random((dims[j], dims[j + 1])) < density)) for j in range(hops)]
    return dims, planes


@pytest.mark.parametrize("hops", [1, 2, 3, 4, 5, 6])
@pytest.mark.parametrize("density", [0.02, 0.25])
def test_batched_walk_pallas_parity(hops, density):
    rng = np.random.default_rng(hops * 100 + int(density * 100))
    n0 = int(rng.integers(5, 90))  # deliberately not a multiple of 32
    dims, planes = _random_chain(rng, n0, hops, density)
    B = 7
    mask = R.pack_bits(jnp.asarray(rng.random((B, n0)) < 0.3))
    got_out, got_cnt = K.batched_walk(mask, planes, use_pallas=True,
                                      interpret=True, block_b=4, block_k=64)
    want_out, want_cnt = R.batched_walk_ref(mask, planes)
    np.testing.assert_array_equal(np.asarray(got_out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(want_cnt))
    # counts really are the per-hop frontier sizes
    assert np.asarray(got_cnt).shape == (hops, B)


def test_batched_walk_empty_mask():
    rng = np.random.default_rng(3)
    _, planes = _random_chain(rng, 40, 3)
    mask = jnp.zeros((5, 2), dtype=jnp.uint32)  # 40 cols -> 2 words, all zero
    out, cnt = K.batched_walk(mask, planes, use_pallas=True, interpret=True,
                              block_b=4, block_k=64)
    assert not np.asarray(out).any()
    assert not np.asarray(cnt).any()


def test_batched_walk_oracle_guard_matches_pallas():
    """use_pallas=None resolves to the oracle off-TPU and must answer
    byte-identically to the interpret-mode Pallas kernel."""
    rng = np.random.default_rng(11)
    _, planes = _random_chain(rng, 33, 4)
    mask = R.pack_bits(jnp.asarray(rng.random((6, 33)) < 0.3))
    o_out, o_cnt = K.batched_walk(mask, planes, use_pallas=None)
    p_out, p_cnt = K.batched_walk(mask, planes, use_pallas=True,
                                  interpret=True, block_b=2, block_k=32)
    np.testing.assert_array_equal(np.asarray(o_out), np.asarray(p_out))
    np.testing.assert_array_equal(np.asarray(o_cnt), np.asarray(p_cnt))


def test_batched_walk_chain_mismatch_raises():
    rng = np.random.default_rng(0)
    a = R.pack_bits(jnp.asarray(rng.random((4, 40)) < 0.2))
    bad = R.pack_bits(jnp.asarray(rng.random((90, 10)) < 0.2))  # 90 != 40
    with pytest.raises(ValueError):
        K.batched_walk(a, [bad])
    with pytest.raises(ValueError):
        K.batched_walk(a, [])


def test_batched_walk_launch_reduction():
    """The tentpole contract: a K-hop batched probe is ONE dispatch fused
    vs exactly 3 per hop unfused, with byte-identical results."""
    rng = np.random.default_rng(21)
    hops = 5
    _, planes = _random_chain(rng, 50, hops)
    mask = R.pack_bits(jnp.asarray(rng.random((8, 50)) < 0.2))
    K.reset_launch_counts()
    f_out, f_cnt = K.batched_walk(mask, planes, use_pallas=None)
    assert K.launch_counts() == {"batched_walk": 1}
    K.reset_launch_counts()
    u_out, u_cnt = K.batched_walk_unfused(mask, planes, use_pallas=None)
    lc = K.launch_counts()
    assert sum(lc.values()) == 3 * hops, lc
    assert lc == {"bitmatmul": hops, "bitset_rank": hops,
                  "lineage_gather": hops}
    np.testing.assert_array_equal(np.asarray(f_out), np.asarray(u_out))
    np.testing.assert_array_equal(np.asarray(f_cnt), np.asarray(u_cnt))
    K.reset_launch_counts()


@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_batched_walk_property(hops, seed):
    rng = np.random.default_rng(seed)
    n0 = int(rng.integers(1, 70))
    dims, planes = _random_chain(rng, n0, hops)
    B = int(rng.integers(1, 9))
    mask = R.pack_bits(jnp.asarray(rng.random((B, n0)) < 0.3))
    got_out, got_cnt = K.batched_walk(mask, planes, use_pallas=True,
                                      interpret=True, block_b=2, block_k=32)
    want_out, want_cnt = R.batched_walk_ref(mask, planes)
    np.testing.assert_array_equal(np.asarray(got_out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(want_cnt))


# ---------------------------------------------------------------------------
# fused walk over real pipelines: query-layer + session routing parity
# ---------------------------------------------------------------------------
def test_fused_walk_record_masks_parity_pipegen():
    """Fused walker vs the full per-op walkers on randomized pipelines
    (outer joins / appends with -1 sentinels included), both directions.
    None (non-linear subgraph) is a legal answer; a mask is not allowed to
    disagree."""
    import pipegen
    from repro.core import query as Q

    fused_hits = 0
    for seed in range(12):
        idx, sink, rng = pipegen.random_pipeline(seed)
        n_src = idx.datasets["src"].n_rows
        n_dst = idx.datasets[sink].n_rows
        B = 4
        rows_b = rng.random((B, n_src)) < 0.3
        ref_m = Q.forward_record_masks_batch(idx, "src", rows_b).get(
            sink, np.zeros((B, n_dst), bool))
        got = Q.fused_walk_record_masks_batch(idx, "src", sink, rows_b, "fwd")
        if got is not None:
            fused_hits += 1
            np.testing.assert_array_equal(got, ref_m)
        rows_d = rng.random((B, n_dst)) < 0.3
        refb = Q.backward_record_masks_batch(idx, sink, rows_d).get(
            "src", np.zeros((B, n_src), bool))
        gotb = Q.fused_walk_record_masks_batch(idx, sink, "src", rows_d, "bwd")
        if gotb is not None:
            np.testing.assert_array_equal(gotb, refb)
    assert fused_hits > 0  # the linearity audit must accept real chains


def test_fused_walk_rejects_diamond():
    """path_tensors picks ONE path through a diamond; the linearity audit
    must refuse to fuse it (the full walker sums both branches)."""
    import pipegen
    from repro.core import query as Q

    idx, sink = pipegen.diamond_pipeline(0)
    n = idx.datasets["src"].n_rows
    rows = np.zeros((2, n), dtype=bool)
    rows[:, 0] = True
    assert Q.fused_walk_record_masks_batch(idx, "src", sink, rows, "fwd") is None


def test_fused_walk_identity_pair():
    import pipegen
    from repro.core import query as Q

    idx, sink, rng = pipegen.random_pipeline(1)
    n = idx.datasets["src"].n_rows
    rows = rng.random((3, n)) < 0.4
    got = Q.fused_walk_record_masks_batch(idx, "src", "src", rows, "fwd")
    np.testing.assert_array_equal(got, rows)


def test_session_fused_walk_routing_parity():
    """QuerySession(fused_walk=True) answers byte-identically to the plain
    walk and bumps the fused_walk counter when the chain fuses."""
    import pipegen
    from repro.provenance import prov
    from repro.provenance.session import QuerySession

    for seed in (0, 5, 9):
        idx, sink, rng = pipegen.random_pipeline(seed)
        n = idx.datasets["src"].n_rows
        rows_b = rng.random((4, n)) < 0.3
        s_on = QuerySession(idx, fused_walk=True, use_hopcache=False)
        s_off = QuerySession(idx, fused_walk=False, use_hopcache=False)
        plan = prov(idx).source("src").rows_batch(rows_b).forward().to(sink).plan()
        got = s_on.run(plan)
        want = s_off.run(plan)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        assert s_off.counters["fused_walk"] == 0


# ---------------------------------------------------------------------------
# calibration: measure -> fit -> persist -> load round-trip
# ---------------------------------------------------------------------------
def test_calibration_round_trip(tmp_path):
    from repro.core import calibrate, costmodel

    path = str(tmp_path / "calibration.json")
    try:
        fitted = calibrate.calibrate(path=path, quick=True, install=False)
        assert fitted.source == "calibrated"
        assert fitted.c_word_op > 0 and fitted.c_spmm_flop > 0
        assert 1e-4 <= fitted.density_threshold <= 0.5
        loaded = calibrate.load_constants(path)
        assert loaded is not None
        assert loaded.device == fitted.device
        assert loaded.c_word_op == pytest.approx(fitted.c_word_op)
        assert loaded.density_threshold == pytest.approx(fitted.density_threshold)
        prov = loaded.provenance()
        assert prov["source"] == "calibrated"
        assert prov["path"] == str(tmp_path / "calibration.json")

        # installing calibrated constants moves the router's crossover
        costmodel.set_constants(loaded)
        assert costmodel.active_constants().density_threshold == \
            pytest.approx(fitted.density_threshold)
        assert costmodel.pick_backend(loaded.density_threshold * 2) == "bitplane"
        assert costmodel.pick_backend(loaded.density_threshold / 2) == "csr"
    finally:
        costmodel.reset_constants()


def test_calibration_absent_file_keeps_defaults(tmp_path, monkeypatch):
    """No calibration file -> bit-for-bit default constants and routing."""
    from repro.core import calibrate, costmodel

    monkeypatch.setenv("REPRO_CALIBRATION", str(tmp_path / "nope.json"))
    try:
        costmodel.reset_constants()
        costmodel.maybe_load_calibration()
        c = costmodel.active_constants()
        assert c.source == "default"
        assert c.density_threshold == costmodel.DENSITY_THRESHOLD
        assert c.c_word_op == costmodel.C_WORD_OP
        assert costmodel.constants_provenance()["source"] == "default"
        assert calibrate.load_constants(str(tmp_path / "nope.json")) is None
    finally:
        costmodel.reset_constants()


def test_calibration_autoload_via_costmodel(tmp_path, monkeypatch):
    """CostModel.__init__ autoloads $REPRO_CALIBRATION once per process."""
    import pipegen
    from repro.core import calibrate, costmodel
    from repro.core.costmodel import CostModel

    path = str(tmp_path / "calibration.json")
    monkeypatch.setenv("REPRO_CALIBRATION", path)
    try:
        fitted = calibrate.calibrate(path=path, quick=True, install=False)
        costmodel.reset_constants()  # re-arm the once-per-process autoload
        idx, sink, rng = pipegen.random_pipeline(2)
        CostModel(idx)
        act = costmodel.active_constants()
        assert act.source == "calibrated"
        assert act.c_word_op == pytest.approx(fitted.c_word_op)
    finally:
        costmodel.reset_constants()
