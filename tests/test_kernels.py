"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

All three kernels are integer/boolean — assertions are EXACT equality.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops as K
from repro.kernels import ref as R


# ---------------------------------------------------------------------------
# bitmatmul: (OR,AND) boolean-semiring matmul on packed bitplanes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 32, 32), (7, 33, 9), (40, 70, 50),
    (64, 256, 128), (130, 300, 257),
])
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_bitmatmul_sweep(m, k, n, density):
    rng = np.random.default_rng(m * 1000 + k + n)
    A = rng.random((m, k)) < density
    B = rng.random((k, n)) < density
    a_b = R.pack_bits(jnp.asarray(A))
    b_b = R.pack_bits(jnp.asarray(B))
    got = K.bitmatmul(a_b, b_b, block_m=8, block_nw=8, block_k=32, interpret=True)
    want = R.bitmatmul_ref(a_b, b_b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # semantic check against dense boolean matmul
    dense = R.unpack_bits(want, n)
    np.testing.assert_array_equal(np.asarray(dense),
                                  (A.astype(int) @ B.astype(int)) > 0)


def test_bitmatmul_identity():
    n = 96
    eye = np.eye(n, dtype=bool)
    rng = np.random.default_rng(0)
    Bm = rng.random((n, 40)) < 0.2
    a_b = R.pack_bits(jnp.asarray(eye))
    b_b = R.pack_bits(jnp.asarray(Bm))
    got = K.bitmatmul(a_b, b_b, block_m=8, block_nw=8, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(R.unpack_bits(got, 40)), Bm)


@given(st.integers(1, 40), st.integers(1, 70), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bitmatmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = rng.random((m, k)) < 0.2
    B = rng.random((k, n)) < 0.2
    got = K.bitmatmul(R.pack_bits(jnp.asarray(A)), R.pack_bits(jnp.asarray(B)),
                      block_m=8, block_nw=8, block_k=32, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(R.unpack_bits(got, n)), (A.astype(int) @ B.astype(int)) > 0)


# ---------------------------------------------------------------------------
# lineage_gather: batched CSR probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_rows,n_cols,nnz,max_deg", [
    (4, 5, 6, 3), (16, 16, 40, 8), (100, 50, 300, 16), (33, 7, 90, 33),
])
def test_lineage_gather_sweep(n_rows, n_cols, nnz, max_deg):
    rng = np.random.default_rng(nnz)
    rows = np.sort(rng.integers(0, n_rows, nnz)).astype(np.int32)
    cols = rng.integers(0, n_cols, nnz).astype(np.int32)
    counts = np.bincount(rows, minlength=n_rows)
    row_ptr = np.zeros(n_rows + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    md = int(max(counts.max(), 1))
    md = min(md, max_deg) if max_deg else md
    queries = rng.integers(0, n_rows, 37).astype(np.int32)
    got = K.lineage_gather(row_ptr, cols, queries, max_deg=md,
                           block_q=16, interpret=True)
    colp = jnp.concatenate([jnp.asarray(cols), jnp.full((md,), -1, jnp.int32)])
    want = R.lineage_gather_ref(jnp.asarray(queries), jnp.asarray(row_ptr),
                                colp, max_deg=md)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lineage_gather_matches_host_csr():
    from repro.core.provtensor import CSR
    rng = np.random.default_rng(5)
    rows = rng.integers(0, 20, 60)
    cols = rng.integers(0, 30, 60)
    csr = CSR.from_pairs(rows, cols, 20, 30)
    qs = np.arange(20, dtype=np.int32)
    md = int(np.diff(csr.row_ptr).max())
    got = np.asarray(K.lineage_gather(csr.row_ptr, csr.col_idx, qs,
                                      max_deg=md, block_q=4, interpret=True))
    for i, q in enumerate(qs):
        want = sorted(csr.neighbors(q).tolist())
        have = sorted(x for x in got[i].tolist() if x >= 0)
        assert have == want


# ---------------------------------------------------------------------------
# bitset_rank: batched inclusive rank
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_bits", [1, 31, 32, 33, 100, 1000])
def test_bitset_rank_sweep(n_bits):
    from repro.core.schema import Bitset
    rng = np.random.default_rng(n_bits)
    bits = rng.random(n_bits) < 0.4
    b = Bitset.from_bits(bits)
    pos = np.concatenate([np.arange(n_bits), [-1]]).astype(np.int32)
    got = np.asarray(K.bitset_rank(b.words, pos, block_q=8, interpret=True))
    want = np.array([b.rank(int(p)) if p >= 0 else 0 for p in pos])
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.booleans(), min_size=1, max_size=200), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_bitset_rank_property(bits, seed):
    from repro.core.schema import Bitset
    b = Bitset.from_bits(bits)
    rng = np.random.default_rng(seed)
    pos = rng.integers(-1, len(bits), 17).astype(np.int32)
    got = np.asarray(K.bitset_rank(b.words, pos, block_q=8, interpret=True))
    cum = np.concatenate([[0], np.cumsum(np.asarray(bits, int))])
    want = np.where(pos >= 0, cum[np.clip(pos, -1, len(bits) - 1) + 1], 0)
    np.testing.assert_array_equal(got, want)
