"""Serving tier: micro-batch fusion, admission control, tenant scoping.

Four pillars:

* **Fusion parity** — answers through the tier (fused ``run_many`` passes,
  any surface: threaded burst, per-request, async) are byte-identical to a
  plain sequential ``session.run`` loop, and the tier counters prove the
  requests actually rode fused batches.
* **Admission** — the bounded front door sheds load with TYPED errors
  (:class:`QueueFullError`, :class:`TenantOverloadError`,
  :class:`TierClosedError`), backpressures with ``wait=True``, caps
  per-tenant in-flight, and scopes tenants to capability ref sets
  (:class:`CapabilityError` at admission, before a bucket ever sees the
  plan).
* **Lifecycle** — ``shutdown(drain=True)`` answers everything already
  admitted; ``drain=False`` rejects it; a stopped tier refuses new work.
* **Engine recording** — ``ServeEngine`` provenance invariants the tier
  rests on: gid collision looping, lineage-vs-hand-built-plan parity,
  bare-ref qualification through ``as_backend()``, the ``prov_index=``
  deprecation warning attributing to the CALLER's file, and seeded
  non-greedy sampling.

pytest-timeout guards these in CI; locally (where the plugin may be
absent) an autouse SIGALRM fixture aborts a wedged async test instead of
hanging the whole suite.
"""
import asyncio
import signal
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import CapabilityError, prov
from repro.serve import (
    QueueFullError,
    ServingTier,
    TenantOverloadError,
    TenantScope,
    TierClosedError,
)
from repro.serve import engine as serve_engine
from repro.serve.engine import GenerationResult, ServeEngine

DEADLINE_S = 120

# engages pytest-timeout where installed (CI); elsewhere the marker is
# inert and the SIGALRM fixture below is the guard
pytestmark = pytest.mark.timeout(DEADLINE_S)


@pytest.fixture(autouse=True)
def _deadline():
    """Abort (don't hang) a wedged serving test when pytest-timeout is not
    installed.  SIGALRM only works on the main thread of a POSIX process;
    anywhere else this is a no-op and the CI plugin is the only guard."""
    if (not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def _boom(signum, frame):
        raise TimeoutError(f"serving test exceeded {DEADLINE_S}s deadline")

    old = signal.signal(signal.SIGALRM, _boom)
    signal.alarm(DEADLINE_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------
def _chain_index(seed=0, n=48):
    """raw -> scaled -> sink chain plus a SIBLING branch off raw (not an
    ancestor of sink — the out-of-scope ref for capability tests)."""
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex(f"serving-test-{seed}")
    s = track(Table.from_columns({
        "k": np.arange(n, dtype=np.float32),
        "x": rng.normal(size=n).astype(np.float32),
    }), idx, "raw")
    scaled = s.value_transform("x", "scale", factor=2.0)
    sibling = s.value_transform("x", "scale", factor=-1.0)
    sink = scaled.filter_rows(rng.random(n) > 0.25)
    sink.mark_sink()
    return idx, sink.dataset_id, sibling.dataset_id


def _mixed_plans(idx, sink, n_plans, seed=1):
    """Round-robin Q1/Q2/Q4 single-probe plans — three fuse keys."""
    rng = np.random.default_rng(seed)
    n_raw = idx.datasets["raw"].n_rows
    n_sink = idx.datasets[sink].n_rows
    plans = []
    for i in range(n_plans):
        if i % 3 == 0:
            plans.append(prov(idx).source("raw")
                         .rows([int(rng.integers(n_raw))])
                         .forward().to(sink).plan())
        elif i % 3 == 1:
            plans.append(prov(idx).source(sink)
                         .rows([int(rng.integers(n_sink))])
                         .backward().to("raw").plan())
        else:
            plans.append(prov(idx).source(sink)
                         .rows([int(rng.integers(n_sink))]).attrs([0])
                         .backward().to("raw").plan())
    return plans


def _assert_parity(ref, got):
    assert len(ref) == len(got)
    for a, b in zip(ref, got):
        la = a if isinstance(a, list) else [a]
        lb = b if isinstance(b, list) else [b]
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _poll(pred, timeout=30.0, what="condition"):
    t0 = time.perf_counter()
    while not pred():
        if time.perf_counter() - t0 > timeout:
            raise TimeoutError(f"{what} not reached within {timeout}s")
        time.sleep(0.005)


class _GatedBackend:
    """``run_many`` blocks until the gate opens — keeps admitted requests
    in flight so the admission bounds become observable from a test."""

    def __init__(self, session):
        self.session = session
        self.gate = threading.Event()
        self.calls = 0

    def run_many(self, plans):
        self.gate.wait(DEADLINE_S)
        self.calls += 1
        return self.session.run_many(plans)


# ===========================================================================
# Fusion parity + batching
# ===========================================================================
def test_tier_burst_parity_and_fused_batches():
    idx, sink, _ = _chain_index()
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 48)
    ref = [sess.run(p) for p in plans]
    with ServingTier(sess, max_batch=16, max_wait_ms=5.0) as tier:
        futs = tier.submit_many_nowait("burst", plans)
        got = [f.result(timeout=60) for f in futs]
        st = tier.stats()["tier"]
    _assert_parity(ref, got)
    # the requests actually fused: 48 plans over 3 fuse keys, 16-wide caps
    assert st["submitted"] == st["completed"] == 48
    assert st["batched_plans"] == 48
    assert st["batches"] < 48
    assert st["max_batch_seen"] == 16
    assert st["flush_full"] >= 3


def test_tier_single_probe_timer_flush():
    idx, sink, _ = _chain_index()
    sess = idx.session()
    plan = _mixed_plans(idx, sink, 1)[0]
    with ServingTier(sess, max_batch=64, max_wait_ms=1.0) as tier:
        got = tier.submit_sync("lone", plan, timeout=30)
        st = tier.stats()["tier"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(sess.run(plan)))
    assert st["flush_timer"] == 1 and st["flush_full"] == 0


def test_tier_async_surface_parity():
    idx, sink, _ = _chain_index(seed=2)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 12, seed=3)
    ref = [sess.run(p) for p in plans]

    async def main():
        tier = ServingTier(sess, max_batch=4, max_wait_ms=1.0)
        got = await asyncio.gather(
            *[tier.submit(f"t{i % 2}", p) for i, p in enumerate(plans)])
        await tier.aclose()
        return got

    _assert_parity(ref, asyncio.run(main()))


# ===========================================================================
# Admission: bounds, backpressure, typed rejection
# ===========================================================================
def test_queue_full_sheds_typed_then_recovers():
    idx, sink, _ = _chain_index(seed=4)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 6, seed=5)
    backend = _GatedBackend(sess)
    tier = ServingTier(backend, max_batch=1, max_wait_ms=0.1,
                       max_queue=4).start()
    try:
        futs = [tier.submit_nowait("t", p) for p in plans[:4]]
        _poll(lambda: tier.admission.pending == 4, what="queue fill")
        with pytest.raises(QueueFullError):
            tier.submit_nowait("t", plans[4]).result(timeout=30)
        assert tier.admission.counters["rejected_queue_full"] == 1
        backend.gate.set()      # drain the gate: admitted work completes
        _assert_parity([sess.run(p) for p in plans[:4]],
                       [f.result(timeout=60) for f in futs])
        # capacity freed: the same submission is admitted now
        np.testing.assert_array_equal(
            np.asarray(tier.submit_sync("t", plans[4], timeout=30)),
            np.asarray(sess.run(plans[4])))
    finally:
        backend.gate.set()
        tier.shutdown()


def test_wait_turns_rejection_into_backpressure():
    idx, sink, _ = _chain_index(seed=6)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 3, seed=7)
    backend = _GatedBackend(sess)
    tier = ServingTier(backend, max_batch=1, max_wait_ms=0.1,
                       max_queue=2).start()
    try:
        futs = [tier.submit_nowait("t", p) for p in plans[:2]]
        _poll(lambda: tier.admission.pending == 2, what="queue fill")
        waiting = tier.submit_nowait("t", plans[2], wait=True)
        time.sleep(0.05)
        assert not waiting.done()       # parked, NOT rejected
        backend.gate.set()
        np.testing.assert_array_equal(np.asarray(waiting.result(timeout=60)),
                                      np.asarray(sess.run(plans[2])))
        for f in futs:
            f.result(timeout=60)
        assert tier.admission.counters["rejected_queue_full"] == 0
    finally:
        backend.gate.set()
        tier.shutdown()


def test_tenant_inflight_cap_isolates_tenants():
    idx, sink, _ = _chain_index(seed=8)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 4, seed=9)
    backend = _GatedBackend(sess)
    tier = ServingTier(backend, max_batch=1, max_wait_ms=0.1,
                       max_queue=16).start()
    tier.register_tenant("capped", max_inflight=2)
    try:
        futs = [tier.submit_nowait("capped", p) for p in plans[:2]]
        _poll(lambda: tier.admission.pending == 2, what="cap fill")
        with pytest.raises(TenantOverloadError):
            tier.submit_nowait("capped", plans[2]).result(timeout=30)
        # the shed request never touched GLOBAL capacity: another tenant
        # with plenty of queue headroom is admitted immediately
        other = tier.submit_nowait("other", plans[3])
        _poll(lambda: tier.admission.pending == 3, what="other admitted")
        backend.gate.set()
        for f in futs + [other]:
            f.result(timeout=60)
        st = tier.admission.stats()
        assert st["rejected_tenant_cap"] == 1
        assert st["tenants"]["capped"]["rejected"] == 1
        assert st["tenants"]["other"]["rejected"] == 0
    finally:
        backend.gate.set()
        tier.shutdown()


# ===========================================================================
# Capability scoping
# ===========================================================================
def test_tenant_scope_denies_out_of_scope_refs_at_admission():
    idx, sink, sibling = _chain_index(seed=10)
    sess = idx.session()
    # the tenant's capability: the sink's export — its ancestor closure
    # (raw, scaled, sink), which excludes the sibling branch
    handle = idx.export(sink)
    tier = ServingTier(sess, max_batch=4, max_wait_ms=1.0,
                       allow_unregistered=False).start()
    tier.register_tenant("scoped", handle)
    tier.register_tenant("operator")        # unrestricted
    try:
        in_scope = (prov(idx).source(sink).rows([0])
                    .backward().to("raw").plan())
        out_scope = (prov(idx).source(sibling).rows([0])
                     .backward().to("raw").plan())
        np.testing.assert_array_equal(
            np.asarray(tier.submit_sync("scoped", in_scope, timeout=30)),
            np.asarray(sess.run(in_scope)))
        with pytest.raises(CapabilityError):
            tier.submit_sync("scoped", out_scope, timeout=30)
        # same plan, unrestricted tenant: served
        np.testing.assert_array_equal(
            np.asarray(tier.submit_sync("operator", out_scope, timeout=30)),
            np.asarray(sess.run(out_scope)))
        # unknown tenants are a capability failure on a closed-roster tier
        with pytest.raises(CapabilityError):
            tier.submit_sync("stranger", in_scope, timeout=30)
        st = tier.stats()["admission"]
        assert st["capability_denied"] == 1
        assert st["tenants"]["scoped"]["denied"] == 1
    finally:
        tier.shutdown()
    assert repr(TenantScope(["a", "b"])) == "TenantScope(2 refs)"


def test_submit_many_isolates_per_plan_rejection():
    idx, sink, sibling = _chain_index(seed=11)
    sess = idx.session()
    good = _mixed_plans(idx, sink, 4, seed=12)
    bad = prov(idx).source(sibling).rows([0]).backward().to("raw").plan()
    plans = good[:2] + [bad] + good[2:]
    with ServingTier(sess, max_batch=8, max_wait_ms=1.0) as tier:
        tier.register_tenant("scoped", idx.export(sink))
        futs = tier.submit_many_nowait("scoped", plans)
        with pytest.raises(CapabilityError):
            futs[2].result(timeout=30)
        _assert_parity([sess.run(p) for p in good],
                       [f.result(timeout=60)
                        for f in futs[:2] + futs[3:]])


# ===========================================================================
# Lifecycle: drain, reject, closed
# ===========================================================================
def test_shutdown_drain_answers_everything_admitted():
    idx, sink, _ = _chain_index(seed=13)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 9, seed=14)
    # huge max_wait + wide batches: everything is still sitting in buckets
    # when shutdown begins, so ONLY the drain path can answer it
    tier = ServingTier(sess, max_batch=64, max_wait_ms=60_000.0).start()
    futs = tier.submit_many_nowait("t", plans)
    _poll(lambda: tier.admission.pending == len(plans), what="bucketed")
    tier.shutdown(drain=True)
    _assert_parity([sess.run(p) for p in plans],
                   [f.result(timeout=1) for f in futs])
    st = tier.stats()
    assert st["tier"]["flush_drain"] >= 1
    assert st["tier"]["completed"] == len(plans)
    assert st["admission"]["pending"] == 0
    with pytest.raises(TierClosedError):
        tier.submit_nowait("t", plans[0])


def test_shutdown_without_drain_rejects_queued():
    idx, sink, _ = _chain_index(seed=15)
    sess = idx.session()
    plans = _mixed_plans(idx, sink, 6, seed=16)
    tier = ServingTier(sess, max_batch=64, max_wait_ms=60_000.0).start()
    futs = tier.submit_many_nowait("t", plans)
    _poll(lambda: tier.admission.pending == len(plans), what="bucketed")
    tier.shutdown(drain=False)
    for f in futs:
        with pytest.raises(TierClosedError):
            f.result(timeout=1)
    st = tier.stats()
    assert st["tier"]["failed"] == len(plans)
    assert st["admission"]["pending"] == 0  # releases balanced the admits


def test_backend_failure_fans_out_and_releases():
    class _Broken:
        def run_many(self, plans):
            raise RuntimeError("backend exploded")

    idx, sink, _ = _chain_index(seed=17)
    plans = _mixed_plans(idx, sink, 3, seed=18)
    with pytest.raises(RuntimeError):
        # __exit__ on the exception path shuts down WITHOUT draining
        with ServingTier(_Broken(), max_batch=1, max_wait_ms=0.1) as tier:
            futs = [tier.submit_nowait("t", p) for p in plans]
            for f in futs:
                with pytest.raises(RuntimeError, match="backend exploded"):
                    f.result(timeout=30)
            assert tier.stats()["tier"]["failed"] == len(plans)
            assert tier.admission.pending == 0
            raise RuntimeError("leave via the exception path")


# ===========================================================================
# ServeEngine recording invariants + tier integration
# ===========================================================================
def _recorded_engine(b=4):
    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:tiertest")
    r = GenerationResult(tokens=np.zeros((b, 2), np.int32),
                         request_ids=np.arange(b))
    engine._record_generation(r, prompt_len=2, n_new=2, request_source=None)
    return engine, r


def test_record_gid_collision_loops_to_free_slot():
    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:gid")
    # an earlier generation (or a sibling engine on a shared index) already
    # owns slot 0 — recording must skip it, not collide
    engine.prov.add_source("responses@0", Table.from_columns(
        {"z": np.zeros(2, np.float32)}))
    r = GenerationResult(tokens=np.zeros((3, 2), np.int32),
                         request_ids=np.arange(3))
    engine._record_generation(r, prompt_len=1, n_new=2, request_source=None)
    assert (r.request_dataset, r.response_dataset) == \
        ("requests@1", "responses@1")
    r2 = GenerationResult(tokens=np.zeros((2, 2), np.int32),
                          request_ids=np.arange(2))
    engine._record_generation(r2, prompt_len=1, n_new=2, request_source=None)
    assert r2.response_dataset == "responses@2"
    # both recordings answer lineage independently
    np.testing.assert_array_equal(engine.response_lineage(r, rows=[2]), [2])
    np.testing.assert_array_equal(engine.response_lineage(r2, rows=[0]), [0])


def test_response_lineage_matches_hand_built_plans():
    engine, r = _recorded_engine()
    got = engine.response_lineage(r, rows=[0, 2])
    ref = (prov(engine.prov).source(r.response_dataset).rows([0, 2])
           .backward().to(r.request_dataset).run(engine.session))
    np.testing.assert_array_equal(got, ref)
    batch = engine.response_lineage_batch(r, [[0], [1], [2, 3]])
    refs = engine.session.run_many([
        prov(engine.prov).source(r.response_dataset).rows(rows)
        .backward().to(r.request_dataset).plan()
        for rows in [[0], [1], [2, 3]]])
    _assert_parity(refs, batch)


def test_engine_backend_qualifies_bare_refs_through_tier():
    engine, r = _recorded_engine()
    backend = engine.as_backend()
    bare = (prov(engine.prov).source(r.response_dataset).rows([1])
            .backward().to(r.request_dataset).plan())
    prepared = backend.prepare(bare)
    assert prepared.source == f"serve/{r.response_dataset}"
    assert prepared.target == f"serve/{r.request_dataset}"
    qualified = (prov(engine.catalog)
                 .source(f"serve/{r.response_dataset}").rows([1])
                 .backward().to(f"serve/{r.request_dataset}").plan())
    with ServingTier(backend, max_batch=4, max_wait_ms=1.0) as tier:
        got_bare = tier.submit_sync("a", bare, timeout=30)
        got_qual = tier.submit_sync("b", qualified, timeout=30)
        st = tier.stats()
    ref = engine.response_lineage(r, rows=[1])
    np.testing.assert_array_equal(np.asarray(got_bare), ref)
    np.testing.assert_array_equal(np.asarray(got_qual), ref)
    assert "backend" in st     # the engine backend exposes session stats


def test_prov_index_deprecation_attributes_callers_file():
    prep = ProvenanceIndex("prep-warnfile")
    track(Table.from_columns({"k": np.arange(3, dtype=np.float32)}),
          prep, "raw").mark_sink()
    serve_engine._DEPRECATION_WARNED.discard("prov_index")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        e = object.__new__(ServeEngine)
        e._init_provenance("serve:warnfile", prov_index=prep)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "prov_index" in str(w.message)]
    assert len(dep) == 1
    # the computed stacklevel lands on THIS file (the deprecated call
    # site), not an engine-internal frame
    assert dep[0].filename == __file__


def test_generate_sampling_seeded_and_in_vocab():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import get_model

    cfg = get_smoke_config("olmo-1b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=4 + 3, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (2, 4)).astype(np.int32)
    a = engine.generate(prompts, n_new=3, greedy=False, sample_seed=7,
                        record_provenance=True)
    b = engine.generate(prompts, n_new=3, greedy=False, sample_seed=7)
    # seeded sampling is deterministic — the reproducibility contract the
    # provenance record rests on
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.dtype == np.int32 and a.tokens.shape == (2, 3)
    assert int(a.tokens.min()) >= 0 and int(a.tokens.max()) < cfg.vocab
    np.testing.assert_array_equal(engine.response_lineage(a, rows=[1]), [1])
