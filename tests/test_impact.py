"""Impact-analysis subsystem: erasure closure, RecomputePlan ordering,
hop-cache/cross-relation invalidation, what-if replay exactness, federated
erasure, and the serving-tier entry point."""
import numpy as np
import pytest

import pipegen
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import (
    FederationError,
    apply_invalidations,
    erasure_plan,
    prov,
    whatif_replay,
)
from repro.provenance.catalog import qualify

SEEDS = list(range(6))


# ---------------------------------------------------------------------------
# Erasure closure: batched plan == per-row production queries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", SEEDS)
def test_erasure_closure_matches_per_row_queries(seed):
    idx, sink, rng = pipegen.random_pipeline(seed)
    n = idx.datasets["src"].n_rows
    rows = sorted(set(rng.integers(0, n, size=4).tolist()))
    plan = erasure_plan(idx, "src", rows)

    got = {i.ref: i.rows for i in plan.impacts}
    # naive reference: one forward record query per (erased row, dataset)
    for ds in idx.datasets:
        expected = np.zeros(idx.datasets[ds].n_rows, dtype=bool)
        for r in rows:
            hit = prov(idx).source("src").rows([r]).forward().to(ds).run()
            expected[np.asarray(hit, dtype=np.int64)] = True
        if expected.any():
            assert ds in got, ds
            np.testing.assert_array_equal(got[ds], np.flatnonzero(expected))
        else:
            assert ds not in got, ds
    # minimal: every listed impact is non-empty, sources lead the plan
    assert all(i.n_affected > 0 for i in plan.impacts)
    assert plan.impacts[0].ref == "src"
    np.testing.assert_array_equal(plan.impacts[0].rows, rows)


@pytest.mark.parametrize("seed", SEEDS)
def test_plan_topologically_ordered_and_classified(seed):
    idx, sink, rng = pipegen.random_pipeline(seed)
    plan = erasure_plan(idx, "src", [0, 1])
    order = [ds for ds in idx.datasets if ds in set(plan.affected)]
    assert list(plan.affected) == order  # registration order IS topological
    for i in plan.impacts:
        rec = idx.datasets[i.ref]
        assert i.materialized == rec.materialized
        assert i.is_sink == rec.is_sink
        assert i.n_rows == rec.n_rows
    assert "src" not in plan.rebuild
    assert all(idx.datasets[r].materialized for r in plan.rebuild)
    # rebuild targets carry a cost estimate when the cost model has a path
    if plan.rebuild:
        assert plan.est_total_ns >= 0.0


def test_erasure_rejects_bad_rows_and_unknown_source():
    idx, sink, _ = pipegen.random_pipeline(0)
    with pytest.raises(KeyError):
        erasure_plan(idx, "nope", [0])
    with pytest.raises(IndexError):
        erasure_plan(idx, "src", [idx.datasets["src"].n_rows + 5])


# ---------------------------------------------------------------------------
# Cache invalidation
# ---------------------------------------------------------------------------
def test_invalidation_drops_stale_entries_and_is_idempotent():
    idx, sink, rng = pipegen.random_pipeline(3)
    comp = idx.composed()
    comp.relation("src", sink)  # composes + caches every (src, mid) prefix
    assert comp.stats()["entries"] > 0
    plan = erasure_plan(idx, "src", [0])
    assert plan.invalidations
    assert {i.kind for i in plan.invalidations} == {"composed"}
    assert apply_invalidations(idx, plan) == len(plan.invalidations)
    assert comp.stats()["entries"] == 0
    # idempotent: a fresh plan over the emptied cache lists nothing
    plan2 = erasure_plan(idx, "src", [0])
    assert not plan2.invalidations
    assert apply_invalidations(idx, plan2) == 0
    # the cache still answers (recomposes from the intact tensors)
    hit = comp.q1_forward("src", [0], sink)
    ref = prov(idx).source("src").rows([0]).forward().to(sink).run()
    np.testing.assert_array_equal(hit, ref)


def test_invalidation_deletes_spilled_payloads(tmp_path):
    idx, sink, rng = pipegen.random_pipeline(3)
    # bitplane entries carry real bytes, so a tiny budget forces spills
    comp = idx.composed(memory_budget_bytes=512, spill=str(tmp_path),
                        backend="bitplane")
    comp.relation("src", sink)
    stats = comp.stats()
    assert stats["spilled_entries"] > 0  # tiny budget forces the spill tier
    n_payloads = comp._spill_store.stats()["entries"]
    plan = erasure_plan(idx, "src", [0])
    residencies = {i.residency for i in plan.invalidations}
    assert "spilled" in residencies
    apply_invalidations(idx, plan)
    assert comp.stats()["entries"] == 0
    assert comp.stats()["spilled_entries"] == 0
    assert comp._spill_store.stats()["entries"] < n_payloads


def test_invalidation_spares_unrelated_entries():
    idx = ProvenanceIndex("inv-spare")
    rng = np.random.default_rng(0)
    a = track(Table.from_columns({
        "k": np.arange(10, dtype=np.float32),
        "x": rng.normal(size=10).astype(np.float32)}), idx, "a")
    b = track(Table.from_columns({
        "k": np.arange(10, dtype=np.float32),
        "z": rng.normal(size=10).astype(np.float32)}), idx, "b")
    b2 = b.value_transform("z", "scale", factor=3.0)
    j = a.join(b2, on="k", how="inner").mark_sink()
    comp = idx.composed()
    comp.relation("a", j.dataset_id)
    comp.relation("b", b2.dataset_id)     # region {b, b2}: off the closure
    plan = erasure_plan(idx, "a", [0, 1])
    stale = {(i.src, i.dst) for i in plan.invalidations}
    assert ("b", b2.dataset_id) not in stale
    apply_invalidations(idx, plan)
    assert comp.residency("b", b2.dataset_id) == "ram"  # survived
    assert comp.residency("a", j.dataset_id) is None    # dropped


# ---------------------------------------------------------------------------
# What-if replay: exactness against a full pipeline re-run
# ---------------------------------------------------------------------------
def _whatif_pipeline(base: Table, keep: np.ndarray, ref1: Table, ref2: Table,
                     name: str):
    """A frozen-choice pipeline over every recomputable category: the same
    selections/params applied to the original and the perturbed base give
    the full-re-run ground truth what-if replay must match exactly."""
    idx = ProvenanceIndex(name)
    cur = track(base.copy(), idx, "src")
    cur = cur.value_transform("x", "scale", factor=2.0)
    cur = cur.filter_rows(keep)
    cur = cur.join(track(ref1.copy(), idx), on="k", how="outer")
    cur = cur.oversample(frac=0.4, seed=5, noise=0.1)
    cur = cur.append(track(ref2.copy(), idx))
    cur.mark_sink()
    return idx, cur.dataset_id, cur.table


def _assert_rows_equal(a: Table, b: Table, rows_a, rows_b):
    np.testing.assert_array_equal(a.null[rows_a], b.null[rows_b])
    da, db = a.data[rows_a], b.data[rows_b]
    ok = ~a.null[rows_a]
    np.testing.assert_allclose(da[ok], db[ok], rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("seed", range(4))
def test_whatif_replay_matches_full_rerun(seed):
    rng = np.random.default_rng(seed)
    n, K = 30, 8
    base = Table.from_columns({
        "k": rng.integers(0, K, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
    })
    keep = rng.random(n) < 0.7
    if not keep.any():
        keep[0] = True
    ref1 = Table.from_columns({
        "k": np.arange(K, dtype=np.float32),
        "z": rng.normal(size=K).astype(np.float32)})
    ref2 = Table.from_columns({
        "x": rng.normal(size=4).astype(np.float32),
        "z": rng.normal(size=4).astype(np.float32)})
    idx, sink, orig_sink = _whatif_pipeline(base, keep, ref1, ref2,
                                            f"wi{seed}")

    rows = sorted(set(rng.integers(0, n, size=3).tolist()))
    vals = rng.normal(size=len(rows)).astype(np.float32) * 10
    res = whatif_replay(idx, "src", rows, {"x": vals}, sink)

    # ground truth: the SAME frozen pipeline over the perturbed base
    patched = base.copy()
    patched.data[np.asarray(rows), patched.cid("x")] = vals
    _, _, full_sink = _whatif_pipeline(patched, keep, ref1, ref2,
                                       f"wi{seed}-rerun")

    # before == recorded run; after == full re-run, on exactly the
    # provenance-related sink rows
    _assert_rows_equal(res.before, orig_sink, np.arange(len(res.sink_rows)),
                       res.sink_rows)
    _assert_rows_equal(res.after, full_sink, np.arange(len(res.sink_rows)),
                       res.sink_rows)
    # completeness: every sink row OUTSIDE the closure is untouched by the
    # full re-run — the closure missed nothing
    outside = np.setdiff1d(np.arange(orig_sink.n_rows), res.sink_rows)
    _assert_rows_equal(orig_sink, full_sink, outside, outside)
    # and the replay recomputed ONLY provenance-related rows
    assert len(res.sink_rows) < orig_sink.n_rows
    # deltas line up with the changed mask
    deltas = res.row_deltas()
    assert len(deltas) == len(res.sink_rows)
    for i, d in enumerate(deltas):
        assert bool(d) == bool(res.changed[i])


def test_whatif_restores_recorded_state():
    rng = np.random.default_rng(1)
    idx, sink, _ = pipegen.random_pipeline(5)
    src_rec = idx.datasets["src"]
    before_tables = {ds: r.table for ds, r in idx.datasets.items()}
    before_x = src_rec.table.data.copy()
    whatif_replay(idx, "src", [0], {"x": [99.0]}, sink)
    for ds, r in idx.datasets.items():
        assert r.table is before_tables[ds]   # same objects, policy intact
    np.testing.assert_array_equal(src_rec.table.data, before_x)


def test_whatif_over_catalog_delegates_within_member():
    base, specs = pipegen.random_specs(2)
    catalog, refs, sink_ref = pipegen.build_federated(base, specs, 1)
    ingest = catalog.datasets["serve/ingest"]
    res = whatif_replay(catalog, "serve/ingest", [0], {"x": [50.0]},
                        sink_ref)
    assert res.source == "serve/ingest" and res.sink == sink_ref
    # value recomputation never crosses members
    with pytest.raises(FederationError, match="never leave"):
        whatif_replay(catalog, "prep/src", [0], {"x": [1.0]}, sink_ref)


# ---------------------------------------------------------------------------
# Federated erasure: closure across links == merged single-index closure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("cut", [1, 2])
def test_federated_erasure_matches_merged(seed, cut):
    base, specs = pipegen.random_specs(seed)
    merged, ids = pipegen.build_merged(base, specs)
    catalog, refs, sink_ref = pipegen.build_federated(base, specs, cut)
    ref_of = dict(zip(ids, refs))

    rows = [0, min(3, len(base["k"]) - 1)]
    mplan = erasure_plan(merged, "src", rows)
    fplan = erasure_plan(catalog, "prep/src", rows)
    f_by_ref = {i.ref: i.rows for i in fplan.impacts}

    boundary_ref = refs[cut]
    for i in mplan.impacts:
        if i.ref not in ref_of:
            continue  # a join/append side table: not represented federated
        np.testing.assert_array_equal(f_by_ref[ref_of[i.ref]], i.rows,
                                      err_msg=ref_of[i.ref])
    # the boundary dataset appears on BOTH sides of the identity link
    if boundary_ref in f_by_ref:
        np.testing.assert_array_equal(f_by_ref["serve/ingest"],
                                      f_by_ref[boundary_ref])
    # member-topological order: every prep impact precedes every serve one
    members = [i.ref.split("/")[0] for i in fplan.impacts]
    assert members == sorted(members, key=["prep", "serve"].index)


def test_federated_erasure_lists_cross_relation_invalidations():
    sp = pytest.importorskip("scipy.sparse")
    base, specs = pipegen.random_specs(0)
    catalog, refs, sink_ref = pipegen.build_federated(base, specs, 2)
    sess = catalog.session()
    link = catalog.links[0]
    # a stitched cross-relation over the route the erasure poisons
    store = catalog._cross_store
    store.put(("prep/src", sink_ref, "fwd"),
              sp.identity(4, dtype=np.float32, format="csr"),
              frozenset({(link.up, link.down)}))
    # and per-member composed entries
    prep_idx = catalog.members["prep"]._index
    prep_idx.composed().relation("src", refs[2].split("/")[1])
    plan = erasure_plan(catalog, "prep/src", [0])
    kinds = {i.kind for i in plan.invalidations}
    assert "cross" in kinds and "composed" in kinds
    cross = [i for i in plan.invalidations if i.kind == "cross"]
    assert cross[0].src == "prep/src" and cross[0].dst == sink_ref
    dropped = apply_invalidations(catalog, plan)
    assert dropped == len(plan.invalidations)
    assert ("prep/src", sink_ref, "fwd") not in store.entries
    assert prep_idx.composed().stats()["entries"] == 0


def test_federated_erasure_through_boundary_handle():
    """An upstream member registered as a read-only capability still
    closes downstream — and the plan carries no invalidations for caches
    the capability cannot touch."""
    from repro.provenance import ProvCatalog

    rng = np.random.default_rng(0)
    prep = ProvenanceIndex("prep-cap")
    s = track(Table.from_columns({
        "k": np.arange(12, dtype=np.float32),
        "x": rng.normal(size=12).astype(np.float32)}), prep, "raw")
    clean = s.value_transform("x", "scale", factor=2.0)
    clean.mark_sink()
    serve = ProvenanceIndex("serve-cap")
    ing = track(clean.table, serve, "ingest")
    out = ing.filter_rows(rng.random(12) < 0.8)
    out.mark_sink()
    catalog = ProvCatalog("cap")
    catalog.register("prep", prep.export(clean.dataset_id))
    catalog.register("serve", serve)
    catalog.link(qualify("prep", clean.dataset_id), "serve/ingest")

    plan = erasure_plan(catalog, "prep/raw", [0, 1])
    refs = set(plan.affected)
    assert qualify("prep", clean.dataset_id) in refs
    assert "serve/ingest" in refs
    assert all(i.scope != "prep" for i in plan.invalidations)


# ---------------------------------------------------------------------------
# Serving-tier entry point
# ---------------------------------------------------------------------------
def test_serve_engine_erasure_impact():
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(0)
    prep = ProvenanceIndex("prep-serve")
    s = track(Table.from_columns({
        "k": np.arange(16, dtype=np.float32),
        "x": rng.normal(size=16).astype(np.float32)}), prep, "raw")
    clean = s.value_transform("x", "scale", factor=2.0)
    clean.mark_sink()

    engine = object.__new__(ServeEngine)
    engine._init_provenance("serve:test",
                            upstream=prep.export(clean.dataset_id))
    # simulate one recorded request batch linked to upstream rows
    req = Table.from_columns({
        "x": rng.normal(size=4).astype(np.float32)})
    track(req, engine.prov, "requests@0").mark_sink()
    up_name, boundary = engine._upstream
    engine.catalog.link(qualify(up_name, boundary),
                        qualify(engine._serve_name, "requests@0"),
                        alignment=np.array([2, 5, 7, 2]))

    plan = engine.erasure_impact([2])   # defaults to the upstream boundary
    by_ref = {i.ref: i.rows for i in plan.impacts}
    assert qualify(up_name, boundary) in by_ref
    # upstream row 2 backs requests 0 and 3
    np.testing.assert_array_equal(
        by_ref[qualify(engine._serve_name, "requests@0")], [0, 3])
    with pytest.raises(ValueError, match="source="):
        e2 = object.__new__(ServeEngine)
        e2._init_provenance("serve:bare")
        e2.erasure_impact([0])
