"""Integration: the launcher's sharded path end-to-end on the LOCAL mesh.

Uses the host's single device as a 1x1 (data, model) mesh — every sharding
rule, activation hint and spec resolves through the same code path as the
production mesh (sizes of 1 make each spec a no-op placement, but structure
mismatches, bad specs, and hint rank errors all still fail loudly).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    pytest.skip("jax.sharding.AxisType unavailable on this JAX",
                allow_module_level=True)

from repro.configs.registry import get_smoke_config
from repro.launch import sharding as SD
from repro.models import pshard as PS
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, make_train_step


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b"])
def test_sharded_train_step_runs(arch, mesh):
    cfg = get_smoke_config(arch)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=4)
    key = jax.random.PRNGKey(0)

    with jax.set_mesh(mesh), PS.use_policy(
            {"dp": ("data",), "tp": "model", "moe_groups": 1}):
        state = init_train_state(cfg, key, opt)
        state_shapes = jax.eval_shape(lambda: state)
        state_sh = SD.to_shardings(SD.state_pspecs(state_shapes, mesh), mesh)
        state = jax.device_put(state, state_sh)

        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab)
        batch = {"tokens": toks,
                 "labels": jnp.concatenate(
                     [toks[:, 1:], -jnp.ones((4, 1), jnp.int32)], axis=1)}
        batch_shapes = jax.eval_shape(lambda: batch)
        batch_sh = SD.to_shardings(SD.batch_pspecs(batch_shapes, mesh), mesh)
        batch = jax.device_put(batch, batch_sh)

        step = jax.jit(
            make_train_step(cfg, opt, n_micro=2),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
        )
        new_state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_state.opt.step) == 1
        # second step with donated-style reuse
        new_state, metrics2 = step(new_state, batch)
        assert bool(jnp.isfinite(metrics2["loss"]))


def test_remat_policies_agree():
    """'nothing' and 'dots' remat policies compute identical losses."""
    import dataclasses
    from repro.models.registry import get_model
    base = get_smoke_config("olmo-1b")
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, 16), 0, base.vocab)
    outs = {}
    for pol in ("nothing", "dots"):
        cfg = dataclasses.replace(base, remat=True, remat_policy=pol,
                                  n_layers=4)
        m = get_model(cfg)
        params = m.init_params(cfg, key)
        loss = jnp.mean(m.forward(cfg, params, toks, dtype=jnp.float32))
        grad = jax.grad(lambda p: jnp.mean(
            m.forward(cfg, p, toks, dtype=jnp.float32) ** 2))(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                             for x in jax.tree.leaves(grad)))
        outs[pol] = (float(loss), float(gnorm))
    assert np.allclose(outs["nothing"][0], outs["dots"][0], rtol=1e-5)
    assert np.allclose(outs["nothing"][1], outs["dots"][1], rtol=1e-3)
