"""Integration: the launcher's sharded path end-to-end on the LOCAL mesh.

The mesh fixture parametrizes over every (data, model) shape the host's
device count can fill — a single-device host still runs the 1x1 lane
(every sharding rule, activation hint and spec resolves through the same
code path as the production mesh; sizes of 1 make each spec a no-op
placement, but structure mismatches, bad specs, and hint rank errors all
still fail loudly), while CI's multi-device lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) additionally
executes REAL collective placement at 2- and 4-way data parallelism.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch import sharding as SD
from repro.launch.mesh import host_device_count, make_mesh_compat
from repro.models import pshard as PS
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, make_train_step

# (data, model) shapes; the model axis stays 1 so the smoke configs' head
# and hidden dims never pick up a divisibility constraint, while the data
# axis carries real multi-device placement (batch of 4 -> up to 4-way).
MESH_SHAPES = [(1, 1), (2, 1), (4, 1)]


@pytest.fixture(scope="module", params=MESH_SHAPES,
                ids=[f"{d}x{m}" for d, m in MESH_SHAPES])
def mesh(request):
    shape = request.param
    need = shape[0] * shape[1]
    if host_device_count() < need:
        pytest.skip(f"mesh {shape} needs {need} devices, "
                    f"have {host_device_count()}")
    return make_mesh_compat(shape, ("data", "model"))


def _active(mesh):
    """``jax.set_mesh`` where it exists; the Mesh context manager (same
    activation semantics) on older releases."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b"])
def test_sharded_train_step_runs(arch, mesh):
    cfg = get_smoke_config(arch)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=4)
    key = jax.random.PRNGKey(0)

    with _active(mesh), PS.use_policy(
            {"dp": ("data",), "tp": "model", "moe_groups": 1}):
        state = init_train_state(cfg, key, opt)
        state_shapes = jax.eval_shape(lambda: state)
        state_sh = SD.to_shardings(SD.state_pspecs(state_shapes, mesh), mesh)
        state = jax.device_put(state, state_sh)

        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab)
        batch = {"tokens": toks,
                 "labels": jnp.concatenate(
                     [toks[:, 1:], -jnp.ones((4, 1), jnp.int32)], axis=1)}
        batch_shapes = jax.eval_shape(lambda: batch)
        batch_sh = SD.to_shardings(SD.batch_pspecs(batch_shapes, mesh), mesh)
        batch = jax.device_put(batch, batch_sh)

        step = jax.jit(
            make_train_step(cfg, opt, n_micro=2),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
        )
        new_state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_state.opt.step) == 1
        # second step with donated-style reuse
        new_state, metrics2 = step(new_state, batch)
        assert bool(jnp.isfinite(metrics2["loss"]))


def test_remat_policies_agree():
    """'nothing' and 'dots' remat policies compute identical losses."""
    import dataclasses
    from repro.models.registry import get_model
    base = get_smoke_config("olmo-1b")
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, 16), 0, base.vocab)
    outs = {}
    for pol in ("nothing", "dots"):
        cfg = dataclasses.replace(base, remat=True, remat_policy=pol,
                                  n_layers=4)
        m = get_model(cfg)
        params = m.init_params(cfg, key)
        loss = jnp.mean(m.forward(cfg, params, toks, dtype=jnp.float32))
        grad = jax.grad(lambda p: jnp.mean(
            m.forward(cfg, p, toks, dtype=jnp.float32) ** 2))(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                             for x in jax.tree.leaves(grad)))
        outs[pol] = (float(loss), float(gnorm))
    assert np.allclose(outs["nothing"][0], outs["dots"][0], rtol=1e-5)
    assert np.allclose(outs["nothing"][1], outs["dots"][1], rtol=1e-3)
