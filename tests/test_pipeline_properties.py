"""Hypothesis property tests on whole-pipeline invariants.

Random pipelines are generated as op sequences over a random source table;
the invariants hold for ANY data-preparation pipeline:

  P1  backward(forward(r)) ∋ r      whenever forward(r) is non-empty
  P2  forward(backward(o)) ∋ o      for every output record o
  P3  einsum composition == chained slice/project queries
  P4  every output record's backward set ⊆ source rows
  P5  provenance bytes scale with nnz, not with cell count (the paper's
      memory claim in its asymptotic form)
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import query as Q
from repro.core.compose import dataset_lineage
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track


def _random_pipeline(seed: int, op_codes):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 60))
    idx = ProvenanceIndex("prop")
    t = Table.from_columns({
        "k": rng.integers(0, max(2, n // 4), n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    })
    cur = track(t, idx, "src")
    for code in op_codes:
        tab = cur.table
        if code == 0:
            mask = np.asarray(tab.col("x")) > float(rng.normal(-1.0, 0.3))
            if not mask.any():
                mask[0] = True
            cur = cur.filter_rows(mask)
        elif code == 1:
            cur = cur.value_transform("x", "scale", factor=1.5)
        elif code == 2:
            cur = cur.oversample(frac=0.4, seed=int(rng.integers(1e6)))
        elif code == 3:
            cur = cur.onehot("k", n_values=int(tab.col("k").max()) + 1)
        elif code == 4:
            keep = [c for c in tab.columns if c != "y"] or list(tab.columns)
            cur = cur.select_columns(keep)
        elif code == 5:
            r = Table.from_columns({
                "k": np.arange(max(2, n // 4), dtype=np.float32),
                "z": rng.normal(size=max(2, n // 4)).astype(np.float32),
            })
            other = track(r, idx)
            cur = cur.join(other, on="k", how="inner")
            if cur.table.n_rows == 0:
                return None
    cur.mark_sink()
    return idx, cur


ops_strategy = st.lists(st.integers(0, 5), min_size=1, max_size=5)


@given(st.integers(0, 10_000), ops_strategy)
@settings(max_examples=40, deadline=None)
def test_p1_p2_roundtrips(seed, op_codes):
    built = _random_pipeline(seed, op_codes)
    if built is None:
        return
    idx, sink = built
    n_src = idx.datasets["src"].n_rows
    n_out = idx.datasets[sink.dataset_id].n_rows
    for r in range(0, n_src, max(1, n_src // 5)):
        fwd = Q.q1_forward(idx, "src", [r], sink.dataset_id)
        if len(fwd):
            back = Q.q2_backward(idx, sink.dataset_id, fwd, "src")
            assert r in back.tolist()                      # P1
    for o in range(0, n_out, max(1, n_out // 5)):
        back = Q.q2_backward(idx, sink.dataset_id, [o], "src")
        assert set(back.tolist()) <= set(range(n_src))     # P4
        if len(back):
            fwd = Q.q1_forward(idx, "src", back, sink.dataset_id)
            assert o in fwd.tolist()                       # P2


@given(st.integers(0, 10_000), ops_strategy)
@settings(max_examples=20, deadline=None)
def test_p3_composition_equals_chained_queries(seed, op_codes):
    built = _random_pipeline(seed, op_codes)
    if built is None:
        return
    idx, sink = built
    rel = dataset_lineage(idx, "src", sink.dataset_id, use_pallas=False)
    n_src = idx.datasets["src"].n_rows
    for r in range(0, n_src, max(1, n_src // 4)):
        want = set(Q.q1_forward(idx, "src", [r], sink.dataset_id).tolist())
        assert set(np.flatnonzero(rel[r]).tolist()) == want


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_p5_memory_scales_with_nnz(seed):
    built = _random_pipeline(seed, [0, 1, 2])
    if built is None:
        return
    idx, _ = built
    total_nnz = sum(op.tensor.nnz for op in idx.ops)
    # COO storage: (1+k) int32 per nnz; CSR at most doubles it per direction
    assert idx.prov_nbytes() <= total_nnz * 5 * 4 + 64 * len(idx.ops)
