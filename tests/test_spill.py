"""Streaming-capture spill tier and incremental hop-cache extension.

Three layers under test:

* :class:`repro.core.spill.SpillStore` — the append-only segmented log every
  spilled artifact lands in: round-trips must be byte-identical, deletes are
  log-structured (dead bytes, segment GC), the read path hands back memmap
  views without heap copies;
* :class:`repro.core.spill.TensorSpiller` (``ProvenanceIndex(spill=...)``) —
  cold op tensors leave RAM under an LRU byte budget with watermark
  hysteresis, capture payload aliases are stripped with them, and any probe
  (query walk, recompute, payload read) faults them back transparently;
* :class:`ComposedIndex` spill-backed eviction + incremental extension —
  evicted composed relations rehydrate byte-identically, appended structured
  ops extend warm relations by ONE closed-form step (``extends`` counter)
  instead of recomposing the chain, and the cost gate prices extend vs
  fold-then-apply recompose.

Plus the ``ProvTensor.slice_rows`` edge cases the shard layer leans on:
empty ``(lo, lo)`` ranges, all-``-1`` sentinel slots, and single-row slices
of append ``SlotRange`` blocks.
"""
import numpy as np
import pytest

from repro.core import capture
from repro.core.capture import restore_payload, strip_payload
from repro.core.costmodel import RelStats, extend_vs_recompose
from repro.core.hopcache import ComposedIndex
from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    ProvTensor,
    SlotGather,
    SlotRange,
    append_tensor,
    haugment_tensor,
    hreduce_tensor,
    identity_tensor,
    join_tensor,
)
from repro.core.recompute import recompute_rows
from repro.core.spill import SpillPolicy, SpillStore, resolve_spill
from repro.dataprep.table import Table


# ===========================================================================
# Pipeline-building helpers (manual record — full control over op mix)
# ===========================================================================
def _table(n, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_columns({
        "x": rng.normal(size=n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    })


def _identity_info(name, n):
    return CaptureInfo(op_name=f"transform:{name}", category=OpCategory.TRANSFORM,
                       contextual=False, n_out=n, n_in=[n],
                       params={"col": "x", "fn": "scale", "fn_params": {"factor": 1.0}},
                       attr_maps=[AttrMap("identity")])


def _filter_info(name, kept, n_in):
    return CaptureInfo(op_name=name, category=OpCategory.HREDUCE, contextual=False,
                       n_out=len(kept), n_in=[n_in],
                       kept_rows=np.asarray(kept, dtype=np.int32),
                       attr_maps=[AttrMap("identity")])


def _gather_info(name, src_rows, n_in):
    return CaptureInfo(op_name=name, category=OpCategory.HAUGMENT, contextual=False,
                       n_out=len(src_rows), n_in=[n_in],
                       src_rows=np.asarray(src_rows, dtype=np.int32),
                       attr_maps=[AttrMap("identity")])


def _filter_chain(n=64, hops=6, seed=0, spill=None):
    """A linear filter chain — every intermediate non-materialized."""
    idx = ProvenanceIndex("spillchain", spill=spill)
    idx.add_source("d0", _table(n, seed))
    rng = np.random.default_rng(seed + 1)
    cur, cn = "d0", n
    for i in range(hops):
        kept = np.flatnonzero(rng.random(cn) > 0.15).astype(np.int32)
        if len(kept) == 0:
            kept = np.array([0], dtype=np.int32)
        out = f"d{i + 1}"
        idx.record([cur], out, _table(len(kept), seed + 2 + i),
                   _filter_info(f"f{i}", kept, cn))
        cur, cn = out, len(kept)
    return idx, cur


# ===========================================================================
# SpillStore: the on-disk segmented log
# ===========================================================================
class TestSpillStore:
    def test_roundtrip_byte_identical(self, tmp_path):
        st = SpillStore(tmp_path / "log")
        arrays = {
            "a": np.arange(100, dtype=np.int32),
            "b": np.random.default_rng(0).normal(size=(7, 3)).astype(np.float32),
            "c": np.array([], dtype=np.int64),
            "d": np.packbits(np.ones(65, dtype=np.uint8)).astype(np.uint8),
        }
        st.put(("op", "p", 0), arrays, {"kind": "test", "n": 100})
        meta, got = st.get(("op", "p", 0))
        assert meta["kind"] == "test" and meta["n"] == 100
        assert set(got) == set(arrays)
        for k in arrays:
            assert got[k].dtype == arrays[k].dtype
            assert got[k].shape == arrays[k].shape
            np.testing.assert_array_equal(got[k], arrays[k])

    def test_overwrite_and_delete(self, tmp_path):
        st = SpillStore(tmp_path / "log")
        st.put("k", {"a": np.arange(4)}, {})
        st.put("k", {"a": np.arange(8)}, {})        # overwrite = delete+append
        _, got = st.get("k")
        assert len(got["a"]) == 8
        assert st.stats()["dead_bytes"] > 0          # first record is dead
        st.delete("k")
        assert "k" not in st
        with pytest.raises(KeyError):
            st.get("k")

    def test_segment_rotation_and_gc(self, tmp_path):
        st = SpillStore(tmp_path / "log", segment_bytes=4096)
        for i in range(16):                          # ~1.3KB each -> rotates
            st.put(i, {"a": np.arange(320, dtype=np.int32)}, {})
        assert st.stats()["segments"] > 1
        for i in range(16):
            st.delete(i)
        # every non-active segment became fully dead -> unlinked
        assert st.stats()["segments"] <= 1
        assert st.stats()["entries"] == 0

    def test_disk_budget_drops_oldest(self, tmp_path):
        st = SpillStore(tmp_path / "log", segment_bytes=2048,
                        disk_budget_bytes=6144)
        for i in range(24):
            st.put(i, {"a": np.arange(128, dtype=np.int64)}, {})
        assert st.stats()["disk_bytes"] <= 6144 + 2048   # active seg slack
        assert st.stats()["drops"] > 0
        # newest survives, oldest dropped
        assert 23 in st and 0 not in st

    def test_ephemeral_root_cleanup(self):
        st = SpillStore()                            # owns a temp root
        root = st.stats()["root"]
        st.put("k", {"a": np.arange(4)}, {})
        st.close()
        import os
        assert not os.path.exists(root)


# ===========================================================================
# ProvTensor payload round-trip: every tensor kind
# ===========================================================================
def _tensor_kinds():
    rng = np.random.default_rng(7)
    kept = np.sort(rng.choice(40, size=25, replace=False)).astype(np.int32)
    src = rng.integers(-1, 40, size=30).astype(np.int32)   # mixes -1 sentinels
    pairs = np.stack([rng.integers(-1, 12, 20), rng.integers(-1, 9, 20)],
                     axis=1).astype(np.int32)
    pairs[(pairs[:, 0] < 0) & (pairs[:, 1] < 0), 0] = 0    # no all-null rows
    links = np.stack([np.repeat(np.arange(10), 2),
                      rng.integers(0, 33, 20)], axis=1).astype(np.int32)
    return {
        "identity": identity_tensor(17),
        "hreduce": hreduce_tensor(kept, 40),
        "haugment": haugment_tensor(src, 40),
        "join": join_tensor(pairs, 12, 9),
        "append": append_tensor(11, 6),
        "coo_links": ProvTensor(n_out=10, n_in=(33,), coo=links),
    }


@pytest.mark.parametrize("kind", list(_tensor_kinds()))
def test_payload_roundtrip(kind, tmp_path):
    t = _tensor_kinds()[kind]
    meta, arrays = t.to_payload()
    # through the store (memmap-backed arrays on the way back)
    st = SpillStore(tmp_path / "log")
    st.put("t", arrays, meta)
    meta2, arrays2 = st.get("t")
    back = ProvTensor.from_payload(meta2, arrays2)
    assert back.n_out == t.n_out and back.n_in == t.n_in
    assert back.structured == t.structured
    np.testing.assert_array_equal(back.coo, t.coo)
    # lazy mirrors rebuild byte-identically
    for a, b in ((back.fwd(0), t.fwd(0)), (back.bwd(0), t.bwd(0))):
        np.testing.assert_array_equal(a.row_ptr, b.row_ptr)
        np.testing.assert_array_equal(a.col_idx, b.col_idx)


def test_payload_strip_restore_aliases():
    """strip_payload frees the info-side aliases; restore rebuilds exactly
    the fields that were stripped (COO HAUGMENT can't be guessed from the
    tensor alone)."""
    src = np.array([0, -1, 2, 1, -1], dtype=np.int32)
    info = _gather_info("g", src, 3)
    t = capture.build_tensor(info)
    strip_payload(info)
    assert info.src_rows is None and info._spill_stripped == ("src_rows",)
    restore_payload(info, t)
    np.testing.assert_array_equal(info.src_rows, src)
    assert info._spill_stripped == ()
    # multi-parent links: raw-COO tensor restores the links field, not src_rows
    links = np.array([[0, 1], [0, 2], [1, 0]], dtype=np.int32)
    info2 = CaptureInfo(op_name="pack", category=OpCategory.HAUGMENT,
                        contextual=False, n_out=2, n_in=[3], links=links,
                        attr_maps=[AttrMap("identity")])
    t2 = capture.build_tensor(info2)
    strip_payload(info2)
    assert info2._spill_stripped == ("links",)
    restore_payload(info2, t2)
    assert info2.src_rows is None
    np.testing.assert_array_equal(info2.links, links)


# ===========================================================================
# TensorSpiller: bounded residency + transparent fault-back
# ===========================================================================
class TestTensorSpiller:
    def test_budget_bounds_residency(self):
        budget = 2048
        idx, sink = _filter_chain(n=512, hops=10,
                                  spill=SpillPolicy(budget_bytes=budget))
        sp = idx.stats()["spill"]
        assert sp["spills"] > 0
        assert sp["resident_bytes"] <= budget
        assert sp["resident_ops"] + sp["spilled_ops"] == len(idx.ops)

    def test_fault_back_parity(self):
        """Queries through a spilled index answer byte-identically to the
        same pipeline captured without spill."""
        ref, sink = _filter_chain(n=256, hops=8)
        idx, sink2 = _filter_chain(n=256, hops=8,
                                   spill=SpillPolicy(budget_bytes=1024))
        assert sink == sink2
        assert idx.stats()["spill"]["spilled_ops"] > 0
        want = ComposedIndex(ref).relation("d0", sink)
        got = ComposedIndex(idx).relation("d0", sink)
        assert np.array_equal(np.asarray(want.todense() if hasattr(want, "todense") else want),
                              np.asarray(got.todense() if hasattr(got, "todense") else got))
        assert idx.stats()["spill"]["rehydrations"] > 0

    def test_recompute_faults_spilled_tensor(self):
        """recompute_rows reads the stripped kept_rows payload — the
        resident() touch must fault the tensor AND restore the payload."""
        ref, sink = _filter_chain(n=128, hops=6, seed=3)
        idx, _ = _filter_chain(n=128, hops=6, seed=3,
                               spill=SpillPolicy(budget_bytes=512))
        # every non-sink intermediate is non-materialized
        mid = "d3"
        op = idx.ops[idx.producer[mid]]
        if type(op.tensor).__name__ != "_TensorFault":
            # force: probe something else to push it out via LRU
            pass
        rows = np.arange(idx.datasets[mid].n_rows, dtype=np.int64)
        got = recompute_rows(idx, mid, rows)
        want = recompute_rows(ref, mid, rows)
        np.testing.assert_array_equal(got.data, want.data)
        np.testing.assert_array_equal(got.null, want.null)

    def test_lru_mru_discipline(self):
        idx, sink = _filter_chain(n=512, hops=10,
                                  spill=SpillPolicy(budget_bytes=2048))
        spiller = idx._spill
        # fault op 0 back -> becomes MRU, some other op spills if over budget
        t0 = idx.ops[0].tensor.resident()
        assert type(t0).__name__ == "ProvTensor"
        assert idx.stats()["spill"]["resident_bytes"] <= 2048
        assert 0 in spiller._resident

    def test_immutable_respill_skips_write(self):
        idx, sink = _filter_chain(n=512, hops=10,
                                  spill=SpillPolicy(budget_bytes=2048))
        st = idx._spill.policy.store
        for op in idx.ops:                           # warm-up: store every op once
            op.tensor.resident()
        writes_before = st.stats()["writes"]
        for _ in range(2):                           # churn the LRU twice around
            for op in idx.ops:
                op.tensor.resident()
        # re-spills of already-stored tensors write nothing new
        assert st.stats()["writes"] == writes_before
        assert st.stats()["reads"] > 0

    def test_resolve_spill_forms(self, tmp_path):
        assert resolve_spill(None) is None
        assert resolve_spill(False) is None
        p = resolve_spill(True)
        assert isinstance(p, SpillPolicy)
        p2 = resolve_spill(str(tmp_path / "s"))
        assert p2.path is not None
        pol = SpillPolicy(budget_bytes=123)
        assert resolve_spill(pol) is pol
        st = SpillStore(tmp_path / "log2")
        assert resolve_spill(st).store is st
        with pytest.raises(TypeError):
            resolve_spill(3.14)


# ===========================================================================
# Hop-cache: spill-backed eviction under append storms
# ===========================================================================
class TestHopcacheSpill:
    def test_append_storm_budget_respected(self):
        """Cache kept across versions must still respect the byte budget:
        appends keep arriving, evictions spill, probes stay correct."""
        budget = 8192
        idx, cur = _filter_chain(n=256, hops=4, seed=5)
        ci = ComposedIndex(idx, memory_budget_bytes=budget, spill=True)
        rng = np.random.default_rng(99)
        cn = idx.datasets[cur].n_rows
        for i in range(30):
            kept = np.flatnonzero(rng.random(cn) > 0.05).astype(np.int32)
            out = f"s{i}"
            idx.record([cur], out, _table(len(kept), i),
                       _filter_info(f"sf{i}", kept, cn))
            cur, cn = out, len(kept)
            if i % 3 == 0:
                ci.relation("d0", cur)               # probe through the storm
        st = ci.stats()
        assert st["bytes"] <= budget * resolve_spill(True).high_watermark
        assert st["evictions"] > 0 and st["spills"] > 0
        # spilled relations are still "contained" and fault back
        assert st["spilled_entries"] > 0 or st["rehydrations"] > 0

    @pytest.mark.parametrize("backend", ["csr", "bitplane", "auto"])
    def test_spilled_entry_roundtrip(self, backend):
        """Evict -> fault must be byte-identical per backend."""
        if backend == "csr":
            pytest.importorskip("scipy")
        idx, sink = _filter_chain(n=200, hops=6, seed=11)
        big = ComposedIndex(idx, backend=backend)
        want = big.relation("d0", sink)
        tiny = ComposedIndex(idx, backend=backend,
                             memory_budget_bytes=256, spill=True)
        tiny.relation("d0", sink)                    # composes, mostly spills
        assert tiny.stats()["spills"] > 0
        got = tiny.relation("d0", sink)              # faults back (or rebuilt)
        if backend == "csr":
            assert (want != got).nnz == 0
        else:
            np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_residency_states(self):
        idx, sink = _filter_chain(n=200, hops=5, seed=13)
        ci = ComposedIndex(idx, memory_budget_bytes=1 << 20, spill=True)
        assert ci.residency("d0", sink) is None
        ci.relation("d0", sink)
        assert ci.residency("d0", sink) == "ram"
        # shrink budget and force eviction through an insert
        ci.memory_budget_bytes = 128
        ci._evict_over_budget()
        spilled = [f"d{k}" for k in range(1, 6)
                   if ci.residency("d0", f"d{k}") == "spilled"]
        assert spilled
        # contains() covers spilled pairs (faulting beats recomposing)
        assert ci.contains("d0", spilled[0])
        ci.relation("d0", spilled[0])                # faults back
        assert ci.stats()["rehydrations"] > 0

    def test_no_spill_keeps_legacy_eviction(self):
        """spill=None preserves the seed behavior exactly: evict-to-budget
        (no hysteresis), no spill counters movement."""
        idx, sink = _filter_chain(n=200, hops=6, seed=17)
        ci = ComposedIndex(idx, memory_budget_bytes=512)
        ci.relation("d0", sink)
        st = ci.stats()
        assert st["spills"] == 0 and st["rehydrations"] == 0
        assert st["spilled_entries"] == 0
        assert "spill" not in st


# ===========================================================================
# Incremental extension: counters + the extend-vs-recompose gate
# ===========================================================================
class TestIncrementalExtension:
    def test_eager_extend_on_append(self):
        idx, cur = _filter_chain(n=128, hops=4, seed=21)
        ci = ComposedIndex(idx)
        ci.relation("d0", cur)                       # warm the chain
        base_ext = ci.stats()["extends"]
        rng = np.random.default_rng(5)
        cn = idx.datasets[cur].n_rows
        for i in range(3):
            kept = np.flatnonzero(rng.random(cn) > 0.1).astype(np.int32)
            out = f"e{i}"
            idx.record([cur], out, _table(len(kept), i),
                       _filter_info(f"ef{i}", kept, cn))
            cur, cn = out, len(kept)
        r = ci.relation("d0", cur)                   # sync absorbed the tail
        st = ci.stats()
        assert st["extends"] >= base_ext + 3
        # parity against a cold compose of the full chain
        want = ComposedIndex(idx).relation("d0", cur)
        assert np.array_equal(
            np.asarray(want.todense() if hasattr(want, "todense") else want),
            np.asarray(r.todense() if hasattr(r, "todense") else r))

    def test_eager_extend_disabled(self):
        idx, cur = _filter_chain(n=128, hops=4, seed=23)
        ci = ComposedIndex(idx, extend_eager=False)
        ci.relation("d0", cur)
        rng = np.random.default_rng(5)
        cn = idx.datasets[cur].n_rows
        kept = np.flatnonzero(rng.random(cn) > 0.1).astype(np.int32)
        idx.record([cur], "e0", _table(len(kept), 0),
                   _filter_info("ef0", kept, cn))
        assert not ci.contains("d0", "e0")           # nothing eager happened
        ci.relation("d0", "e0")                      # lazy single-step extend
        assert ci.stats()["extends"] >= 1

    def test_extend_counter_vs_recompose_counter(self):
        idx, cur = _filter_chain(n=128, hops=5, seed=29)
        ci = ComposedIndex(idx, extend_eager=False)
        ci.relation("d0", cur)                       # cold multi-step
        st = ci.stats()
        assert st["recomposes"] >= 1
        rng = np.random.default_rng(31)
        cn = idx.datasets[cur].n_rows
        kept = np.flatnonzero(rng.random(cn) > 0.1).astype(np.int32)
        idx.record([cur], "x0", _table(len(kept), 0),
                   _filter_info("xf0", kept, cn))
        before = ci.stats()["extends"]
        ci.relation("d0", "x0")                      # ONE pending op
        assert ci.stats()["extends"] == before + 1

    def test_gate_unit(self):
        prefix = RelStats(rows=4000, cols=100_000, nnz=400_000)   # dense CSR
        step = RelStats(rows=3800, cols=4000, nnz=3800, structured=True)
        one = extend_vs_recompose(prefix, [step])
        assert one["strategy"] == "extend"           # single step: always
        tail = [RelStats(rows=4000 - 50 * k, cols=4000 - 50 * (k - 1),
                         nnz=4000 - 50 * k, structured=True)
                for k in range(1, 6)]
        multi = extend_vs_recompose(prefix, tail)
        # folding 5 tiny gathers first, then ONE prefix apply, beats 5 applies
        assert multi["strategy"] == "recompose"
        assert multi["recompose_ns"] < multi["extend_ns"]
        assert extend_vs_recompose(prefix, [])["strategy"] == "extend"


# ===========================================================================
# slice_rows edge cases (shard-construction primitive)
# ===========================================================================
class TestSliceRowsEdges:
    def test_empty_range(self):
        for t in _tensor_kinds().values():
            lo = t.n_out // 2
            s = t.slice_rows(lo, lo)
            assert s.n_out == 0
            assert s.coo.shape[0] == 0
            assert s.n_in == t.n_in

    def test_reversed_range_raises(self):
        t = identity_tensor(10)
        with pytest.raises(ValueError):
            t.slice_rows(5, 3)

    def test_all_sentinel_slots(self):
        """A slice landing entirely on -1 sentinel rows: zero nnz, correct
        shape, empty mirrors."""
        src = np.full(8, -1, dtype=np.int32)
        src[:2] = [3, 1]                             # rows 2..8 all synthetic
        t = haugment_tensor(src, 10)
        s = t.slice_rows(2, 8)
        assert s.n_out == 6 and s.slot_nnz(0) == 0   # nnz counts sentinel rows
        assert s.fwd(0).row_ptr[-1] == 0
        g = s.slot_gather(0)
        assert g is not None and (np.asarray(g) == -1).all()

    def test_single_row_slices_of_append_blocks(self):
        t = append_tensor(5, 3)                      # SlotRange blocks
        # one row from the left block, the boundary row, one from the right
        for r in (0, 4, 5, 7):
            s = t.slice_rows(r, r + 1)
            assert s.n_out == 1
            coo = np.asarray(s.coo)
            assert coo.shape[0] == 1 and coo[0, 0] == 0
            k = 0 if r < 5 else 1
            np.testing.assert_array_equal(
                coo[0, 1:], [r if k == 0 else -1, -1 if k == 0 else r - 5][
                    : coo.shape[1] - 1] if coo.shape[1] == 3 else coo[0, 1:])
        # structured form survives the slice
        s = t.slice_rows(4, 6)                       # straddles the boundary
        full = np.asarray(t.coo)
        np.testing.assert_array_equal(
            np.asarray(s.coo)[:, 1:], full[4:6, 1:])
