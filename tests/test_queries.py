"""Integration tests: Q1-Q11 over tracked pipelines (paper §IV, Table VII)."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep import ops as P
from repro.dataprep.table import Table
from repro.dataprep.tracked import track


@pytest.fixture
def join_pipeline():
    """The paper's running example: Dl |x| Dr -> filter -> onehot."""
    idx = ProvenanceIndex("demo")
    dl = Table.from_columns({
        "ID": [10., 20, 30, 40],
        "Birthdate": [1996., 1994, np.nan, 1987],
        "Gender": [0., 1, 0, 1],
    }, null={"Birthdate": [False, False, True, False]})
    dr = Table.from_columns({"ID": [20., 40], "Name": [0., 1]})
    tl = track(dl, idx, "Dl")
    tr = track(dr, idx, "Dr")
    tj = tl.join(tr, on="ID", how="inner")
    tf = tj.filter_rows(np.asarray(tj.table.col("Gender")) > 0.5)
    to = tf.onehot("Gender", n_values=2).mark_sink()
    return idx, tj, tf, to


def test_q1_q2_forward_backward(join_pipeline):
    idx, tj, tf, to = join_pipeline
    # Dl row 1 (ID=20) joins Dr row 0 -> out row 0, survives the filter
    assert Q.q1_forward(idx, "Dl", [1], to.dataset_id).tolist() == [0]
    assert Q.q2_backward(idx, to.dataset_id, [0], "Dl").tolist() == [1]
    assert Q.q2_backward(idx, to.dataset_id, [0], "Dr").tolist() == [0]
    # Dl row 0 (ID=10) is dangling: contributes nowhere
    assert Q.q1_forward(idx, "Dl", [0], to.dataset_id).tolist() == []


def test_q3_q4_attribute_level(join_pipeline):
    idx, tj, tf, to = join_pipeline
    # forward from Dl's Birthdate (attr 1): lands in join attr 1, then
    # onehot preserves position
    cells = Q.q3_forward_attr(idx, "Dl", [1], [1], to.dataset_id)
    assert (0, 1) in {tuple(c) for c in cells}
    # backward from the onehot outputs: Gender=1 column derives from Gender
    out_cols = to.table.columns
    gcol = out_cols.index("Gender=1")
    back = Q.q4_backward_attr(idx, to.dataset_id, [0], [gcol], "Dl")
    assert {tuple(c) for c in back} == {(1, 2)}   # Dl row 1, attr Gender(2)


def test_q5_q8_how_provenance(join_pipeline):
    idx, tj, tf, to = join_pipeline
    recs, hops = Q.q6_backward_how(idx, to.dataset_id, [0], "Dl")
    ops = [h.op_name for h in hops]
    assert recs.tolist() == [1]
    assert "onehot" in ops and "filter" in ops and any("join" in o for o in ops)
    _, hops_attr = Q.q8_backward_attr_how(idx, to.dataset_id, [0], [0], "Dl")
    assert len(hops_attr) >= 3


def test_q9_all_transformations(join_pipeline):
    idx, tj, tf, to = join_pipeline
    names = [o["op"] for o in Q.q9_all_transformations(idx, to.dataset_id)]
    assert names == ["join:inner", "filter", "onehot"]


def test_q10_co_contributory(join_pipeline):
    idx, tj, tf, to = join_pipeline
    # which Dr records were used together with Dl record 1?
    co = Q.q10_co_contributory(idx, "Dl", [1], "Dr", via=tj.dataset_id)
    assert co.tolist() == [0]


def test_q11_co_dependency():
    # D1 --opA--> D2 and D1 --opB--> D3: trace D2 rows to D3 via D1
    idx = ProvenanceIndex("codep")
    d1 = Table.from_columns({"k": np.arange(6, dtype=np.float32)})
    t1 = track(d1, idx, "D1")
    t2 = t1.filter_rows(np.asarray(t1.table.col("k")) % 2 == 0)   # rows 0,2,4
    t3 = t1.filter_rows(np.asarray(t1.table.col("k")) >= 2)        # rows 2..5
    dep = Q.q11_co_dependency(idx, t2.dataset_id, [1], "D1", t3.dataset_id)
    # t2 row 1 <- D1 row 2 -> t3 row 0
    assert dep.tolist() == [0]


def test_append_provenance():
    idx = ProvenanceIndex("append")
    a = Table.from_columns({"x": [1., 2], "y": [3., 4]})
    b = Table.from_columns({"x": [5., 6, 7], "z": [8., 9, 10]})
    ta = track(a, idx, "A")
    tb = track(b, idx, "B")
    tc = ta.append(tb).mark_sink()
    assert tc.table.n_rows == 5
    assert Q.q2_backward(idx, tc.dataset_id, [0], "A").tolist() == [0]
    assert Q.q2_backward(idx, tc.dataset_id, [3], "B").tolist() == [1]
    assert Q.q2_backward(idx, tc.dataset_id, [3], "A").tolist() == []
    # attr mapping: column z exists only in B
    zcol = tc.table.columns.index("z")
    cells = Q.q4_backward_attr(idx, tc.dataset_id, [3], [zcol], "B")
    assert {tuple(c) for c in cells} == {(1, 1)}


def test_outer_join_dangling_rows():
    idx = ProvenanceIndex("outer")
    l = Table.from_columns({"k": [1., 2, 3], "a": [0., 0, 0]})
    r = Table.from_columns({"k": [2., 9], "b": [1., 1]})
    tl, tr = track(l, idx, "L"), track(r, idx, "R")
    tj = tl.join(tr, on="k", how="outer").mark_sink()
    assert tj.table.n_rows == 4      # 1 match + 2 dangling left + 1 dangling right
    for i in range(tj.table.n_rows):
        lsrc = Q.q2_backward(idx, tj.dataset_id, [i], "L")
        rsrc = Q.q2_backward(idx, tj.dataset_id, [i], "R")
        assert len(lsrc) + len(rsrc) >= 1


def test_oversample_provenance_paper_e():
    idx = ProvenanceIndex("ovs")
    t = Table.from_columns({"x": np.arange(10, dtype=np.float32)})
    tt = track(t, idx, "T")
    to = tt.oversample(frac=0.5, seed=1, noise=0.01).mark_sink()
    assert to.table.n_rows == 15
    # every synthetic row maps back to exactly one source record
    for i in range(10, 15):
        src = Q.q2_backward(idx, to.dataset_id, [i], "T")
        assert len(src) == 1
