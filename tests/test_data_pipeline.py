"""Training-data pipeline provenance: the paper's queries over the token path."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.data.pipeline import CorpusConfig, TokenPipeline


@pytest.fixture(scope="module")
def tp():
    return TokenPipeline(CorpusConfig(n_docs=256, mean_len=96, seed=7), seq_len=128)


def test_shapes_and_determinism(tp):
    assert tp.tokens.shape[1] == 128
    b1 = tp.batch_at(3, 8)
    tp2 = TokenPipeline(CorpusConfig(n_docs=256, mean_len=96, seed=7), seq_len=128)
    b2 = tp2.batch_at(3, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["seq_rows"], b2["seq_rows"])


def test_batch_backward_lineage(tp):
    tp.batch_at(0, 8, record_provenance=True)
    docs = tp.batch_to_documents(0)
    assert len(docs) > 0
    n_corpus = tp.index.datasets["corpus"].n_rows
    assert all(0 <= d < n_corpus for d in docs)


def test_document_forward_lineage(tp):
    tp.batch_at(1, 8, record_provenance=True)
    docs = tp.batch_to_documents(1)
    target = int(docs[0])
    batches = tp.document_to_batches(target)
    assert 1 in batches


def test_filtered_documents_have_no_lineage(tp):
    meta = tp.index.datasets["corpus"].table
    dropped = np.flatnonzero(meta.col("quality") < tp.cfg.min_quality)
    if len(dropped):
        masks, _ = Q.forward_record_masks(tp.index, "corpus", dropped[:3])
        seqs = masks.get("sequences")
        assert seqs is None or not seqs.any()


def test_consent_audit(tp):
    """The paper's §IV consent use case: every sequence must trace only to
    consenting documents, and the audit exposes any that do not."""
    tp.batch_at(2, 8, record_provenance=True)
    meta = tp.index.datasets["corpus"].table
    consent = meta.col("consent") > 0
    docs = tp.batch_to_documents(2)
    flagged = [d for d in docs if not consent[d]]
    # the audit finds exactly the non-consenting contributors
    want = set(np.flatnonzero(~consent).tolist()) & set(int(d) for d in docs)
    assert set(int(f) for f in flagged) == want


def test_dedup_is_contextual_and_materializes_input(tp):
    op = next(o for o in tp.index.ops if o.info.op_name == "dedup")
    assert op.info.contextual
    for d in op.input_ids:
        assert tp.index.datasets[d].materialized
