"""§III-E materialization policy + per-record recomputation correctness."""
import numpy as np
import pytest

from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import materialized_frontier, recompute_rows
from repro.dataprep.table import Table
from repro.dataprep.tracked import track


def _tracked_chain(seed=0):
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex("rc")
    t = Table.from_columns({
        "a": rng.integers(0, 4, 40).astype(np.float32),
        "b": rng.normal(size=40).astype(np.float32),
        "c": np.where(rng.random(40) < 0.2, np.nan, rng.normal(size=40)).astype(np.float32),
    })
    tt = track(t, idx, "src")
    t1 = tt.value_transform("b", "scale", factor=3.0)       # localized
    t2 = t1.impute(["c"], strategy="mean")                   # CONTEXTUAL
    t3 = t2.onehot("a", n_values=4)                          # localized
    t4 = t3.filter_rows(np.asarray(t3.table.col("b")) > 0)   # localized
    t4.mark_sink()
    return idx, [tt, t1, t2, t3, t4]


def test_materialization_policy():
    idx, ts = _tracked_chain()
    # source + sink always materialized
    assert idx.datasets[ts[0].dataset_id].materialized
    assert idx.datasets[ts[-1].dataset_id].materialized
    # input of the contextual impute (t1's output) is materialized by policy
    assert idx.datasets[ts[1].dataset_id].materialized
    # outputs of impute and onehot are NOT materialized
    assert not idx.datasets[ts[2].dataset_id].materialized
    assert not idx.datasets[ts[3].dataset_id].materialized


def test_frontier_walks_to_materialized():
    idx, ts = _tracked_chain()
    f = materialized_frontier(idx, ts[3].dataset_id)
    assert idx.datasets[f].materialized


@pytest.mark.parametrize("which", [2, 3])
def test_recompute_matches_eager_values(which):
    idx, ts = _tracked_chain()
    target = ts[which]                       # non-materialized intermediates
    truth = target.table                     # TrackedTable kept it in python
    rows = [0, 3, 17]
    sub = recompute_rows(idx, target.dataset_id, rows)
    assert sub.n_rows == len(rows)
    np.testing.assert_allclose(sub.data, truth.data[rows], rtol=1e-6)
    np.testing.assert_array_equal(sub.null, truth.null[rows])
