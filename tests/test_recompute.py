"""§III-E materialization policy + per-record recomputation correctness."""
import numpy as np
import pytest

import pipegen
from repro.core.pipeline import ProvenanceIndex
from repro.core.recompute import fetch_rows, materialized_frontier, \
    recompute_rows
from repro.dataprep.table import Table
from repro.dataprep.tracked import track


def _tracked_chain(seed=0):
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex("rc")
    t = Table.from_columns({
        "a": rng.integers(0, 4, 40).astype(np.float32),
        "b": rng.normal(size=40).astype(np.float32),
        "c": np.where(rng.random(40) < 0.2, np.nan, rng.normal(size=40)).astype(np.float32),
    })
    tt = track(t, idx, "src")
    t1 = tt.value_transform("b", "scale", factor=3.0)       # localized
    t2 = t1.impute(["c"], strategy="mean")                   # CONTEXTUAL
    t3 = t2.onehot("a", n_values=4)                          # localized
    t4 = t3.filter_rows(np.asarray(t3.table.col("b")) > 0)   # localized
    t4.mark_sink()
    return idx, [tt, t1, t2, t3, t4]


def test_materialization_policy():
    idx, ts = _tracked_chain()
    # source + sink always materialized
    assert idx.datasets[ts[0].dataset_id].materialized
    assert idx.datasets[ts[-1].dataset_id].materialized
    # input of the contextual impute (t1's output) is materialized by policy
    assert idx.datasets[ts[1].dataset_id].materialized
    # outputs of impute and onehot are NOT materialized
    assert not idx.datasets[ts[2].dataset_id].materialized
    assert not idx.datasets[ts[3].dataset_id].materialized


def test_frontier_walks_to_materialized():
    idx, ts = _tracked_chain()
    f = materialized_frontier(idx, ts[3].dataset_id)
    assert idx.datasets[f].materialized


@pytest.mark.parametrize("which", [2, 3])
def test_recompute_matches_eager_values(which):
    idx, ts = _tracked_chain()
    target = ts[which]                       # non-materialized intermediates
    truth = target.table                     # TrackedTable kept it in python
    rows = [0, 3, 17]
    sub = recompute_rows(idx, target.dataset_id, rows)
    assert sub.n_rows == len(rows)
    np.testing.assert_allclose(sub.data, truth.data[rows], rtol=1e-6)
    np.testing.assert_array_equal(sub.null, truth.null[rows])


# ---------------------------------------------------------------------------
# Randomized parity: recompute vs a fully materialized build of the SAME
# spec list (ground truth captured via a record hook at build time)
# ---------------------------------------------------------------------------
def _build_with_truth(seed):
    """pipegen specs applied under a record hook that snapshots EVERY
    intermediate table — the fully materialized twin recompute must match."""
    base, specs = pipegen.random_specs(seed)
    idx = ProvenanceIndex(f"rcpar{seed}")
    truth = {}
    idx.add_record_hook(
        lambda input_ids, output_id, out_table, info, input_tables:
        truth.__setitem__(output_id, out_table.copy()))
    cur = track(Table.from_columns({c: v.copy() for c, v in base.items()}),
                idx, "src")
    for spec in specs:
        cur = pipegen.apply_spec(cur, spec, idx)
    cur.mark_sink()
    for ds, rec in idx.datasets.items():
        if rec.is_source:       # add_source fires no hook; tables are kept
            truth[ds] = rec.table.copy()
    return idx, truth


def _assert_rows_match(sub: Table, truth: Table, rows):
    assert sub.n_rows == len(rows)
    assert sub.columns == truth.columns
    np.testing.assert_array_equal(sub.null, truth.null[rows])
    ok = ~sub.null
    np.testing.assert_allclose(sub.data[ok], truth.data[rows][ok],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(10))
def test_recompute_parity_randomized(seed):
    idx, truth = _build_with_truth(seed)
    rng = np.random.default_rng(seed + 1000)
    for ds, rec in idx.datasets.items():
        n = rec.n_rows
        if n == 0:
            continue
        rows = sorted(set(rng.integers(0, n, size=min(6, n)).tolist()))
        # sorted unique probes through recompute_rows
        _assert_rows_match(recompute_rows(idx, ds, rows), truth[ds], rows)
        # duplicate + unordered probes through fetch_rows (aligned 1:1)
        dup = rng.permutation(np.asarray(rows + rows, dtype=np.int64))
        _assert_rows_match(fetch_rows(idx, ds, dup), truth[ds], dup)


def test_recompute_outer_join_right_only_rows():
    """Outer-join rows with NO left parent (-1 sentinel) assemble entirely
    from the right side, key column included."""
    rng = np.random.default_rng(7)
    idx = ProvenanceIndex("rc-outer")
    left = track(Table.from_columns({
        "k": np.array([0, 1, 2], dtype=np.float32),
        "x": rng.normal(size=3).astype(np.float32)}), idx, "left")
    right = track(Table.from_columns({
        "k": np.array([1, 2, 3, 4], dtype=np.float32),
        "z": rng.normal(size=4).astype(np.float32)}), idx)
    j = left.join(right, on="k", how="outer")
    truth = j.table.copy()
    j.value_transform("x", "scale", factor=1.0).mark_sink()  # j recomputable
    assert not idx.datasets[j.dataset_id].materialized
    pairs = np.asarray(idx.ops[idx.producer[j.dataset_id]].info.join_pairs)
    right_only = np.flatnonzero(pairs[:, 0] < 0)
    assert right_only.size > 0  # keys 3 and 4 have no left match
    _assert_rows_match(recompute_rows(idx, j.dataset_id, right_only.tolist()),
                       truth, right_only.tolist())
    # vocab survives recompute (was dropped to {} before the JOIN fix)
    sub = recompute_rows(idx, j.dataset_id, [0, 1])
    assert set(sub.vocab) == {c for c in truth.vocab if c in truth.columns}


def test_recompute_oversample_jitter_regenerated():
    """Synthetic oversample rows regenerate their jitter from the stored
    seed — recomputed values equal the captured run bit-for-bit."""
    rng = np.random.default_rng(11)
    idx = ProvenanceIndex("rc-jitter")
    t = track(Table.from_columns({
        "k": np.arange(20, dtype=np.float32),
        "x": rng.normal(size=20).astype(np.float32)}), idx, "src")
    ov = t.oversample(frac=0.5, seed=42, noise=0.2)
    truth = ov.table.copy()
    ov.value_transform("x", "scale", factor=1.0).mark_sink()
    assert not idx.datasets[ov.dataset_id].materialized
    synth = list(range(20, truth.n_rows))   # rows past n_in are synthetic
    assert synth
    sub = recompute_rows(idx, ov.dataset_id, synth)
    np.testing.assert_array_equal(sub.data, truth.data[synth])
