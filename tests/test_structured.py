"""Structured-representation parity suite.

Every pipeline here is built TWICE from the same seed: once with the default
structured capture (implicit identity/gather/range slots) and once under
``force_coo_capture`` (the legacy eager-COO tensors).  The two worlds must be
indistinguishable to every consumer:

* tensor level — COO mirrors, bidirectional CSR halves, relation bitplanes,
  slot statistics, mask propagation (single + batched), and the
  ``forward_rows``/``backward_rows`` row-gather fast paths are byte-identical;
* query level — record, cells, and how plans answer identically under both
  physical strategies (walk and hop-cache);
* compose level — the hop-cache's closed-form gather algebra (identity
  elimination, gather∘gather, block-append distribution) produces the same
  relations as the spmm/bitplane reference backends, while its byte
  accounting reflects the implicit form (insert / evict / convert).
"""
import warnings

import numpy as np
import pytest

import pipegen
import test_query_parity as tqp
from repro.core import capture
from repro.core.compose import chain_gather, compose_gather, path_tensors
from repro.core.hopcache import ComposedIndex
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    SlotGather,
    SlotIdentity,
    SlotRange,
    append_tensor,
    hreduce_tensor,
    identity_tensor,
    unpack_bitplane,
)
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import QuerySession, prov

SEEDS = list(range(8))


def _both_worlds(seed):
    """The same random pipeline captured structured and forced-COO.

    Dataset ids carry a process-global op counter, so the two worlds'
    names differ — ops correspond POSITIONALLY, and each world is queried
    through its own sink id."""
    s_idx, s_sink, _ = pipegen.random_pipeline(seed)
    with capture.force_coo_capture():
        c_idx, c_sink, _ = pipegen.random_pipeline(seed)
    return s_idx, c_idx, (s_sink, c_sink)


# ===========================================================================
# Tensor-level parity: every derived view is byte-identical
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_tensor_views_byte_identical(seed):
    s_idx, c_idx, _ = _both_worlds(seed)
    rng = np.random.default_rng(seed + 100)
    assert len(s_idx.ops) == len(c_idx.ops)
    saw_structured = False
    for s_op, c_op in zip(s_idx.ops, c_idx.ops):
        st, ct = s_op.tensor, c_op.tensor
        saw_structured |= st.structured
        assert not ct.structured
        assert st.nnz == ct.nnz and st.n_out == ct.n_out and st.n_in == ct.n_in
        np.testing.assert_array_equal(st.coo, ct.coo)
        for k in range(st.k):
            assert st.slot_nnz(k) == ct.slot_nnz(k)
            for a, b in ((st.fwd(k), ct.fwd(k)), (st.bwd(k), ct.bwd(k))):
                assert (a.n_rows, a.n_cols) == (b.n_rows, b.n_cols)
                np.testing.assert_array_equal(a.row_ptr, b.row_ptr)
                np.testing.assert_array_equal(a.col_idx, b.col_idx)
            np.testing.assert_array_equal(st.bitplane_fwd(k), ct.bitplane_fwd(k))
            np.testing.assert_array_equal(st.bitplane_bwd(k), ct.bitplane_bwd(k))
            # mask propagation, single + batched, incl. an empty mask row
            in_masks = rng.random((3, st.n_in[k])) < 0.3
            in_masks[1] = False
            out_masks = rng.random((3, st.n_out)) < 0.3
            out_masks[2] = False
            np.testing.assert_array_equal(
                st.forward_mask_batch(k, in_masks),
                ct.forward_mask_batch(k, in_masks))
            np.testing.assert_array_equal(
                st.backward_mask_batch(k, out_masks),
                ct.backward_mask_batch(k, out_masks))
            np.testing.assert_array_equal(
                st.forward_mask(k, in_masks[0]), ct.forward_mask(k, in_masks[0]))
            np.testing.assert_array_equal(
                st.backward_mask(k, out_masks[0]), ct.backward_mask(k, out_masks[0]))
    assert saw_structured  # the generator always emits at least one such op


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_row_gather_fast_paths(seed):
    """forward_rows/backward_rows: structured fast path == COO CSR gather ==
    the legacy dense-mask spelling, with empty and duplicate probes."""
    s_idx, c_idx, _ = _both_worlds(seed)
    rng = np.random.default_rng(seed)
    for s_op, c_op in zip(s_idx.ops, c_idx.ops):
        st, ct = s_op.tensor, c_op.tensor
        for k in range(st.k):
            probes = [
                [], [0], list(rng.integers(0, st.n_in[k], size=5)),
                np.array([0, 0, st.n_in[k] - 1]),          # duplicates
            ]
            for p in probes:
                got = st.forward_rows(k, p)
                np.testing.assert_array_equal(got, ct.forward_rows(k, p))
                # legacy semantics: flatnonzero of the dense-mask propagation
                m = np.zeros(st.n_in[k], dtype=bool)
                m[np.asarray(list(p), dtype=np.int64)] = True
                np.testing.assert_array_equal(
                    got, np.flatnonzero(ct.forward_mask(k, m)))
                assert got.dtype == np.int64
            probes_b = [[], [0], list(rng.integers(0, st.n_out, size=5))]
            for p in probes_b:
                got = st.backward_rows(k, p)
                np.testing.assert_array_equal(got, ct.backward_rows(k, p))
                m = np.zeros(st.n_out, dtype=bool)
                m[np.asarray(list(p), dtype=np.int64)] = True
                np.testing.assert_array_equal(
                    got, np.flatnonzero(ct.backward_mask(k, m)))


def test_row_gather_bounds_and_negative_wraparound():
    t = hreduce_tensor(np.array([1, 3, 4]), n_in=6)
    np.testing.assert_array_equal(t.forward_rows(0, [-3]), [1])  # wraps to 3
    with pytest.raises(IndexError):
        t.forward_rows(0, [6])
    with pytest.raises(IndexError):
        t.backward_rows(0, [3])
    assert t.forward_rows(0, []).size == 0
    assert t.backward_rows(0, []).size == 0


def test_capture_fast_path_never_allocates_coo():
    """build_tensor emits implicit forms straight from CaptureInfo — the
    explicit COO of a structured tensor is only a lazy mirror."""
    idx, _, _ = pipegen.random_pipeline(0)
    assert any(op.tensor.structured for op in idx.ops)
    for op in idx.ops:
        if op.tensor.structured:
            assert op.tensor._coo is None       # never touched by capture
    # the structured index is strictly smaller than the forced-COO twin
    with capture.force_coo_capture():
        coo_idx, _, _ = pipegen.random_pipeline(0)
    assert idx.prov_nbytes() < coo_idx.prov_nbytes()


# ===========================================================================
# Query-level parity: all plan kinds, both strategies, both worlds
# ===========================================================================
@pytest.mark.parametrize("seed", SEEDS)
def test_query_plans_identical_across_worlds(seed):
    s_idx, c_idx, sinks = _both_worlds(seed)
    rng = np.random.default_rng(seed + 7)
    n_src = s_idx.datasets["src"].n_rows
    n_sink = s_idx.datasets[sinks[0]].n_rows
    rows_f = [[0], sorted(rng.choice(n_src, size=3, replace=False).tolist()), []]
    rows_b = [[0], sorted(rng.choice(n_sink, size=3, replace=False).tolist())]

    def sessions(idx):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return (
                QuerySession(idx, ComposedIndex(idx), use_hopcache=False),
                QuerySession(idx, ComposedIndex(idx), hopcache_min_batch=1),
            )

    def same(a, b):
        if isinstance(a, tuple):                        # (records, hops)
            same(a[0], b[0])
            assert len(a[1]) == len(b[1])
            for ha, hb in zip(a[1], b[1]):              # hop ids differ by name
                assert (ha.op_id, ha.category, ha.n_records) \
                    == (hb.op_id, hb.category, hb.n_records)
        elif isinstance(a, list):                       # batched: per-probe
            assert len(a) == len(b)
            for xa, xb in zip(a, b):
                same(xa, xb)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def check(plan_of):
        for s_sess, c_sess in zip(sessions(s_idx), sessions(c_idx)):
            same(s_sess.run(plan_of(s_idx, sinks[0])),
                 c_sess.run(plan_of(c_idx, sinks[1])))

    for p in rows_f:
        check(lambda i, s, p=p: prov(i).source("src").rows(p).forward().to(s).plan())
        check(lambda i, s, p=p: prov(i).source("src").rows(p).attrs([0])
              .forward().to(s).plan())
        check(lambda i, s, p=p: prov(i).source("src").rows(p).forward()
              .to(s).how().plan())
    for p in rows_b:
        check(lambda i, s, p=p: prov(i).source(s).rows(p).backward().to("src").plan())
        check(lambda i, s, p=p: prov(i).source(s).rows(p).attrs([0])
              .backward().to("src").how().plan())
    check(lambda i, s: prov(i).source("src")
          .rows_batch(rows_f[:2]).forward().to(s).plan())
    check(lambda i, s: prov(i).source(s)
          .rows_batch(rows_b).backward().to("src").plan())


# ===========================================================================
# The closed-form compose algebra vs the spmm / bitplane reference
# ===========================================================================
def _selection_chain(n=80, n_ops=6, structured=True):
    """identity/selection/gather-only chain: fully closed-form composable."""
    def build():
        rng = np.random.default_rng(5)
        idx = ProvenanceIndex("sel-chain")
        d = track(Table.from_columns({
            "x": rng.normal(size=n).astype(np.float32)}), idx, "src")
        for i in range(n_ops):
            if i % 3 == 0:
                d = d.value_transform("x", "scale", factor=1.5)
            elif i % 3 == 1:
                mask = np.ones(d.table.n_rows, dtype=bool)
                mask[i::5] = False
                d = d.filter_rows(mask)
            else:
                d = d.oversample(frac=0.2, seed=i)
        d.mark_sink()
        return idx, d.dataset_id
    if structured:
        return build()
    with capture.force_coo_capture():
        return build()


def test_gather_compose_matches_boolean_matmul():
    rng = np.random.default_rng(3)
    g1 = rng.integers(-1, 10, size=12).astype(np.int32)    # mid -> src (|src|=10)
    g2 = rng.integers(-1, 12, size=15).astype(np.int32)    # dst -> mid
    g = compose_gather(g1, g2)
    # dense boolean reference: R1 (src x mid) @ R2 (mid x dst)
    r1 = np.zeros((10, 12), dtype=bool)
    r1[g1[g1 >= 0], np.flatnonzero(g1 >= 0)] = True
    r2 = np.zeros((12, 15), dtype=bool)
    r2[g2[g2 >= 0], np.flatnonzero(g2 >= 0)] = True
    ref = (r1.astype(int) @ r2.astype(int)) > 0
    got = np.zeros_like(ref)
    got[g[g >= 0], np.flatnonzero(g >= 0)] = True
    np.testing.assert_array_equal(got, ref)


def test_chain_gather_folds_structured_paths():
    idx, sink = _selection_chain()
    chain = path_tensors(idx, "src", sink)
    g = chain_gather(chain)
    assert g is not None and g.dtype == np.int32
    # equals the bitplane einsum composition of the same chain
    from repro.core.compose import compose_chain
    bits = compose_chain(idx, "src", sink, use_pallas=False)
    dense = unpack_bitplane(bits, idx.datasets[sink].n_rows)
    ref = np.zeros_like(dense)
    ref[g[g >= 0], np.flatnonzero(g >= 0)] = True
    np.testing.assert_array_equal(dense, ref)


@pytest.mark.parametrize("forced", ["csr", "bitplane"])
def test_structured_hopcache_matches_forced_backends(forced):
    if forced == "csr":
        pytest.importorskip("scipy")
    idx, sink = _selection_chain()
    auto = ComposedIndex(idx)                        # host default: auto
    ref = ComposedIndex(idx, backend=forced)
    rng = np.random.default_rng(11)
    n_src, n_sink = idx.datasets["src"].n_rows, idx.datasets[sink].n_rows
    probes_f = [[0], sorted(rng.choice(n_src, 4, replace=False).tolist()), []]
    probes_b = [[0], sorted(rng.choice(n_sink, 4, replace=False).tolist())]
    for a, b in zip(auto.q1_forward("src", probes_f, sink),
                    ref.q1_forward("src", probes_f, sink)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(auto.q2_backward(sink, probes_b, "src"),
                    ref.q2_backward(sink, probes_b, "src")):
        np.testing.assert_array_equal(a, b)
    # the whole chain composed without leaving the implicit form
    st = auto.stats()
    assert st["entries_structured"] == st["entries"] > 0
    assert auto.relation_backend("src", sink) == "structured"
    assert auto.conversions == 0
    # relation_csr (the federation hook) agrees with the forced-CSR relation
    if forced == "csr":
        a = auto.relation_csr("src", sink).toarray()
        b = ref.relation_csr("src", sink).toarray()
        np.testing.assert_array_equal(a > 0, b > 0)


def test_identity_chain_composes_to_free_identity_entry():
    idx = ProvenanceIndex("ident")
    d = track(Table.from_columns({"x": np.zeros(50, np.float32)}), idx, "src")
    for _ in range(4):
        d = d.value_transform("x", "scale", factor=2.0)
    d.mark_sink()
    sink = idx.sinks()[0]
    ci = ComposedIndex(idx)
    np.testing.assert_array_equal(ci.q1_forward("src", [3, 7], sink), [3, 7])
    np.testing.assert_array_equal(ci.q2_backward(sink, [1], "src"), [1])
    entry = ci._relation_entry("src", sink)
    assert entry.backend == "structured" and entry.rel is None
    assert entry.nbytes() == 0                      # pure identity: FREE
    assert ci.stats()["bytes"] == 0


def test_append_union_distributes_over_blocks():
    """Block-append distribution: the union of the two branch contributions
    lands in disjoint output blocks and STAYS a structured gather."""
    idx = ProvenanceIndex("append")
    rng = np.random.default_rng(2)
    t = Table.from_columns({"x": rng.normal(size=30).astype(np.float32)})
    d = track(t, idx, "src")
    top = d.filter_rows(np.arange(30) % 2 == 0)
    bot = d.filter_rows(np.arange(30) % 3 == 0)
    app = top.append(bot)
    app.mark_sink()
    sink = app.dataset_id
    ci = ComposedIndex(idx)
    entry = ci._relation_entry("src", sink)
    assert entry.backend == "structured" and entry.rel is not None
    # parity with the walking engine on both directions
    np.testing.assert_array_equal(
        ci.q1_forward("src", [0], sink), tqp.ref_q1(idx, "src", [0], sink))
    np.testing.assert_array_equal(
        ci.q2_backward(sink, [0], "src"), tqp.ref_q2(idx, sink, [0], "src"))


def test_agreeing_diamond_stays_structured():
    """A diamond joined on a UNIQUE key: the two branch gathers agree on
    every output row, so their union is still one gather — no densification."""
    idx, sink = pipegen.diamond_pipeline(0)
    ci = ComposedIndex(idx)
    want = tqp.ref_q1(idx, "src", [0, 3], sink)
    np.testing.assert_array_equal(ci.q1_forward("src", [0, 3], sink), want)
    assert ci._relation_entry("src", sink).backend == "structured"
    assert ci.conversions == 0


def test_overlapping_union_densifies_with_conversion():
    """A join on a LOW-CARDINALITY key: output rows have left and right
    parents tracing to DIFFERENT src rows, the branch gathers disagree, and
    the union leaves the closed form (conversion counted) — parity holds."""
    pytest.importorskip("scipy")
    rng = np.random.default_rng(4)
    n = 24
    idx = ProvenanceIndex("densediamond")
    t = Table.from_columns({
        "k": rng.integers(0, 3, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
    })
    s = track(t, idx, "src")
    a = s.filter_rows(np.ones(n, dtype=bool))
    b = s.value_transform("x", "scale", factor=2.0)
    j = a.join(b, on="k", how="inner").mark_sink()
    sink = j.dataset_id
    ci = ComposedIndex(idx)
    want = tqp.ref_q1(idx, "src", [0, 3], sink)
    np.testing.assert_array_equal(ci.q1_forward("src", [0, 3], sink), want)
    np.testing.assert_array_equal(
        ci.q2_backward(sink, [0], "src"), tqp.ref_q2(idx, sink, [0], "src"))
    entry = ci._relation_entry("src", sink)
    assert entry.backend in ("csr", "bitplane")
    assert ci.conversions >= 1
    st = ci.stats()
    assert st["entries"] == (st["entries_csr"] + st["entries_bitplane"]
                             + st["entries_structured"])


# ===========================================================================
# Hop-cache byte accounting for structured entries
# ===========================================================================
def test_structured_entry_bytes_reflect_implicit_form():
    """A composed chain of selections costs ONE int32 array, not a CSR."""
    idx, sink = _selection_chain(n=200, n_ops=6)
    ci = ComposedIndex(idx)
    ci.q1_forward("src", [0], sink)
    entry = ci._relation_entry("src", sink)
    assert entry.backend == "structured"
    n_sink = idx.datasets[sink].n_rows
    assert entry.nbytes() == 4 * n_sink            # one int32 per sink row
    # ... and the cache's global accounting is the sum of implicit payloads
    assert ci.stats()["bytes"] == sum(
        e.nbytes() for e in ci._cache.values())
    # a CSR of the same relation would be strictly larger
    csr = ComposedIndex(idx, backend="csr")
    csr.q1_forward("src", [0], sink)
    assert csr._relation_entry("src", sink).nbytes() > entry.nbytes()


def test_structured_insert_overwrite_and_eviction_accounting():
    from repro.core.hopcache import _Entry

    idx, sink = _selection_chain(n=40, n_ops=3)
    ci = ComposedIndex(idx, memory_budget_bytes=384)
    g = np.arange(64, dtype=np.int32)
    e1 = _Entry("structured", g, 64, 64, 64)
    ci._insert(("a", "b"), e1)
    assert ci._bytes == g.nbytes
    # overwrite releases the old entry's bytes first (no double count)
    ci._insert(("a", "b"), _Entry("structured", g.copy(), 64, 64, 64))
    assert ci._bytes == g.nbytes
    # inserting more structured entries evicts LRU-first under the budget
    ci._insert(("c", "d"), _Entry("structured", g.copy(), 64, 64, 64))
    assert ci._bytes <= 384 and ci.evictions >= 1
    # an entry larger than the whole budget is served uncached
    big = _Entry("structured", np.arange(1024, dtype=np.int32), 1024, 1024, 1024)
    before = ci._bytes
    ci._insert(("e", "f"), big)
    assert ci._bytes == before and ("e", "f") not in ci._cache


def test_relation_hands_out_private_arrays():
    """relation() on a structured entry answers a COPY (the cached gather
    may be an op tensor's own capture payload); identity chains materialize
    the arange instead of leaking the rel=None sentinel."""
    idx, sink = _selection_chain(n=40, n_ops=4)
    ci = ComposedIndex(idx)
    g = ci.relation("src", sink)
    assert isinstance(g, np.ndarray) and g.dtype == np.int32
    g[:] = -5                       # mutate the handed-out array...
    entry = ci._relation_entry("src", sink)
    assert np.count_nonzero(entry.gather() >= 0) == entry.nnz   # cache intact
    np.testing.assert_array_equal(
        ci.q1_forward("src", [0], sink), tqp.ref_q1(idx, "src", [0], sink))
    # pure identity chain: an int32 arange, not None
    idx2 = ProvenanceIndex("ident2")
    d = track(Table.from_columns({"x": np.zeros(9, np.float32)}), idx2, "src")
    d = d.value_transform("x", "scale", factor=2.0)
    d.mark_sink()
    np.testing.assert_array_equal(
        ci2_rel := ComposedIndex(idx2).relation("src", idx2.sinks()[0]),
        np.arange(9, dtype=np.int32))


def test_identity_elimination_does_not_alias_cache_entries():
    """prefix ∘ I copies the relation: two cache entries must never share
    one array, or the byte budget double-counts and eviction frees nothing."""
    idx, sink = _selection_chain(n=40, n_ops=4)   # filter at op 1, then more
    ci = ComposedIndex(idx)
    ci.relation("src", sink)
    rel_ids = [id(e.rel) for e in ci._cache.values() if e.rel is not None]
    assert len(rel_ids) == len(set(rel_ids))
    # and the global byte count is the sum over genuinely distinct arrays
    assert ci.stats()["bytes"] == sum(
        e.nbytes() for e in ci._cache.values())


def test_structured_conversion_roundtrip_preserves_relation():
    pytest.importorskip("scipy")
    idx, sink = _selection_chain(n=60, n_ops=4)
    ci = ComposedIndex(idx)
    entry = ci._relation_entry("src", sink)
    assert entry.backend == "structured"
    as_csr = ci._to_csr(entry)
    as_bp = ci._to_bitplane(entry)
    assert ci.conversions == 2
    dense_csr = np.asarray(as_csr.rel.toarray()) > 0
    dense_bp = unpack_bitplane(as_bp.rel, entry.cols)
    ref = np.zeros((entry.rows, entry.cols), dtype=bool)
    g = entry.rel
    ref[g[g >= 0], np.flatnonzero(g >= 0)] = True
    np.testing.assert_array_equal(dense_csr, ref)
    np.testing.assert_array_equal(dense_bp, ref)
    assert as_csr.nnz == as_bp.nnz == entry.nnz


# ===========================================================================
# Cost model: structured chains are priced at the closed form
# ===========================================================================
def test_costmodel_prices_structured_chains_cheaper():
    from repro.core import costmodel as cm

    s_idx, s_sink = _selection_chain()
    c_idx, c_sink = _selection_chain(structured=False)
    s_rel, s_cost = cm.CostModel(s_idx).composed_estimate("src", s_sink)
    c_rel, c_cost = cm.CostModel(c_idx).composed_estimate("src", c_sink)
    assert s_rel.structured and not c_rel.structured
    assert (s_rel.rows, s_rel.cols, s_rel.nnz) == (c_rel.rows, c_rel.cols, c_rel.nnz)
    assert s_cost < c_cost                  # closed form beats spmm pricing
    assert s_rel.est_bytes() <= 4 * s_rel.cols
    # ... and the session surfaces the structured verdict through explain()
    sess = QuerySession(s_idx, ComposedIndex(s_idx))
    out = sess.explain(prov(s_idx).source("src").rows([0])
                       .forward().to(s_sink).plan())
    assert out["cost"]["structured"] is True


def test_slot_structure_taxonomy():
    assert isinstance(identity_tensor(4).slot_structure(0), SlotIdentity)
    assert isinstance(hreduce_tensor(np.array([1, 2]), 4).slot_structure(0),
                      SlotGather)
    t = append_tensor(3, 2)
    assert t.slot_structure(0) == SlotRange(0, 3)
    assert t.slot_structure(1) == SlotRange(3, 2)
    assert identity_tensor(4, structured=False).slot_structure(0) is None
