"""The query-planning cost model (repro.core.costmodel) and its consumers:
nnz-aware chain planning, density-driven backend selection in the
ComposedIndex, demand-amortized walk-vs-compose routing in QuerySession,
the hopcache_min_batch deprecation, and the _insert byte-accounting
regression.
"""
import warnings

import numpy as np
import pytest

import test_query_parity as tqp
from repro.core import costmodel as cm
from repro.core.costmodel import CostModel, RelStats
from repro.core.hopcache import ComposedIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import QuerySession, prov


def _chain_index(n=300, n_ops=8):
    """A moderately deep linear pipeline for routing tests."""
    rng = np.random.default_rng(3)
    idx = ProvenanceIndex("chain")
    t = Table.from_columns({
        "k": rng.integers(0, n // 2, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
    })
    d = track(t, idx, "src")
    for i in range(n_ops):
        if i % 3 == 1:
            mask = np.ones(d.table.n_rows, dtype=bool)
            mask[i::11] = False
            d = d.filter_rows(mask)
        else:
            d = d.value_transform("x", "scale", factor=1.01)
    d.mark_sink()
    return idx, d.dataset_id


# ===========================================================================
# RelStats + estimates
# ===========================================================================
def test_relstats_density_and_slot_accessors():
    idx, sink = _chain_index(n=50, n_ops=2)
    op = idx.ops[0]
    s = RelStats.from_slot(op.tensor, 0)
    assert s.rows == op.tensor.n_in[0] and s.cols == op.tensor.n_out
    assert s.nnz == op.tensor.slot_nnz(0)
    assert s.density == pytest.approx(op.tensor.slot_density(0))
    assert 0.0 < s.density <= 1.0
    # sentinel links (-1) are not relation entries
    from repro.core.provtensor import append_tensor
    t = append_tensor(4, 3)
    assert t.slot_nnz(0) == 4 and t.slot_nnz(1) == 3
    assert t.nnz == 7  # COO rows (one per output record)


def test_compose_est_saturates_and_preserves_shape():
    a = RelStats(100, 50, 200)
    b = RelStats(50, 80, 400)
    c = cm.compose_est(a, b)
    assert (c.rows, c.cols) == (100, 80)
    assert 0 <= c.nnz <= c.rows * c.cols
    # a full × full compose saturates at full
    full = cm.compose_est(RelStats(10, 10, 100), RelStats(10, 10, 100))
    assert full.density == pytest.approx(1.0, abs=1e-6)
    # empty operands compose to empty
    assert cm.compose_est(RelStats(10, 10, 0), b_ := RelStats(10, 10, 50)).nnz == 0


def test_spmm_cost_scales_with_nnz_not_dims():
    sparse = RelStats(10_000, 10_000, 100)
    dense = RelStats(100, 100, 10_000)
    assert cm.spmm_cost(sparse, sparse) < cm.spmm_cost(dense, dense)
    # the dims-only view would order these the other way around
    assert sparse.rows * sparse.cols > dense.rows * dense.cols


def test_pick_backend_threshold():
    assert cm.pick_backend(cm.DENSITY_THRESHOLD / 10) == "csr"
    assert cm.pick_backend(cm.DENSITY_THRESHOLD * 2) == "bitplane"
    assert cm.pick_backend(0.0, have_scipy=False) == "bitplane"


# ===========================================================================
# nnz-aware chain DP
# ===========================================================================
def test_plan_chain_stats_same_merge_contract_as_dims_dp():
    from repro.core.compose import plan_chain

    # uniform density: the nnz DP must agree with the classic dims DP on the
    # textbook example (10x100)(100x5)(5x50) -> ((A B) C)
    dims = [(10, 100), (100, 5), (5, 50)]
    stats = [RelStats(r, c, r * c // 2) for r, c in dims]
    assert cm.plan_chain_stats(stats) == plan_chain(dims)


def _canon_est(stats, lo, hi):
    acc = stats[lo]
    for j in range(lo + 1, hi + 1):
        acc = cm.compose_est(acc, stats[j])
    return acc


def _eval_order(stats, order, backend="csr"):
    """Model cost of an arbitrary merge order (the compose_chain protocol:
    (i, _) merges the segment at original index i with the next live one)."""
    segs = {i: (i, i) for i in range(len(stats))}
    cost = 0.0
    for (i, _k) in order:
        j = i + 1
        while j not in segs:
            j += 1
        (alo, ahi), (blo, bhi) = segs[i], segs[j]
        cost += cm.compose_cost_pair(_canon_est(stats, alo, ahi),
                                     _canon_est(stats, blo, bhi), backend)
        segs[i] = (alo, bhi)
        del segs[j]
    return cost


def _all_orders(n):
    def rec(live):
        if len(live) == 1:
            yield []
            return
        for x in range(len(live) - 1):
            for rest in rec(live[: x + 1] + live[x + 2:]):
                yield [(live[x], 0)] + rest
    yield from rec(list(range(n)))


def _random_stats(rng, n=4):
    stats = []
    r = int(rng.integers(5, 2000))
    for _ in range(n):
        c = int(rng.integers(5, 2000))
        density = 10 ** rng.uniform(-3, 0)
        stats.append(RelStats(r, c, max(1, int(r * c * density))))
        r = c
    return stats


@pytest.mark.parametrize("seed", range(12))
def test_plan_chain_stats_is_optimal_under_the_model(seed):
    """Brute-force every parenthesization of a random length-4 chain: the
    DP's order must achieve the minimal model cost."""
    stats = _random_stats(np.random.default_rng(seed))
    dp_cost = _eval_order(stats, cm.plan_chain_stats(stats, backend="csr"))
    best = min(_eval_order(stats, o) for o in _all_orders(len(stats)))
    assert dp_cost <= best + 1e-6


def test_plan_chain_stats_beats_dims_only_plan_on_sparse_chains():
    """Seed where the dims-only DP picks an order the nnz model prices >3x
    worse — the mis-planning this PR removes (densities span 0.1%..100%)."""
    from repro.core.compose import plan_chain

    stats = _random_stats(np.random.default_rng(5))
    dims = [(s.rows, s.cols) for s in stats]
    nnz_order = cm.plan_chain_stats(stats, backend="csr")
    dims_order = plan_chain(dims)
    assert nnz_order != dims_order
    assert _eval_order(stats, dims_order) > 3 * _eval_order(stats, nnz_order)


def test_compose_chain_parity_with_nnz_plan():
    """The nnz-aware merge order changes cost, never the relation."""
    from repro.core.compose import compose_chain

    idx, sink = _chain_index(n=60, n_ops=5)
    a = compose_chain(idx, "src", sink, use_pallas=False, optimize=False)
    b = compose_chain(idx, "src", sink, use_pallas=False, optimize=True)
    np.testing.assert_array_equal(a, b)


# ===========================================================================
# CostModel chain statistics + routing decisions
# ===========================================================================
def test_chain_stats_matches_dag_and_caches():
    idx, sink = _chain_index()
    model = CostModel(idx)
    chain = model.chain_stats("src", sink)
    assert chain is not None and len(chain) == len(idx.ops)
    assert chain[0].rows == idx.datasets["src"].n_rows
    assert chain[-1].cols == idx.datasets[sink].n_rows
    assert model.chain_stats("src", sink) is chain          # cached
    assert model.chain_stats(sink, "src") is None           # no reverse path
    assert model.chain_stats("src", "src") == []


def test_choose_amortizes_demand_for_small_probe_streams():
    idx, sink = _chain_index()
    model = CostModel(idx)
    first = model.choose("src", sink, 1, 1.0)
    assert first["strategy"] == "walk"        # one tiny probe: walking wins
    # keep pushing single-probe demand at the same pair: the one-time compose
    # cost amortizes away and the decision flips to the hop-cache
    decisions = [model.choose("src", sink, 1, 1.0)["strategy"]
                 for _ in range(200)]
    assert "hopcache" in decisions
    flip = decisions.index("hopcache")
    assert all(d == "hopcache" for d in decisions[flip:])   # flips ONCE


def test_choose_routes_large_cold_batch_to_hopcache():
    idx, sink = _chain_index(n=1000)
    model = CostModel(idx)
    assert model.choose("src", sink, 64, 4.0)["strategy"] == "hopcache"


def test_composed_estimate_models_the_dag_not_a_chain():
    """On a diamond, the composed estimate must accumulate the way the
    executor does — compose along edges, union sibling branches — instead
    of folding parallel branch ops into one bogus linear chain."""
    idx, sink = tqp._diamond_pipeline(0)
    model = CostModel(idx)
    rel, cost = model.composed_estimate("src", sink)
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    assert (rel.rows, rel.cols) == (n_src, n_sink)
    assert 0 < rel.nnz <= n_src * n_sink
    assert cost > 0
    # estimate is the same object the routing decision consumes, and cached
    assert model.composed_estimate("src", sink) is model.composed_estimate("src", sink)
    # no path -> (None, 0)
    assert model.composed_estimate(sink, "src") == (None, 0.0)
    # an adjacent pair reuses the op's own relation: zero compose work
    first_out = idx.ops[0].output_id
    rel1, cost1 = model.composed_estimate("src", first_out)
    assert cost1 == 0.0 and rel1.nnz == idx.ops[0].tensor.slot_nnz(0)


def test_unretainable_relation_never_flips_to_hopcache():
    """Regression: with a cache budget too small to retain the composed
    relation, accumulated demand must NOT flip routing to 'hopcache' —
    that would recompose the whole chain on every probe, forever."""
    idx, sink = _chain_index(n=1000)
    ci = ComposedIndex(idx, memory_budget_bytes=1024)
    sess = QuerySession(idx, ci)
    for i in range(40):
        sess.run(prov(idx).source("src").rows([i % 10]).forward().to(sink).plan())
    assert sess.counters["hopcache"] == 0 and sess.counters["walk"] == 40
    # and the model reports why: the relation is not retainable
    c = sess.explain(prov(idx).source("src").rows([0]).forward().to(sink).plan())
    assert c["cost"]["retainable"] is False


def test_relT_materialization_respects_budget():
    """Regression: the lazy transposed plane must not push a sole cached
    entry past memory_budget_bytes (un-evictable), only retain when it
    fits."""
    from repro.core.hopcache import _Entry
    from repro.core.provtensor import pack_bitplane, unpack_bitplane

    idx, sink = _chain_index(n=40, n_ops=2)
    rng = np.random.default_rng(1)
    dense = rng.random((60, 300)) < 0.3
    rel = pack_bitplane(dense)                        # 60 x 10 words = 2400 B
    entry = _Entry("bitplane", rel, 60, 300, int(dense.sum()))
    ci = ComposedIndex(idx, backend="bitplane",
                       memory_budget_bytes=entry.nbytes() + 100)  # relT won't fit
    ci._insert(("a", "b"), entry)
    relT = ci._entry_relT(("a", "b"), entry)
    np.testing.assert_array_equal(unpack_bitplane(relT, 60), dense.T)
    assert entry.relT is None                         # served transiently
    assert ci._bytes <= ci.memory_budget_bytes        # invariant holds
    # with room, the plane IS retained and accounted
    ci2 = ComposedIndex(idx, backend="bitplane",
                        memory_budget_bytes=1 << 20)
    e2 = _Entry("bitplane", rel.copy(), 60, 300, int(dense.sum()))
    ci2._insert(("a", "b"), e2)
    ci2._entry_relT(("a", "b"), e2)
    assert e2.relT is not None
    assert ci2._bytes == e2.nbytes()


def test_co_query_pricing_covers_both_legs():
    """co_dependency/co_contributory compose TWO relations on the hopcache
    path; the planner must price both, not half the real cost."""
    idx, sink = _chain_index(n=400, n_ops=6)
    mid = idx.ops[2].output_id
    sess = QuerySession(idx, ComposedIndex(idx))
    p = prov(idx).source(mid).rows([0]).co_dependency("src", sink).plan()
    assert sess._plan_pairs(p) == [("src", mid), ("src", sink)]
    c = sess.explain(p)["cost"]
    assert c["legs"] is not None and len(c["legs"]) == 2
    assert c["walk_ns"] == pytest.approx(
        sum(leg["walk_ns"] for leg in c["legs"]))
    p10 = prov(idx).source("src").rows([0]).co_contributory(mid, via=sink).plan()
    assert sess._plan_pairs(p10) == [("src", sink), (mid, sink)]


def test_choose_no_path_walks():
    idx, sink = _chain_index()
    model = CostModel(idx)
    assert model.choose(sink, "src", 64, 4.0)["strategy"] == "walk"


def test_explain_does_not_mutate_demand():
    idx, sink = _chain_index()
    sess = QuerySession(idx, ComposedIndex(idx))
    p = prov(idx).source("src").rows([0]).forward().to(sink).plan()
    before = dict(sess.costmodel._demand)
    out = sess.explain(p)
    assert out["strategy"] in ("walk", "hopcache")
    assert "cost" in out and out["cost"]["walk_ns"] > 0
    assert sess.costmodel._demand == before


# ===========================================================================
# QuerySession routing counters (small-batch/cached vs large-batch/cold)
# ===========================================================================
def test_session_cost_model_routing_counters():
    idx, sink = _chain_index(n=1000)
    sess = QuerySession(idx, ComposedIndex(idx))
    # cold single-probe plans walk at first, then flip once demand amortizes
    for i in range(40):
        sess.run(prov(idx).source("src").rows([i % 10]).forward().to(sink).plan())
    assert sess.counters["walk"] >= 1
    assert sess.counters["hopcache"] >= 1
    walked = sess.counters["walk"]
    # once the relation is cached, even B=1 plans probe it (contains() path)
    sess.run(prov(idx).source("src").rows([0]).forward().to(sink).plan())
    assert sess.counters["walk"] == walked

    # a LARGE cold batch routes straight to the hop-cache on a fresh session
    fresh = QuerySession(idx, ComposedIndex(idx))
    probes = [[i % 10] for i in range(64)]
    fresh.run(prov(idx).source(sink).rows_batch(probes).backward().to("src").plan())
    assert fresh.counters == {**fresh.counters, "hopcache": 1, "walk": 0}


def test_hopcache_min_batch_deprecated_but_honored():
    idx, sink = _chain_index()
    with pytest.warns(DeprecationWarning, match="hopcache_min_batch"):
        legacy = QuerySession(idx, ComposedIndex(idx), hopcache_min_batch=8)
    # the legacy heuristic never composes for sub-threshold probes, no matter
    # how much demand accumulates — the mis-routing the cost model fixes
    for i in range(40):
        legacy.run(prov(idx).source("src").rows([i % 10]).forward().to(sink).plan())
    assert legacy.counters["walk"] == 40 and legacy.counters["hopcache"] == 0
    # ... and still routes >= min_batch probes to the hop-cache
    legacy.run(prov(idx).source("src")
               .rows_batch([[i] for i in range(8)]).forward().to(sink).plan())
    assert legacy.counters["hopcache"] == 1
    # default sessions carry no heuristic and emit no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        QuerySession(idx, ComposedIndex(idx))


# ===========================================================================
# ComposedIndex: byte accounting + auto-backend mixing
# ===========================================================================
def test_insert_overwrite_releases_old_bytes():
    """Regression: re-inserting an existing key must subtract the old
    entry's size — _bytes used to inflate and force spurious evictions."""
    idx, sink = _chain_index(n=40, n_ops=2)
    ci = ComposedIndex(idx, backend="bitplane")
    from repro.core.hopcache import _Entry

    rel = np.ones((8, 4), dtype=np.uint32)
    entry = _Entry("bitplane", rel, 8, 128, 1024)
    ci._insert(("a", "b"), entry)
    once = ci._bytes
    assert once == entry.nbytes()
    for _ in range(5):
        ci._insert(("a", "b"), _Entry("bitplane", rel.copy(), 8, 128, 1024))
    assert ci._bytes == once                       # no double counting
    assert ci.evictions == 0                       # no spurious evictions


def test_insert_overwrite_under_tight_budget_no_spurious_evictions():
    idx, sink = _chain_index(n=40, n_ops=2)
    from repro.core.hopcache import _Entry

    rel = np.ones((64, 8), dtype=np.uint32)        # 2 KiB
    other = np.ones((32, 8), dtype=np.uint32)      # 1 KiB
    ci = ComposedIndex(idx, backend="bitplane", memory_budget_bytes=4096)
    ci._insert(("x", "y"), _Entry("bitplane", other, 32, 256, 10))
    for _ in range(10):                            # would blow 4 KiB if leaked
        ci._insert(("a", "b"), _Entry("bitplane", rel.copy(), 64, 256, 10))
    assert ("x", "y") in ci._cache and ("a", "b") in ci._cache
    assert ci.evictions == 0
    assert ci._bytes == sum(e.nbytes() for e in ci._cache.values())


def _dense_join_pipeline():
    """Two stacked diamonds re-joined on a 3-valued key: each diamond UNIONS
    two branch contributions and multiplies fan-out, so the accumulated
    src→sink relation densifies past the cost model's threshold mid-chain —
    the sparse prefix must stay CSR while the blow-up converts to packed
    bitplanes, in ONE cache."""
    rng = np.random.default_rng(7)
    n = 24
    idx = ProvenanceIndex("densejoin")
    t = Table.from_columns({
        "k": rng.integers(0, 3, n).astype(np.float32),   # 3 join keys
        "x": rng.normal(size=n).astype(np.float32),
    })
    s = track(t, idx, "src")
    a = s.filter_rows(np.ones(n, dtype=bool))
    b = s.value_transform("x", "scale", factor=2.0)
    j = a.join(b, on="k", how="inner")                   # diamond 1
    col = [c for c in j.table.columns if c != "k"][0]
    a2 = j.filter_rows(np.ones(j.table.n_rows, dtype=bool))
    b2 = j.value_transform(col, "scale", factor=3.0)
    j2 = a2.join(b2, on="k", how="inner")                # diamond 2
    j2.mark_sink()
    return idx, j2.dataset_id


def test_auto_mixes_backends_in_one_cache_with_parity():
    """Pinned to forced-COO capture: with structured tensors the sparse
    prefixes stay implicit gathers and never touch CSR — this test exercises
    the explicit csr↔bitplane conversion machinery (densification mid-chain),
    which must keep working for unstructured relations.  The structured
    three-way mix is covered in tests/test_structured.py."""
    pytest.importorskip("scipy")
    from repro.core.capture import force_coo_capture
    with force_coo_capture():
        idx, sink = _dense_join_pipeline()
    auto = ComposedIndex(idx, backend="auto")
    want = tqp.ref_q1(idx, "src", [0, 5], sink)
    np.testing.assert_array_equal(auto.q1_forward("src", [0, 5], sink), want)
    st = auto.stats()
    assert st["entries_csr"] > 0 and st["entries_bitplane"] > 0
    assert auto.conversions >= 1     # a CSR accumulation densified mid-chain
    # the src->sink relation itself crossed the density threshold
    assert auto.relation_backend("src", sink) == "bitplane"
    assert auto._relation_entry("src", sink).density >= cm.DENSITY_THRESHOLD
    # parity against both forced backends on forward AND backward probes
    for be in ("csr", "bitplane"):
        forced = ComposedIndex(idx, backend=be)
        np.testing.assert_array_equal(
            forced.q1_forward("src", [0, 5], sink), want)
        for a_, f_ in zip(auto.q2_backward(sink, [[0], [1, 2]], "src"),
                          forced.q2_backward(sink, [[0], [1, 2]], "src")):
            np.testing.assert_array_equal(a_, f_)


def test_bitplane_backward_probe_matches_reference_loop():
    """The vectorized transposed-plane backward probe == the old per-probe
    row-scan loop, bit for bit."""
    idx, sink = _chain_index(n=150, n_ops=6)
    ci = ComposedIndex(idx, backend="bitplane")
    entry = ci._relation_entry("src", sink)
    rng = np.random.default_rng(0)
    n_sink = idx.datasets[sink].n_rows
    masks = rng.random((17, n_sink)) < 0.05
    masks[3] = False                                # an empty probe too
    got = ci.probe_backward(masks, sink, "src")
    from repro.core.provtensor import pack_bitplane
    words = pack_bitplane(masks)
    want = np.stack([(entry.rel & w[None, :]).any(axis=1) for w in words], axis=0)
    np.testing.assert_array_equal(got, want)
    # the transposed plane was cached on the entry and accounted
    assert entry.relT is not None
    assert ci._bytes >= entry.relT.nbytes
