"""Per-architecture smoke tests (reduced configs, CPU): shapes + finiteness,
decode == forward, one train step moves the loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, make_train_step

ALL_ARCHS = list(ARCHS)


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks,
             "labels": jnp.concatenate([toks[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(cfg, key)
    b = _batch(cfg, key)
    if cfg.is_encdec:
        logits = m.forward(cfg, params, b["frames"], b["tokens"])
    else:
        logits = m.forward(cfg, params, b["tokens"])
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    key = jax.random.PRNGKey(1)
    state = init_train_state(cfg, key, opt)
    step = jax.jit(make_train_step(cfg, opt, n_micro=2))
    b = _batch(cfg, key, b=4)
    new_state, metrics = step(state, b)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["gemma3-12b", "mamba2-370m",
                                  "jamba-1.5-large-398b", "whisper-small"])
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    m = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    cache = m.init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.is_encdec:
        from repro.models import whisper as W
        frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        full = m.forward(cfg, params, frames, toks, dtype=jnp.float32)
        cache = W.encode_into_cache(cfg, params, frames, cache)
    else:
        full = m.forward(cfg, params, toks, dtype=jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = m.decode_step(cfg, params, toks[:, t], jnp.int32(t),
                                      cache, dtype=jnp.float32)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-3, rel


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-moe-235b-a22b"])
def test_prefill_matches_forward_last_position(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    m = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    full = m.forward(cfg, params, toks, dtype=jnp.float32)
    logits, cache = m.prefill(cfg, params, toks, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1, :]),
                               rtol=2e-3, atol=2e-3)


def test_param_count_formula_matches_actual():
    for arch in ALL_ARCHS:
        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.n_params()
        assert abs(actual - predicted) / actual < 0.05, (arch, actual, predicted)


def test_moe_routes_to_multiple_experts():
    from repro.models.moe import init_moe, moe_forward
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    y = moe_forward(cfg, p, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    # zero input -> zero output (experts have no bias)
    y0 = moe_forward(cfg, p, jnp.zeros_like(x))
    assert float(jnp.abs(y0).max()) < 1e-5
