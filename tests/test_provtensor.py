"""Unit tests: ProvTensor constructors, CSR probes, bitplanes, set-semantics."""
import numpy as np
import pytest

from repro.core.provtensor import (
    CSR, ProvTensor, append_tensor, haugment_tensor, hreduce_tensor,
    identity_tensor, join_tensor, pack_bitplane, unpack_bitplane,
)


def test_identity_tensor():
    t = identity_tensor(5)
    assert t.nnz == 5 and t.n_out == 5 and t.n_in == (5,)
    assert t.forward_rows(0, [2]).tolist() == [2]
    assert t.backward_rows(0, [4]).tolist() == [4]


def test_hreduce_masking_tensor():
    # paper §III-A c: some input columns all-zero (filtered out)
    t = hreduce_tensor(np.array([1, 3, 4]), n_in=6)
    assert t.n_out == 3
    assert t.forward_rows(0, [3]).tolist() == [1]     # input 3 -> output 1
    assert t.forward_rows(0, [0]).tolist() == []      # filtered out
    assert t.backward_rows(0, [2]).tolist() == [4]


def test_haugment_with_synthetic_rows():
    # -1 = synthetic row with no establishable mapping (paper §III-A e)
    t = haugment_tensor(np.array([0, 1, 1, -1]), n_in=2)
    assert t.backward_rows(0, [2]).tolist() == [1]
    assert t.backward_rows(0, [3]).tolist() == []
    assert sorted(t.forward_rows(0, [1]).tolist()) == [1, 2]


def test_join_tensor_paper_example():
    # paper Tables II-IV: T[1,2,1]=1 and T[2,4,2]=1 (1-based); 0-based here
    t = join_tensor(np.array([[1, 0], [3, 1]]), n_left=4, n_right=2)
    assert t.k == 2 and t.n_out == 2
    assert t.backward_rows(0, [0]).tolist() == [1]    # left parent of out 0
    assert t.backward_rows(1, [0]).tolist() == [0]    # right parent of out 0
    assert t.forward_rows(0, [3]).tolist() == [1]
    assert t.forward_rows(0, [0]).tolist() == []      # dangling left row


def test_append_block_diagonal():
    t = append_tensor(3, 2)
    assert t.n_out == 5
    assert t.backward_rows(0, [1]).tolist() == [1]    # left block
    assert t.backward_rows(1, [4]).tolist() == [1]    # right block
    assert t.backward_rows(0, [4]).tolist() == []     # right rows have no left parent
    assert t.forward_rows(1, [0]).tolist() == [3]


def test_csr_neighbor_mask_and_batch():
    rows = np.array([0, 0, 2, 3])
    cols = np.array([1, 4, 0, 2])
    csr = CSR.from_pairs(rows, cols, n_rows=4, n_cols=5)
    assert sorted(csr.neighbors(0).tolist()) == [1, 4]
    assert csr.neighbors(1).tolist() == []
    mask = csr.neighbor_mask(np.array([0, 3]))
    assert mask.tolist() == [False, True, True, False, True]
    table = csr.batch_neighbors(np.array([0, 1, 2]), max_deg=2)
    assert table.shape == (3, 2)
    assert set(table[0]) == {1, 4} and table[1].tolist() == [-1, -1]


def test_bitplane_roundtrip():
    rng = np.random.default_rng(0)
    for r, c in [(1, 1), (3, 31), (5, 32), (7, 33), (16, 100)]:
        dense = rng.random((r, c)) < 0.3
        packed = pack_bitplane(dense)
        assert packed.shape == (r, (c + 31) // 32)
        assert (unpack_bitplane(packed, c) == dense).all()


def test_tensor_bitplanes_match_coo():
    t = join_tensor(np.array([[1, 0], [3, 1], [3, 0]]), n_left=4, n_right=2)
    fwd = unpack_bitplane(t.bitplane_fwd(0), t.n_out)       # (n_left, n_out)
    assert fwd[1, 0] and fwd[3, 1] and fwd[3, 2] and fwd.sum() == 3
    bwd = unpack_bitplane(t.bitplane_bwd(1), t.n_in[1])     # (n_out, n_right)
    assert bwd[0, 0] and bwd[1, 1] and bwd[2, 0] and bwd.sum() == 3


def test_set_semantics_canonicalize():
    # paper §III-C.a: duplicates 2 and 4 (1-based) -> smallest id wins
    t = join_tensor(np.array([[0, 0], [1, 1], [2, 0], [1, 1]]), n_left=3, n_right=2)
    groups = np.array([0, 1, 2, 1])   # outputs 1 and 3 are value-duplicates
    c = t.canonicalize(groups)
    assert c.nnz == 3                  # the duplicate link merged
    assert sorted(c.backward_rows(0, [1]).tolist()) == [1]
    # querying the canonical id returns provenance of BOTH duplicates
    assert 1 in c.coo[:, 0]
    assert 3 not in c.coo[:, 0]


def test_nbytes_accounting():
    pairs = np.array([[1, 0], [3, 1]])
    t = join_tensor(pairs, n_left=4, n_right=2)
    base = t.nbytes()
    assert base == 2 * 2 * 4          # two implicit int32 gathers, no COO
    legacy = join_tensor(pairs, n_left=4, n_right=2, structured=False)
    assert legacy.nbytes() == legacy.coo.nbytes > base
    t.fwd(0); t.bwd(1)
    assert t.nbytes() > base          # built CSR mirrors are accounted
    assert t.nbytes(include_index=False) == base


def test_structured_tensors_store_implicit_forms():
    # identity and append are O(1) bytes; the COO mirror is lazy and exact
    ident = identity_tensor(1000)
    assert ident.structured and ident.nbytes() == 0
    app = append_tensor(3, 2)
    assert app.structured and app.nbytes() == 0
    legacy = append_tensor(3, 2, structured=False)
    np.testing.assert_array_equal(app.coo, legacy.coo)
    assert app.nnz == legacy.nnz == 5
    red = hreduce_tensor(np.array([1, 3, 4]), n_in=6)
    assert red.structured and red.nbytes() == 3 * 4
    np.testing.assert_array_equal(
        red.coo, hreduce_tensor(np.array([1, 3, 4]), 6, structured=False).coo)


def test_coo_validation():
    with pytest.raises(ValueError):
        ProvTensor(n_out=2, n_in=(2,), coo=np.zeros((3, 3), np.int32))


def test_set_semantics_via_table_duplicate_groups():
    """Paper §III-C.a end-to-end: querying a duplicate's canonical id returns
    the provenance of ALL value-identical output records."""
    from repro.dataprep.table import Table
    out_table = Table.from_columns({"k": [1., 2., 1., 3.], "v": [5., 6., 5., 7.]})
    groups = out_table.duplicate_groups()
    assert groups.tolist() == [0, 1, 0, 3]          # rows 0 and 2 identical
    t = join_tensor(np.array([[0, 0], [1, 1], [2, 0], [0, 1]]),
                    n_left=3, n_right=2)
    c = t.canonicalize(groups)
    # canonical record 0 now carries the parents of BOTH duplicates (rows 0, 2)
    assert sorted(c.backward_rows(0, [0]).tolist()) == [0, 2]
