"""The Chapman-style baseline must AGREE with TensProv on query answers
(same lineage, radically different cost — that's the paper's claim)."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.chapman import ChapmanIndex
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep import ops as P
from repro.dataprep.table import Table


def _dual_capture(ops_seq, sources):
    """Run the same op sequence through TensProv AND the Chapman baseline."""
    tens = ProvenanceIndex("t")
    chap = ChapmanIndex()
    tabs = {}
    for name, t in sources.items():
        tens.add_source(name, t)
        tabs[name] = t
    op_ids = []
    for i, (fn, in_names, out_name) in enumerate(ops_seq):
        ins = [tabs[n] for n in in_names]
        out, info = fn(*ins)
        tens.record(list(in_names), out_name, out, info,
                    keep_output=(i == len(ops_seq) - 1), input_tables=ins)
        chap.capture(list(in_names), ins, out_name, out, info)
        tabs[out_name] = out
        op_ids.append(i)
    return tens, chap, tabs, op_ids


def test_agreement_on_linear_chain():
    rng = np.random.default_rng(0)
    src = Table.from_columns({
        "a": rng.integers(0, 5, 30).astype(np.float32),
        "b": rng.normal(size=30).astype(np.float32),
        "c": rng.normal(size=30).astype(np.float32),
    })
    seq = [
        (lambda t: P.filter_rows(t, np.asarray(t.col("b")) > -1.0), ["S"], "F"),
        (lambda t: P.value_transform(t, "c", "clip", lo=-1, hi=1), ["F"], "T"),
        (lambda t: P.onehot(t, "a", n_values=5), ["T"], "O"),
    ]
    tens, chap, tabs, ids = _dual_capture(seq, {"S": src})
    n_out = tabs["O"].n_rows
    for row in range(0, n_out, 3):
        t_ans = Q.q2_backward(tens, "O", [row], "S").tolist()
        c_ans = chap.backward_rows(ids, [row]).tolist()
        assert t_ans == c_ans
    for row in range(0, src.n_rows, 5):
        t_ans = Q.q1_forward(tens, "S", [row], "O").tolist()
        c_ans = chap.forward_rows(ids, [row]).tolist()
        assert t_ans == c_ans


def test_agreement_on_join():
    rng = np.random.default_rng(1)
    l = Table.from_columns({"k": rng.integers(0, 8, 20).astype(np.float32),
                            "x": rng.normal(size=20).astype(np.float32)})
    r = Table.from_columns({"k": np.arange(8, dtype=np.float32),
                            "y": rng.normal(size=8).astype(np.float32)})
    tens = ProvenanceIndex("t")
    chap = ChapmanIndex()
    tens.add_source("L", l)
    tens.add_source("R", r)
    out, info = P.join(l, r, on="k", how="inner")
    tens.record(["L", "R"], "J", out, info, keep_output=True, input_tables=[l, r])
    chap.capture(["L", "R"], [l, r], "J", out, info)
    for row in range(out.n_rows):
        t_l = set(Q.q2_backward(tens, "J", [row], "L").tolist())
        t_r = set(Q.q2_backward(tens, "J", [row], "R").tolist())
        c = set(chap.backward_rows([0], [row]).tolist())
        # Chapman merges slots; with hash-matching duplicates may widen the
        # answer to value-identical rows — TensProv's must be a subset
        assert (t_l | t_r) <= c


def test_chapman_memory_is_larger():
    """Table IX's qualitative claim on any non-trivial pipeline."""
    rng = np.random.default_rng(2)
    src = Table.from_columns({f"a{i}": rng.normal(size=500).astype(np.float32)
                              for i in range(10)})
    seq = [
        (lambda t: P.filter_rows(t, np.asarray(t.col("a0")) > -0.5), ["S"], "F"),
        (lambda t: P.normalize(t, ["a1", "a2"]), ["F"], "N"),
        (lambda t: P.drop_columns(t, ["a9"]), ["N"], "D"),
    ]
    tens, chap, _, _ = _dual_capture(seq, {"S": src})
    assert chap.total_nbytes() > 5 * tens.prov_nbytes()
