"""Unit + property tests for bitset schema metadata and attribute maps."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import schema as sc


def test_bitset_paper_notation():
    b = sc.Bitset.from_string("10011")
    assert str(b) == "10011"
    assert b.popcount() == 3
    assert b.indices().tolist() == [0, 3, 4]
    assert b.test(0) and not b.test(1) and b.test(4)


def test_rank_select_inverse():
    b = sc.Bitset.from_string("1010110")
    for i in range(b.n):
        if b.test(i):
            assert b.select(b.rank(i)) == i


def test_map_vr_paper_example():
    # Table VI: bitset 10011 -> 2nd and 3rd attrs dropped (0-based: 1, 2)
    b = sc.Bitset.from_string("10011")
    assert sc.map_vr_f(b, 0) == 0
    assert sc.map_vr_f(b, 1) is None
    assert sc.map_vr_f(b, 3) == 1
    assert sc.map_vr_f(b, 4) == 2
    assert sc.map_vr_b(b, 0) == 0
    assert sc.map_vr_b(b, 1) == 3
    assert sc.map_vr_b(b, 2) == 4


def test_map_va_paper_example():
    # Table VI: 101011 with m=4 -> attrs 0,2 engineered the two new attrs
    b = sc.Bitset.from_string("101011")
    m = 4
    assert sc.map_va_f(m, 2) == 2
    assert sc.map_va_b(b, m, 1) == [1]            # preserved position
    assert sc.map_va_b(b, m, 4) == [0, 2]         # new attr -> source attrs
    assert sc.map_va_b(b, m, 5) == [0, 2]


def test_map_join_paper_example():
    # Table VI: [10101, 11010] over a 5-attr output
    bl = sc.Bitset.from_string("10101")
    br = sc.Bitset.from_string("11010")
    # forward: left attr 0 -> out 0; left attr 1 -> out 2; left attr 2 -> out 4
    assert sc.map_join_f(bl, 0) == 0
    assert sc.map_join_f(bl, 1) == 2
    assert sc.map_join_f(bl, 2) == 4
    # backward: out attr 1 comes from the right dataset only
    assert sc.map_join_b(bl, 1) is None
    assert sc.map_join_b(br, 1) == 1
    assert sc.map_join_b(br, 4) is None


def test_perm_fallback():
    # paper: [4,2,5] (1-based) = order-changing vertical reduction
    perm = np.array([3, 1, 4])
    assert sc.perm_backward(perm, 0) == 3
    assert sc.perm_forward(perm, 1) == 1
    assert sc.perm_forward(perm, 0) is None


# ---------------------------------------------------------------------------
# Property tests (hypothesis): rank/select laws over arbitrary bitsets
# ---------------------------------------------------------------------------
@given(st.lists(st.booleans(), min_size=1, max_size=200))
@settings(max_examples=200, deadline=None)
def test_rank_is_cumsum(bits):
    b = sc.Bitset.from_bits(bits)
    cum = np.cumsum(np.asarray(bits, dtype=int))
    for i in range(len(bits)):
        assert b.rank(i) == cum[i]


@given(st.lists(st.booleans(), min_size=1, max_size=120))
@settings(max_examples=100, deadline=None)
def test_vr_forward_backward_roundtrip(bits):
    b = sc.Bitset.from_bits(bits)
    for i in range(len(bits)):
        j = sc.map_vr_f(b, i)
        if bits[i]:
            assert j is not None and sc.map_vr_b(b, j) == i
        else:
            assert j is None


@given(st.lists(st.booleans(), min_size=1, max_size=120).filter(lambda x: any(x)))
@settings(max_examples=100, deadline=None)
def test_join_maps_are_partial_inverses(bits):
    b = sc.Bitset.from_bits(bits)
    n_in = b.popcount()
    for i in range(n_in):
        j = sc.map_join_f(b, i)
        assert j is not None and sc.map_join_b(b, j) == i
    for j in range(len(bits)):
        a = sc.map_join_b(b, j)
        if bits[j]:
            assert sc.map_join_f(b, a) == j
        else:
            assert a is None
