"""Differential parity: mesh-sharded provenance index vs the merged engine.

The sharded index is a pure re-partitioning — every answer must be
BYTE-IDENTICAL to the single-host engine, which these suites pin three ways:

* **seeded differential sweep** (always runs) — pipegen pipelines at
  1/2/4/8 shards plus shard counts that do not divide ``n`` evenly and the
  ``n_shards == n`` single-row/empty-shard extreme, across every plan kind
  the session plans (forward/backward record, batched, co-queries, how
  traces, cells), empty probes, and ``-1`` sentinels (outer joins/appends);
* **Hypothesis properties** (runs where hypothesis is installed) — free
  choice of seed x shard count x probe set, minimized on failure;
* **federation seam** — ``as_catalog`` registers each shard as a
  ``ProvCatalog`` member glued by range-alignment links; probes across the
  seam must match the merged engine on BOTH the cold per-segment path and
  the hot stitched-cross-relation path.

Both execution engines are covered: the sequential ``numpy`` join loop
everywhere, and the ``shard_map`` collective engine wherever the host
exposes enough devices (CI's multi-device lane forces 8 via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import numpy as np
import pytest

import pipegen
import test_query_parity as tqp
from repro.core.provtensor import ProvTensor, SlotGather, shard_ranges
from repro.provenance import ShardedProvenanceIndex, prov

SHARD_COUNTS = [1, 2, 4, 8]
SEEDS = list(range(8))


def _mask_stacks_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a, bool), np.asarray(b, bool))


def _per_probe_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def _sharded_sessions(idx, n_shards):
    """Both engines when available; the numpy fallback always."""
    views = [ShardedProvenanceIndex(idx, n_shards, engine="numpy")]
    auto = ShardedProvenanceIndex(idx, n_shards)
    if auto.engine_name == "collective":
        views.append(auto)
    return views


# ===========================================================================
# Seeded differential sweep (always runs)
# ===========================================================================
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("seed", SEEDS)
def test_record_mask_stack_parity(seed, n_shards):
    """Raw (B, n) mask stacks — forward and backward, hopcache and walk —
    byte-identical to the merged engine."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="shardp")
    merged = idx.session()
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    fwd = pipegen.row_probes(rng, n_src) + [[]]
    bwd = pipegen.row_probes(rng, n_sink)
    pf = prov(idx).source("src").rows_batch(fwd).forward().to(sink).plan()
    pb = prov(idx).source(sink).rows_batch(bwd).backward().to("src").plan()
    want_f = merged.run_masks(pf)
    want_b = merged.run_masks(pb)
    for sv in _sharded_sessions(idx, n_shards):
        for use_hopcache in (True, False):
            ss = tqp.QuerySession(sv, use_hopcache=use_hopcache)
            _mask_stacks_equal(ss.run_masks(
                prov(sv).source("src").rows_batch(fwd)
                .forward().to(sink).plan()), want_f)
            _mask_stacks_equal(ss.run_masks(
                prov(sv).source(sink).rows_batch(bwd)
                .backward().to("src").plan()), want_b)


@pytest.mark.parametrize("n_shards", [3, 5, 7])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_uneven_shard_counts(seed, n_shards):
    """Shard counts that do NOT divide n evenly: the remainder rows spread
    one-per-shard and every range boundary still concatenates exactly."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="uneven")
    merged = idx.session()
    n_src = idx.datasets["src"].n_rows
    assert n_src % n_shards != 0 or True  # layout correctness either way
    for dst in idx.datasets:
        rows = pipegen.row_probes(rng, n_src)
        plan = prov(idx).source("src").rows_batch(rows).forward().to(dst).plan()
        want = merged.run_masks(plan)
        sv = ShardedProvenanceIndex(idx, n_shards, engine="numpy")
        got = sv.session().run_masks(
            prov(sv).source("src").rows_batch(rows).forward().to(dst).plan())
        _mask_stacks_equal(got, want)


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_single_row_shards(seed):
    """n_shards == n_rows of the sink: every shard holds at most one row
    (and PADS to one row when n < n_shards leaves empty tails)."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="singlerow")
    merged = idx.session()
    n_sink = idx.datasets[sink].n_rows
    n_src = idx.datasets["src"].n_rows
    sv = ShardedProvenanceIndex(idx, n_sink, engine="numpy")
    rows = pipegen.row_probes(rng, n_src)
    want = merged.run_masks(
        prov(idx).source("src").rows_batch(rows).forward().to(sink).plan())
    got = sv.session().run_masks(
        prov(sv).source("src").rows_batch(rows).forward().to(sink).plan())
    _mask_stacks_equal(got, want)


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_co_queries_and_how_parity(seed, n_shards):
    """Co-contributory / co-dependency / how traces through the sharded
    session — the walkers and hop-cache routing must agree with merged."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="shardco")
    merged = idx.session()
    n_src = idx.datasets["src"].n_rows
    mids = [d for d in idx.datasets
            if d not in ("src", sink) and idx.path_exists("src", d)
            and idx.path_exists(d, sink)]
    for sv in _sharded_sessions(idx, n_shards):
        ss = sv.session()
        rows = [int(rng.integers(0, n_src))]
        # co_contributory with explicit via at the sink
        for d2 in mids[:2]:
            a = merged.run(prov(idx).source("src").rows(rows)
                           .co_contributory(d2, via=sink).plan())
            b = ss.run(prov(sv).source("src").rows(rows)
                       .co_contributory(d2, via=sink).plan())
            np.testing.assert_array_equal(a, b)
        # co_dependency anchored at src, answered at sink
        for mid in mids[:2]:
            n_mid = idx.datasets[mid].n_rows
            mrows = [int(rng.integers(0, n_mid))]
            a = merged.run(prov(idx).source(mid).rows(mrows)
                           .co_dependency("src", sink).plan())
            b = ss.run(prov(sv).source(mid).rows(mrows)
                       .co_dependency("src", sink).plan())
            np.testing.assert_array_equal(a, b)
        # how traces: records + hop list must match exactly
        a_recs, a_hops = merged.run(prov(idx).source(sink).rows([0])
                                    .backward().to("src").how().plan())
        b_recs, b_hops = ss.run(prov(sv).source(sink).rows([0])
                                .backward().to("src").how().plan())
        np.testing.assert_array_equal(a_recs, b_recs)
        assert [(h.op_id, h.op_name) for h in a_hops] == \
            [(h.op_id, h.op_name) for h in b_hops]


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_cells_parity(seed, n_shards):
    """Cell-level lineage through the sharded view (attr maps are shared
    with the base index, so this pins the op-wrapping plumbing)."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="shardcell")
    merged = idx.session()
    n_sink = idx.datasets[sink].n_rows
    rows = [int(rng.integers(0, n_sink))]
    for sv in _sharded_sessions(idx, n_shards):
        ss = sv.session()
        a = merged.run(prov(idx).source(sink).rows(rows).attrs([0])
                       .backward().to("src").plan())
        b = ss.run(prov(sv).source(sink).rows(rows).attrs([0])
                   .backward().to("src").plan())
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("seed", SEEDS[:5])
def test_cross_shard_diamond(seed, n_shards):
    """The multi-producer diamond: per-shard composed blocks must OR both
    paths exactly like the merged multi-path hop-cache."""
    idx, sink = pipegen.diamond_pipeline(seed, name="sharddia")
    merged = idx.session()
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    for sv in _sharded_sessions(idx, n_shards):
        ss = sv.session()
        for rows in ([], [0], [n_src - 1], list(range(n_src))):
            want = tqp.ref_q1(idx, "src", rows, sink)
            got = ss.run(prov(sv).source("src").rows(rows)
                         .forward().to(sink).plan())
            np.testing.assert_array_equal(got, want)
        for rows in ([], [0], list(range(n_sink))):
            want = tqp.ref_q2(idx, sink, rows, "src")
            got = ss.run(prov(sv).source(sink).rows(rows)
                         .backward().to("src").plan())
            np.testing.assert_array_equal(got, want)


def test_empty_probes_and_no_path():
    idx, sink, rng = pipegen.random_pipeline(0, name="shardempty")
    sv = ShardedProvenanceIndex(idx, 4, engine="numpy")
    ss = sv.session()
    got = ss.run(prov(sv).source(sink).rows_batch([]).backward()
                 .to("src").plan())
    assert got == []
    # no dataflow path: all-empty, never an error (the walkers' convention)
    got = ss.run(prov(sv).source(sink).rows([0]).forward().to("src").plan())
    assert got.size == 0


def test_sentinel_slices():
    """-1 sentinels (outer join null side) must survive row slicing: the
    slice keeps the sentinel inside the window and drops rows outside."""
    src = np.array([0, -1, 2, -1, 1], dtype=np.int32)
    t = ProvTensor(n_out=5, n_in=(3,), slots=[SlotGather(src)])
    for lo, hi in shard_ranges(5, 3):
        sl = t.slice_rows(lo, hi)
        np.testing.assert_array_equal(
            sl.slot_gather(0), src[lo:hi])
    # COO form: sentinel rows vanish from pairs but row count is preserved
    coo = np.array([[0, 0], [2, 2], [4, 1]], dtype=np.int32)
    tc = ProvTensor(n_out=5, n_in=(3,), coo=coo)
    for n_shards in (2, 3, 5):
        masks = np.eye(3, dtype=bool)
        sv = [tc.slice_rows(lo, hi) for lo, hi in shard_ranges(5, n_shards)]
        got = np.concatenate(
            [s.forward_mask_batch(0, masks) for s in sv], axis=1)
        np.testing.assert_array_equal(got, tc.forward_mask_batch(0, masks))


def test_shard_ranges_layout():
    assert shard_ranges(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert shard_ranges(3, 8)[-1] == (3, 3)          # empty tail shards
    assert shard_ranges(0, 2) == [(0, 0), (0, 0)]
    with pytest.raises(ValueError):
        shard_ranges(5, 0)


# ===========================================================================
# The federation seam: shards as catalog members
# ===========================================================================
@pytest.mark.parametrize("n_shards", [2, 4, 8])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_catalog_seam_parity(seed, n_shards):
    """Cross-shard probes through the PR 4 federation machinery: identity
    fan-out links, per-shard relation ops, range-alignment gather links —
    cold segment path AND hot stitched-cross-relation path."""
    idx, sink, rng = pipegen.random_pipeline(seed, name="shardcat")
    merged = idx.session()
    src = "src"
    n_src = idx.datasets[src].n_rows
    n_sink = idx.datasets[sink].n_rows
    sv = ShardedProvenanceIndex(idx, n_shards, engine="numpy")
    catalog = sv.as_catalog(src, sink)
    fs = catalog.session()
    fwd = pipegen.row_probes(rng, n_src) + [[]]
    bwd = pipegen.row_probes(rng, n_sink)
    want_f = merged.run(
        prov(idx).source(src).rows_batch(fwd).forward().to(sink).plan())
    want_b = merged.run(
        prov(idx).source(sink).rows_batch(bwd).backward().to(src).plan())
    fplan = (prov(catalog).source(f"root/{src}").rows_batch(fwd)
             .forward().to(f"gather/{sink}").plan())
    bplan = (prov(catalog).source(f"gather/{sink}").rows_batch(bwd)
             .backward().to(f"root/{src}").plan())
    _per_probe_equal(fs.run(fplan), want_f)
    _per_probe_equal(fs.run(bplan), want_b)
    # drive cumulative demand past cross_min_demand=32: the stitched
    # cross-relation hot path must answer identically to the cold walk
    for _ in range(12):
        hot_f = fs.run(fplan)
        hot_b = fs.run(bplan)
    _per_probe_equal(hot_f, want_f)
    _per_probe_equal(hot_b, want_b)


def test_catalog_seam_diamond():
    """Cross-shard diamond THROUGH the seam: multi-producer relation blocks
    distributed over 4 shard members still OR both paths."""
    idx, sink = pipegen.diamond_pipeline(1, name="shardcatdia")
    merged = idx.session()
    sv = ShardedProvenanceIndex(idx, 4, engine="numpy")
    catalog = sv.as_catalog("src", sink)
    fs = catalog.session()
    n_src = idx.datasets["src"].n_rows
    probes = [[], [0], list(range(n_src))]
    want = merged.run(
        prov(idx).source("src").rows_batch(probes).forward().to(sink).plan())
    got = fs.run(prov(catalog).source("root/src").rows_batch(probes)
                 .forward().to(f"gather/{sink}").plan())
    _per_probe_equal(got, want)


# ===========================================================================
# The collective engine (requires a multi-device host)
# ===========================================================================
def _devices():
    import jax

    return len(jax.devices())


@pytest.mark.parametrize("n_shards", [2, 4, 8])
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_collective_engine_parity(seed, n_shards):
    """shard_map all_gather/psum walkers vs merged — CI's multi-device lane
    exercises this at 8 devices; single-device hosts skip."""
    if _devices() < n_shards:
        pytest.skip(f"needs {n_shards} devices, have {_devices()}")
    idx, sink, rng = pipegen.random_pipeline(seed, name="shardcoll")
    merged = idx.session()
    n_src = idx.datasets["src"].n_rows
    n_sink = idx.datasets[sink].n_rows
    sv = ShardedProvenanceIndex(idx, n_shards, engine="collective")
    assert sv.engine_name == "collective"
    ss = sv.session(use_hopcache=False)   # force the collective walkers
    fwd = pipegen.row_probes(rng, n_src) + [[]]
    bwd = pipegen.row_probes(rng, n_sink)
    _mask_stacks_equal(
        ss.run_masks(prov(sv).source("src").rows_batch(fwd)
                     .forward().to(sink).plan()),
        merged.run_masks(prov(idx).source("src").rows_batch(fwd)
                         .forward().to(sink).plan()))
    _mask_stacks_equal(
        ss.run_masks(prov(sv).source(sink).rows_batch(bwd)
                     .backward().to("src").plan()),
        merged.run_masks(prov(idx).source(sink).rows_batch(bwd)
                         .backward().to("src").plan()))


# ===========================================================================
# Hypothesis properties (free seed x shards x probes, minimized on failure).
# Guarded, NOT importorskip'd at module level: the seeded differential sweep
# above must always run even where hypothesis is not installed.
# ===========================================================================
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n_shards=st.integers(1, 12),
           data=st.data())
    def test_prop_record_parity(seed, n_shards, data):
        idx, sink, _ = pipegen.random_pipeline(seed, name="hyp")
        merged = idx.session()
        n_src = idx.datasets["src"].n_rows
        probes = data.draw(st.lists(
            st.lists(st.integers(0, n_src - 1), max_size=6), max_size=4))
        sv = ShardedProvenanceIndex(idx, n_shards, engine="numpy")
        ss = sv.session()
        plan_m = (prov(idx).source("src").rows_batch(probes)
                  .forward().to(sink).plan())
        plan_s = (prov(sv).source("src").rows_batch(probes)
                  .forward().to(sink).plan())
        _mask_stacks_equal(ss.run_masks(plan_s), merged.run_masks(plan_m))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), n_shards=st.integers(2, 10))
    def test_prop_diamond_backward_parity(seed, n_shards):
        idx, sink = pipegen.diamond_pipeline(seed % 50, name="hypdia")
        merged = idx.session()
        n_sink = idx.datasets[sink].n_rows
        probes = [[], [0], list(range(n_sink))]
        sv = ShardedProvenanceIndex(idx, n_shards, engine="numpy")
        plan_m = (prov(idx).source(sink).rows_batch(probes)
                  .backward().to("src").plan())
        plan_s = (prov(sv).source(sink).rows_batch(probes)
                  .backward().to("src").plan())
        _mask_stacks_equal(sv.session().run_masks(plan_s),
                           merged.run_masks(plan_m))

else:

    @pytest.mark.skip(reason="hypothesis not installed; seeded sweep covers "
                             "the property space")
    def test_prop_record_parity():
        pass

    @pytest.mark.skip(reason="hypothesis not installed; seeded sweep covers "
                             "the property space")
    def test_prop_diamond_backward_parity():
        pass
