"""Shared randomized-pipeline generators for the differential parity suites.

One op mix, one seed discipline, one ``-1``-sentinel story (outer joins and
appends) — used by ``test_query_parity``, ``test_structured``,
``test_federation`` and ``test_sharded_parity`` so every engine variant
(walk, hop-cache, structured fast path, federated, sharded) is pinned
against the SAME pipeline distribution.

Two generator families:

* :func:`random_pipeline` / :func:`diamond_pipeline` — build directly into
  one :class:`ProvenanceIndex` (single-index parity suites);
* :func:`random_specs` + :func:`build_merged` / :func:`build_federated` —
  freeze every random choice into a replayable spec list first, so the
  SAME ops can be built merged and split-at-a-boundary
  (federation/sharding seam suites).
"""
import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import ProvCatalog
from repro.provenance.catalog import qualify


# ===========================================================================
# Randomized pipelines over every op category
# ===========================================================================
def random_pipeline(seed, name="parity"):
    """3-8 random ops over identity/vreduce/vaugment/hreduce/haugment/join/
    append, including outer joins and appends (``-1`` sentinels).  Returns
    ``(index, sink_dataset_id, rng)`` — the rng is advanced past the build
    so callers draw independent probes."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(15, 50))
    K = max(3, n // 4)
    idx = ProvenanceIndex(f"{name}{seed}")
    t = Table.from_columns({
        "k": rng.integers(0, K, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "g": rng.integers(0, 4, n).astype(np.float32),
        "y": rng.normal(size=n).astype(np.float32),
    })
    cur = track(t, idx, "src")
    n_ops = int(rng.integers(3, 8))
    for i in range(n_ops):
        code = int(rng.integers(0, 9))
        cols = cur.table.columns
        if code == 0:
            mask = np.asarray(cur.table.col("x")) > float(rng.normal(-1.0, 0.4))
            if not mask.any():
                mask[0] = True
            cur = cur.filter_rows(mask)
        elif code == 1:
            cur = cur.value_transform("x", "scale", factor=2.0)
        elif code == 2:
            cur = cur.oversample(frac=0.3, seed=int(rng.integers(1 << 20)))
        elif code == 3:
            cur = cur.undersample(frac=0.7, seed=int(rng.integers(1 << 20)))
        elif code == 4 and "g" in cols:
            cur = cur.onehot("g", n_values=4)
        elif code == 5:
            # order-changing vreduce: keep k/x/g, shuffle, maybe drop y
            keep = [c for c in cols if c in ("k", "x", "g")]
            extra = [c for c in cols if c not in ("k", "x", "g")]
            rng.shuffle(keep)
            keep += list(rng.choice(extra, size=len(extra) // 2, replace=False)) \
                if extra else []
            cur = cur.select_columns(keep)
        elif code == 6:
            r = Table.from_columns({
                "k": np.arange(K, dtype=np.float32),
                f"z{i}": rng.normal(size=K).astype(np.float32),
            })
            how = str(rng.choice(["inner", "outer"]))
            cur = cur.join(track(r, idx), on="k", how=how)
        elif code == 7:
            m = int(rng.integers(3, 9))
            r = Table.from_columns({
                "x": rng.normal(size=m).astype(np.float32),
                f"w{i}": rng.normal(size=m).astype(np.float32),
            })
            cur = cur.append(track(r, idx))
        elif code == 8 and "y" in cols:
            cur = cur.drop_columns(["y"])
        if cur.table.n_rows == 0:
            break
    cur.mark_sink()
    return idx, cur.dataset_id, rng


def row_probes(rng, n):
    """The canonical probe triple: empty, single row, small sorted set."""
    probes = [[], [int(rng.integers(0, n))],
              sorted(set(rng.integers(0, n, size=min(5, n)).tolist()))]
    return probes


def diamond_pipeline(seed=0, name="diamond"):
    """src feeds two branches re-joined downstream — TWO producer paths, the
    shape the old unique-chain hop-cache could not compose."""
    rng = np.random.default_rng(seed)
    idx = ProvenanceIndex(f"{name}{seed}")
    n = int(rng.integers(8, 20))
    t = Table.from_columns({
        "k": np.arange(n, dtype=np.float32),
        "x": rng.normal(size=n).astype(np.float32),
    })
    s = track(t, idx, "src")
    a = s.filter_rows(rng.random(n) < 0.75)
    b = s.value_transform("x", "scale", factor=2.0)
    j = a.join(b, on="k", how="inner").mark_sink()
    return idx, j.dataset_id


# ===========================================================================
# Spec-replay pipelines: ONE op list, built merged and split
# ===========================================================================
def random_specs(seed):
    """A replayable op-spec list (every random choice frozen into the spec,
    so the merged and the federated build apply IDENTICAL ops)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(15, 40))
    K = max(3, n // 4)
    base = {
        "k": rng.integers(0, K, n).astype(np.float32),
        "x": rng.normal(size=n).astype(np.float32),
        "g": rng.integers(0, 4, n).astype(np.float32),
    }
    specs = []
    for i in range(int(rng.integers(4, 8))):
        code = int(rng.integers(0, 6))
        if code == 0:
            specs.append(("filter", float(rng.normal(-1.0, 0.4))))
        elif code == 1:
            specs.append(("scale",))
        elif code == 2:
            specs.append(("oversample", 0.3, int(rng.integers(1 << 20))))
        elif code == 3:
            specs.append(("undersample", 0.7, int(rng.integers(1 << 20))))
        elif code == 4:
            ref = {
                "k": np.arange(K, dtype=np.float32),
                f"z{i}": rng.normal(size=K).astype(np.float32),
            }
            specs.append(("join", ref, str(rng.choice(["inner", "outer"]))))
        else:
            m = int(rng.integers(3, 9))
            ref = {
                "x": rng.normal(size=m).astype(np.float32),
                f"w{i}": rng.normal(size=m).astype(np.float32),
            }
            specs.append(("append", ref))
    return base, specs


def apply_spec(cur, spec, idx):
    kind = spec[0]
    if kind == "filter":
        mask = np.asarray(cur.table.col("x")) > spec[1]
        if not mask.any():
            mask[0] = True
        return cur.filter_rows(mask)
    if kind == "scale":
        return cur.value_transform("x", "scale", factor=2.0)
    if kind == "oversample":
        return cur.oversample(frac=spec[1], seed=spec[2])
    if kind == "undersample":
        return cur.undersample(frac=spec[1], seed=spec[2])
    if kind == "join":
        r = track(Table.from_columns({c: v.copy() for c, v in spec[1].items()}), idx)
        return cur.join(r, on="k", how=spec[2])
    if kind == "append":
        r = track(Table.from_columns({c: v.copy() for c, v in spec[1].items()}), idx)
        return cur.append(r)
    raise ValueError(kind)


def build_merged(base, specs):
    idx = ProvenanceIndex("merged")
    cur = track(Table.from_columns({c: v.copy() for c, v in base.items()}),
                idx, "src")
    ids = ["src"]
    for spec in specs:
        cur = apply_spec(cur, spec, idx)
        ids.append(cur.dataset_id)
    cur.mark_sink()
    return idx, ids


def build_federated(base, specs, cut):
    """Split the SAME spec list at ``cut``: prep owns ops [0, cut), serve
    owns ops [cut, ...) over a source holding the boundary table, glued by
    an identity link.  Returns the catalog plus the merged-id -> qualified
    ref mapping aligned with ``build_merged``'s ``ids``."""
    prep = ProvenanceIndex("prep")
    cur = track(Table.from_columns({c: v.copy() for c, v in base.items()}),
                prep, "src")
    refs = [qualify("prep", "src")]
    for spec in specs[:cut]:
        cur = apply_spec(cur, spec, prep)
        refs.append(qualify("prep", cur.dataset_id))
    boundary = cur.dataset_id
    serve = ProvenanceIndex("serve")
    scur = track(cur.table, serve, "ingest")
    for spec in specs[cut:]:
        scur = apply_spec(scur, spec, serve)
        refs.append(qualify("serve", scur.dataset_id))
    scur.mark_sink()
    catalog = ProvCatalog(f"fed-cut{cut}")
    catalog.register("prep", prep).register("serve", serve)
    catalog.link(qualify("prep", boundary), "serve/ingest")
    return catalog, refs, qualify("serve", scur.dataset_id)


def cross_boundary_diamond(seed=0):
    """Two links carry two branches of one source across the boundary —
    BOTH must contribute or the answer under-counts."""
    rng = np.random.default_rng(seed)
    base = {
        "k": np.arange(12, dtype=np.float32),
        "x": rng.normal(size=12).astype(np.float32),
    }
    keep = rng.random(12) < 0.75
    if not keep.any():
        keep[0] = True

    merged = ProvenanceIndex("merged")
    s = track(Table.from_columns({c: v.copy() for c, v in base.items()}),
              merged, "src")
    a = s.filter_rows(keep)
    b = s.value_transform("x", "scale", factor=2.0)
    j = a.join(b, on="k", how="inner").mark_sink()

    prep = ProvenanceIndex("prep")
    ps = track(Table.from_columns({c: v.copy() for c, v in base.items()}),
               prep, "src")
    pa = ps.filter_rows(keep)
    pb = ps.value_transform("x", "scale", factor=2.0)
    serve = ProvenanceIndex("serve")
    sa = track(pa.table, serve, "branch_a")
    sb = track(pb.table, serve, "branch_b")
    sj = sa.join(sb, on="k", how="inner").mark_sink()

    catalog = ProvCatalog("diamond")
    catalog.register("prep", prep).register("serve", serve)
    catalog.link(qualify("prep", pa.dataset_id), "serve/branch_a")
    catalog.link(qualify("prep", pb.dataset_id), "serve/branch_b")
    return merged, j.dataset_id, catalog, qualify("serve", sj.dataset_id)
