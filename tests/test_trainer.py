"""Trainer substrate: loss goes down, checkpoint/resume is exact, crash
injection recovers, grad compression error feedback behaves."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import CorpusConfig, TokenPipeline
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.trainer import TrainState, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("olmo-1b")
    opt = AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=60,
                      moment_dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, key, opt)
    step = jax.jit(make_train_step(cfg, opt, n_micro=2))
    tp = TokenPipeline(CorpusConfig(n_docs=64, mean_len=64, vocab=cfg.vocab,
                                    seed=1), seq_len=32)
    return cfg, opt, state, step, tp


def _batches(tp, bs):
    """Step-indexed batch function (pure in step — resumable)."""
    def fn(step):
        b = tp.batch_at(step, bs)
        return {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
    return fn


def test_loss_decreases(setup):
    cfg, opt, state, step, tp = setup
    fn = _batches(tp, 4)
    losses = []
    for i in range(30):
        state, m = step(state, fn(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, opt, state, step, tp = setup
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    ckpt.save(7, state, blocking=True)
    assert ckpt.latest_step() == 7
    restored = ckpt.restore(7, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_gc(tmp_path, setup):
    cfg, opt, state, step, tp = setup
    ckpt = CheckpointManager(str(tmp_path / "ck2"), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, state, blocking=True)
    assert ckpt.all_steps() == [3, 4]
    # stale tmp dirs are collected on next manager construction
    os.makedirs(str(tmp_path / "ck2" / "step_000000099.tmp"))
    CheckpointManager(str(tmp_path / "ck2"), keep=2)
    assert not os.path.exists(str(tmp_path / "ck2" / "step_000000099.tmp"))


def test_crash_and_resume_exact(tmp_path, setup):
    cfg, opt, state0, step, tp = setup
    loop_dir = str(tmp_path / "loop")

    # uninterrupted reference run
    ck_a = CheckpointManager(loop_dir + "_a", keep=5)
    out_a = run_training(step, state0, _batches(tp, 4), ck_a,
                         LoopConfig(total_steps=12, ckpt_every=4),
                         log=lambda s: None)

    # crash at step 9, then resume from the step-8 checkpoint
    ck_b = CheckpointManager(loop_dir + "_b", keep=5)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(step, state0, _batches(tp, 4), ck_b,
                     LoopConfig(total_steps=12, ckpt_every=4, fail_at_step=9),
                     log=lambda s: None)
    out_b = run_training(step, state0, _batches(tp, 4), ck_b,
                         LoopConfig(total_steps=12, ckpt_every=4),
                         log=lambda s: None)
    assert out_b["resumed_from"] == 8
    for a, b in zip(jax.tree.leaves(out_a["final_state"].params),
                    jax.tree.leaves(out_b["final_state"].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_restore_new_sharding(tmp_path, setup):
    """A checkpoint restores onto a different mesh/sharding (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh_compat
    cfg, opt, state, step, tp = setup
    ckpt = CheckpointManager(str(tmp_path / "ck3"), keep=1)
    ckpt.save(1, state.params, blocking=True)
    mesh = make_mesh_compat((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state.params)
    restored = ckpt.restore(1, like=state.params, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf.sharding, NamedSharding)


def test_grad_compression_error_feedback():
    params = {"w": jnp.zeros((4, 4), jnp.float32)}
    opt = AdamWConfig(grad_compress_bits=8, clip_norm=1e9, weight_decay=0.0,
                      lr_peak=1.0, warmup_steps=0, total_steps=1,
                      moment_dtype=jnp.float32)
    state = init_opt_state(params, opt)
    assert state.err is not None
    g = {"w": jnp.full((4, 4), 0.333e-3, jnp.float32)
         + jnp.arange(16, dtype=jnp.float32).reshape(4, 4) * 1e-6}
    _, state2, _ = adamw_update(params, g, state, opt)
    # residual is bounded by one quantization bucket
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(state2.err["w"]).max()) <= scale * 0.5 + 1e-12
    # and is carried (nonzero somewhere, because values straddle buckets)
    assert float(jnp.abs(state2.err["w"]).max()) > 0


def test_straggler_detection(tmp_path, setup):
    import time
    cfg, opt, state, step, tp = setup
    calls = {"n": 0}

    def slow_step(s, b):
        calls["n"] += 1
        if calls["n"] == 9:
            time.sleep(0.25)
        return step(s, b)

    ck = CheckpointManager(str(tmp_path / "ck4"), keep=1)
    out = run_training(slow_step, state, _batches(tp, 4), ck,
                       LoopConfig(total_steps=10, ckpt_every=100,
                                  straggler_factor=2.5),
                       log=lambda s: None)
    assert out["stragglers"] >= 1
