"""GDPR erasure propagation + what-if replay over a federated catalog.

    PYTHONPATH=src python examples/erasure_audit.py

Two impact-analysis workloads on one closure engine:

1. **Deletion propagation** — three users revoke consent.  One
   ``erasure_plan`` over the catalog computes the full downstream closure
   (prep pipeline AND the linked serving member), lists every dataset the
   erasure touches in rebuild order, and enumerates the cached composed
   relations the rewrite poisons; ``apply_invalidations`` drops them.
2. **What-if replay** — before actually erasing, replay the sink with one
   user's income zeroed: ``whatif_replay`` recomputes ONLY the
   provenance-related sink rows (never the whole dataset) and returns
   exact before/after deltas.
"""
import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import (
    ProvCatalog,
    apply_invalidations,
    erasure_plan,
    prov,
    whatif_replay,
)

rng = np.random.default_rng(0)
N = 500

# --- prep member: a consent-bearing user pipeline ------------------------------
prep = ProvenanceIndex("prep")
users = Table.from_columns({
    "uid": np.arange(N, dtype=np.float32),
    "age": rng.uniform(18, 80, N).astype(np.float32),
    "income": rng.lognormal(10, 1, N).astype(np.float32),
    "score": rng.normal(size=N).astype(np.float32),
})
t = track(users, prep, "users")
t = t.value_transform("income", "scale", factor=1e-4)
t = t.filter_rows(np.asarray(t.table.col("score")) > -0.5)
t = t.oversample(frac=0.2, seed=7)
t.mark_sink()
clean = t.dataset_id

# --- serving member: the prep sink crosses a boundary link ---------------------
serve = ProvenanceIndex("serve")
s = track(t.table, serve, "ingest")
s = s.filter_rows(np.asarray(s.table.col("score")) > 0.0)
s.mark_sink()
catalog = ProvCatalog("erasure-demo")
catalog.register("prep", prep).register("serve", serve)
catalog.link(f"prep/{clean}", "serve/ingest")
sink_ref = f"serve/{s.dataset_id}"

# warm the caches an erasure would poison: a lineage probe composes
# per-member relations the usual way
prov(catalog).source("prep/users").rows([0]).forward().to(sink_ref).run()
prep.composed().relation("users", clean)

# --- 1. deletion propagation ---------------------------------------------------
revoked = sorted(rng.choice(N, size=3, replace=False).tolist())
plan = erasure_plan(catalog, "prep/users", revoked)
print(f"consent revoked by users {revoked}\n")
print(plan.describe())
print(f"\nrebuild order: {list(plan.rebuild)}")
dropped = apply_invalidations(catalog, plan)
print(f"stale cached relations dropped: {dropped}")
assert prep.composed().stats()["entries"] == 0

# --- 2. what-if replay ---------------------------------------------------------
uid = revoked[0]
res = whatif_replay(serve, "ingest", [0], {"income": [0.0]},
                    s.dataset_id)
print(f"\nwhat-if: zero ingest row 0's income -> {len(res.sink_rows)} of "
      f"{serve.datasets[s.dataset_id].n_rows} sink rows recomputed")
for row, delta in zip(res.sink_rows, res.row_deltas()):
    for col, (lo, hi) in delta.items():
        print(f"  sink row {row}: {col} {lo:.4f} -> {hi:.4f}")
assert res.changed.any() or len(res.sink_rows) == 0
# recorded provenance untouched by the replay
assert serve.datasets["ingest"].table.data is not None
print("\nerasure planned, caches invalidated, what-if replayed — "
      "without rerunning the pipeline.")
