"""Streaming capture: bounded memory + always-warm lineage on a live pipeline.

    PYTHONPATH=src python examples/streaming_lineage.py

A long-running preparation service never stops appending ops, so two things
that are fine for batch pipelines become problems: provenance tensors
accumulate in RAM without bound, and every append invalidates nothing — yet
a naive composed cache would recompose the whole chain to stay current.

This example runs a small append stream against both mechanisms:

* ``ProvenanceIndex(spill=...)`` — cold op tensors leave RAM for a compact
  on-disk log under an LRU byte budget and fault back transparently when a
  query touches them (answers stay byte-identical);
* ``index.composed(spill=True)`` — the hop-cache extends its warm composed
  relations by ONE closed-form step per appended op (``extends`` counter)
  and spills evicted relations instead of dropping them.
"""
import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.core.spill import SpillPolicy
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import prov

rng = np.random.default_rng(7)
n = 400

# --- a spill-tiered index: op tensors bounded to 8 KB resident ---------------
index = ProvenanceIndex("stream", spill=SpillPolicy(budget_bytes=8 << 10))
composed = index.composed(memory_budget_bytes=32 << 10, spill=True)

cur = track(Table.from_columns({
    "x": rng.normal(size=n).astype(np.float32),
    "g": rng.integers(0, 4, n).astype(np.float32),
}), index, "src")

# --- the live stream: filters and transforms keep arriving -------------------
cur = cur.value_transform("x", "scale", factor=1.01)
composed.relation("src", cur.dataset_id)    # first probe: src is now tracked
for i in range(40):
    if i % 3 == 2:
        mask = np.asarray(cur.table.col("x")) > float(rng.normal(-1.2, 0.3))
        if not mask.any():
            mask[0] = True
        cur = cur.filter_rows(mask)
    else:
        cur = cur.value_transform("x", "scale", factor=1.01)
    # any probe keeps the composed relation warm: the appended tail is
    # absorbed by ONE closed-form extension per op, never a recompose
    composed.contains("src", cur.dataset_id)

sink = cur.mark_sink().dataset_id
stats = composed.stats()
print(f"after 40 appended ops: extends={stats['extends']} "
      f"recomposes={stats['recomposes']}")

spill = index.stats()["spill"]
print(f"op tensors: {spill['resident_ops']} resident "
      f"({spill['resident_bytes']} B <= {spill['budget_bytes']} B budget), "
      f"{spill['spilled_ops']} spilled to disk")

# --- queries fault spilled state back transparently --------------------------
rows = prov(index).source(sink).rows([0, 1]).backward().to("src").run()
print("Q2  sink rows [0, 1] derive from src rows:", rows.tolist())
fwd = prov(index).source("src").rows(rows[:1].tolist()).forward().to(sink).run()
print("Q1  src row", int(rows[0]), "reaches sink rows:", fwd.tolist())
print(f"rehydrations: hop-cache={stats['rehydrations']} "
      f"tensors={index.stats()['spill']['rehydrations']}")

# --- where does each hop of the chain live right now? ------------------------
spilled = [d for d in index.datasets
           if composed.residency("src", d) == "spilled"]
ram = sum(1 for d in index.datasets if composed.residency("src", d) == "ram")
print(f"composed relations from src: {ram} in RAM, {len(spilled)} on disk")

# probing a spilled pair faults it back from the log (one mmap read) instead
# of recomposing the chain up to it
composed.relation("src", spilled[0])
print(f"probe of spilled ('src', '{spilled[0]}') faulted back: "
      f"rehydrations={composed.stats()['rehydrations']}")
assert composed.stats()["bytes"] <= 32 << 10
print("bounded: composed-relation residency stayed under the 32 KB budget")
