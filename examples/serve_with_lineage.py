"""Batched serving with request-level lineage.

    PYTHONPATH=src python examples/serve_with_lineage.py

Serves a small decoder LM (smoke-size gemma3 family: exercises the
local:global interleave + ring caches on the decode path) over a batch of
requests, then records the (response -> request) why-provenance with the
same ProvTensor machinery and answers backward queries over it.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.core.query import q1_forward, q2_backward
from repro.dataprep.table import Table
from repro.models.registry import get_model
from repro.serve.engine import ServeEngine

cfg = get_smoke_config("gemma3-1b")
model = get_model(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))

B, SP, NEW = 4, 8, 6
rng = np.random.default_rng(1)
prompts = rng.integers(1, cfg.vocab, (B, SP)).astype(np.int32)

engine = ServeEngine(cfg, params, max_seq=SP + NEW, dtype=jnp.float32)
result = engine.generate(prompts, n_new=NEW,
                         request_ids=np.array([101, 102, 103, 104]))
print("generated tokens:\n", result.tokens)

# --- capture serving provenance: one response row per request row -------------
idx = ProvenanceIndex("serving")
req_table = Table.from_columns({
    "request_id": result.request_ids.astype(np.float32),
    "prompt_len": np.full(B, SP, np.float32),
})
idx.add_source("requests", req_table)
resp_table = Table.from_columns({
    "request_id": result.request_ids.astype(np.float32),
    "n_tokens": np.full(B, NEW, np.float32),
})
idx.record(
    ["requests"], "responses", resp_table,
    CaptureInfo(op_name="generate", category=OpCategory.HAUGMENT,
                contextual=False, n_out=B, n_in=[B],
                src_rows=np.arange(B, dtype=np.int32),
                attr_maps=[AttrMap(kind="identity")],
                params={"n_new": NEW}),
    keep_output=True,
)

print("\nQ2: response row 2 derives from request row:",
      q2_backward(idx, "responses", [2], "requests"),
      "(request_id", int(result.request_ids[2]), ")")
print("Q1: request row 0 produced response rows:",
      q1_forward(idx, "requests", [0], "responses"))
print("\nprovenance bytes for the serving path:", idx.prov_nbytes())
