"""Batched serving with request-level lineage through the serving tier.

    PYTHONPATH=src python examples/serve_with_lineage.py

Serves a small decoder LM (smoke-size gemma3 family: exercises the
local:global interleave + ring caches on the decode path) over a batch of
requests.  The engine owns its OWN provenance index wrapped in a
single-entry :class:`ProvCatalog` (``engine.catalog``): serving-local
lineage routes through the index's shared ``QuerySession`` exactly as
before, and the same catalog is where an upstream data-prep boundary
attaches (``upstream=prep_index.export(...)`` — see
``examples/federated_lineage.py`` for the cross-index trace-to-source
flow).  The legacy ``prov_index=`` attach is deprecated.

Per-request lineage probes are served through the async micro-batching
:class:`~repro.serve.tier.ServingTier` (``engine.as_backend()``):
concurrent tenants submit single-probe plans, the tier coalesces them by
fuse key into fused ``run_many`` passes, and admission scopes each tenant
to a capability ref set.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models.registry import get_model
from repro.provenance import prov
from repro.serve import ServingTier
from repro.serve.engine import ServeEngine

cfg = get_smoke_config("gemma3-1b")
model = get_model(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))

B, SP, NEW = 4, 8, 6
rng = np.random.default_rng(1)
prompts = rng.integers(1, cfg.vocab, (B, SP)).astype(np.int32)

engine = ServeEngine(cfg, params, max_seq=SP + NEW, dtype=jnp.float32)
# the engine's serving index is registered in its catalog under "serve";
# the shared session's cost model routes per plan — cheap adjacent
# (response -> request) hops stay on the walk, and sustained probe demand
# against a distant pair amortizes a composition and flips to the hop-cache
print("catalog:", engine.catalog)
result = engine.generate(prompts, n_new=NEW,
                         request_ids=np.array([101, 102, 103, 104]),
                         record_provenance=True)
print("generated tokens:\n", result.tokens)
print("recorded:", result.request_dataset, "->", result.response_dataset)

# --- per-request lineage through the shared session ----------------------------
print("\nQ2: response row 2 derives from request row:",
      engine.response_lineage(result, rows=[2]),
      "(request_id", int(result.request_ids[2]), ")")

# batched per-request lineage: every response row traced in ONE fused probe
per_request = engine.response_lineage_batch(result, [[i] for i in range(B)])
print("Q2 batch: response row -> request row:",
      {i: r.tolist() for i, r in enumerate(per_request)})

# --- the same probes, served: the async micro-batching tier --------------------
# many tenants each trace THEIR response row; same-shape plans coalesce
# into fused passes (bare serving-local refs are qualified by the backend)
with ServingTier(engine.as_backend(), max_batch=16, max_wait_ms=2.0) as tier:
    futs = [
        tier.submit_nowait(
            f"tenant-{i % 2}",
            prov(engine.prov).source(result.response_dataset).rows([i])
            .backward().to(result.request_dataset).plan())
        for i in range(B)
    ]
    served = [f.result(timeout=60.0) for f in futs]
assert all(s.tolist() == r.tolist() for s, r in zip(served, per_request))
stats = tier.stats()
print("tier: served", stats["tier"]["completed"], "probes in",
      stats["tier"]["batches"], "fused batch(es), max width",
      stats["tier"]["max_batch_seen"])

# forward plans run through the same session/composed relations — spelled
# either against the index or against the catalog with a qualified ref
print("Q1: request row 0 produced response rows:",
      prov(engine.prov).source(result.request_dataset).rows([0])
      .forward().to(result.response_dataset).run(engine.session))
print("Q1 (catalog ref):",
      prov(engine.catalog).source(f"serve/{result.request_dataset}").rows([0])
      .forward().to(f"serve/{result.response_dataset}").run())

print("\nsession stats (shared composed relations):", engine.session.stats())
print("federation stats (single-entry catalog):",
      engine.federation.stats()["federation"])
print("provenance bytes for the serving path:", engine.prov.prov_nbytes())
