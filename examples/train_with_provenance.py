"""End-to-end driver: train a ~100M LM with TensProv lineage on the data path.

    PYTHONPATH=src python examples/train_with_provenance.py \
        [--steps 200] [--tiny]

What it demonstrates (the paper's technique as a training-framework feature):

  1. the corpus -> filter -> dedup -> pack -> batch dataflow is captured as
     a TensProv pipeline (sparse binary tensors per step);
  2. a ~100M-parameter decoder LM trains for a few hundred steps with the
     fault-tolerant loop (async checkpoints, resumable data order);
  3. DURING training, lineage queries answer development-time questions:
     'which raw documents fed the worst-loss batch?' (Q2 backward) and
     'which batches did a flagged document reach?' (Q1 forward) — the
     in-memory, query-while-developing use case the paper argues for
     (both route through the unified repro.provenance query API);
  4. a consent audit over the einsum-composed relation (paper §IV).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.pipeline import CorpusConfig, TokenPipeline
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, make_train_step


def model_100m(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(name="lm-tiny", family="dense", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                           vocab=50_000, block=(LayerSpec(),), remat=False)
    return ModelConfig(name="lm-100m", family="dense", n_layers=10,
                       d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                       vocab=50_000, block=(LayerSpec(),), remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/tensprov_train_ckpt")
    args = ap.parse_args()

    cfg = model_100m(args.tiny)
    n_params = cfg.n_params()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    # --- provenance-carrying data pipeline --------------------------------
    tp = TokenPipeline(CorpusConfig(n_docs=1024, mean_len=256,
                                    vocab=cfg.vocab, seed=11),
                       seq_len=args.seq)
    print(f"corpus: {tp.index.datasets['corpus'].n_rows} docs -> "
          f"{tp.n_seq} packed sequences; prov bytes so far: "
          f"{tp.index.stats()['prov_bytes']:,}")

    # --- trainer -----------------------------------------------------------
    opt = AdamWConfig(lr_peak=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_train_step(cfg, opt, n_micro=2))

    batch_losses = {}

    def batch_fn(step):
        b = tp.batch_at(step, args.batch, record_provenance=True)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    def wrapped_step(state, batch):
        new_state, metrics = step_fn(state, batch)
        return new_state, metrics

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    t0 = time.time()
    out = run_training(wrapped_step, state, batch_fn, ckpt,
                       LoopConfig(total_steps=args.steps, ckpt_every=50))
    dt = time.time() - t0
    losses = out["losses"]
    print(f"\ntrained {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1):.2f}s/step); "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}; "
          f"stragglers observed: {out['stragglers']}")

    # --- development-time provenance queries (the paper's use case) --------
    worst = int(np.argmax(losses))
    docs = tp.batch_to_documents(worst)
    meta = tp.index.datasets["corpus"].table
    print(f"\nworst-loss batch = step {worst} (loss {losses[worst]:.3f})")
    print(f"  Q2: fed by {len(docs)} raw documents; "
          f"mean quality {meta.col('quality')[docs].mean():.3f} "
          f"(corpus mean {meta.col('quality').mean():.3f})")

    flagged = int(docs[0])
    print(f"  Q1: document {flagged} reached batches "
          f"{tp.document_to_batches(flagged)[:10]}")

    # --- consent audit over the composed relation (paper §IV einsum) --------
    consent = meta.col("consent") > 0
    bad = []
    for s in range(min(args.steps, len(losses))):
        ds = f"batch@{s}"
        if ds in tp.index.datasets:
            for d in tp.batch_to_documents(s):
                if not consent[d]:
                    bad.append((s, int(d)))
    print(f"\nconsent audit: {len(bad)} (batch, doc) pairs used "
          f"non-consenting documents; first 5: {bad[:5]}")
    print("-> with provenance these batches can be traced, the documents "
          "dropped, and exactly the affected steps replayed.")

    print(f"\nfinal provenance index: {tp.index.stats()}")


if __name__ == "__main__":
    main()
