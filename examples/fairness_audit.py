"""Dataset-level fairness / consent audit via einsum composition (paper §IV).

    PYTHONPATH=src python examples/fairness_audit.py

The paper's motivating audit: "determine the proportion of female/male
individuals in the output dataset using a gender attribute available only
in the input dataset".  Record-by-record tracing would need |D'| backward
queries; the paper instead CONTRACTS the per-op tensors into one
src -> sink relation (Einstein summation).  We run it three ways and show
they agree:

  1. one backward record plan through the unified query API
     (``prov(idx)...backward()``, the walking reference);
  2. composed relation via boolean-semiring matmul (matrix-chain-ordered);
  3. the MESH-SHARDED audit (rows of the relation sharded over 'data';
     one psum crosses the mesh) — the pod-scale path.

Then the IMPACT API turns the same closure machinery around: one
``erasure_plan`` per protected group answers "which downstream records
derive from this group's rows" (and, for a GDPR request, which datasets
must be rebuilt and which cached relations go stale) — cross-checked
against the composed relation of method 2.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compose import compose_chain, dataset_lineage
from repro.provenance import erasure_plan, prov
from repro.core.distributed import lineage_audit_sharded, shard_relation
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.kernels.ref import pack_bits

rng = np.random.default_rng(0)
N = 2000

# --- a credit-scoring style pipeline -----------------------------------------
idx = ProvenanceIndex("audit")
src = Table.from_columns({
    "gender": rng.integers(0, 2, N).astype(np.float32),
    "age": rng.uniform(18, 80, N).astype(np.float32),
    "income": rng.lognormal(10, 1, N).astype(np.float32),
    "score": rng.normal(size=N).astype(np.float32),
})
t = track(src, idx, "applicants")
t = t.impute(["income"], strategy="median")
t = t.normalize(["age", "income"], kind="zscore")
t = t.drop_columns(["gender"])                    # gender REMOVED mid-pipeline
t = t.filter_rows(np.asarray(t.table.col("score")) > 0.2)   # selection step
t = t.oversample(frac=0.25, seed=3)
t.mark_sink()
sink = t.dataset_id
n_out = idx.datasets[sink].n_rows
print(f"pipeline: {N} applicants -> {n_out} selected+augmented records "
      f"(gender column dropped mid-way)\n")

gender = src.col("gender").astype(int)

# --- 1. hop-by-hop reference (one lazy backward plan) --------------------------
t0 = time.perf_counter()
contributors = (prov(idx).source(sink).rows(np.arange(n_out))
                .backward().to("applicants").run())
ref_counts = np.bincount(gender[contributors], minlength=2)
t_ref = time.perf_counter() - t0

# --- 2. einsum composition ----------------------------------------------------
t0 = time.perf_counter()
rel = dataset_lineage(idx, "applicants", sink, use_pallas=False)  # (N, n_out)
hits = rel.any(axis=1)
comp_counts = np.bincount(gender[hits], minlength=2)
t_comp = time.perf_counter() - t0

# --- 3. sharded audit (the pod-scale path) -------------------------------------
from repro.launch.mesh import make_local_mesh
mesh = make_local_mesh()
bits = np.asarray(pack_bits(jnp.asarray(rel)))
rel_sh = shard_relation(bits, mesh)
mask = np.ones(n_out, bool)
mw = jnp.asarray(pack_bits(jnp.asarray(mask[None]))[0])
grp = jnp.asarray(gender.astype(np.int32))
t0 = time.perf_counter()
shard_counts = np.asarray(
    lineage_audit_sharded(rel_sh[:N], grp, mw, 2, mesh))
t_shard = time.perf_counter() - t0

print(f"{'method':28s} {'female':>7s} {'male':>7s} {'time':>9s}")
print(f"{'1. hop-by-hop Q2':28s} {ref_counts[0]:7d} {ref_counts[1]:7d} {t_ref*1e3:7.1f}ms")
print(f"{'2. einsum composition':28s} {comp_counts[0]:7d} {comp_counts[1]:7d} {t_comp*1e3:7.1f}ms")
print(f"{'3. sharded audit (psum)':28s} {shard_counts[0]:7d} {shard_counts[1]:7d} {t_shard*1e3:7.1f}ms")

assert (ref_counts == comp_counts).all() and (ref_counts == shard_counts).all()
sel = ref_counts / ref_counts.sum()
base = np.bincount(gender, minlength=2) / N
print(f"\nselection rate by gender: female {sel[0]:.3f} vs base {base[0]:.3f}; "
      f"male {sel[1]:.3f} vs base {base[1]:.3f}")
print("all three methods agree — the audit answers WITHOUT the gender column "
      "ever reaching the output dataset.")

# --- 4. impact API: erasure closure over protected-group rows -------------------
# The forward view of the same question: ONE batched erasure plan per group
# lists every downstream record deriving from that group's rows — and, for
# an actual GDPR request, which datasets to rebuild and which cached
# composed relations to drop.
out_by_group = []
for g, label in ((0, "female"), (1, "male")):
    plan = erasure_plan(idx, "applicants", np.flatnonzero(gender == g))
    impact = plan.impact(sink)
    derived = impact.rows if impact is not None else np.empty(0, np.int64)
    out_by_group.append(derived)
    print(f"erasure closure [{label:6s}]: {len(derived)}/{n_out} output "
          f"records derive from {int((gender == g).sum())} applicants; "
          f"rebuild {list(plan.rebuild)}")
    # cross-check against method 2's composed relation, column-wise
    np.testing.assert_array_equal(
        derived, np.flatnonzero(rel[gender == g].any(axis=0)))
union = np.union1d(*out_by_group)
np.testing.assert_array_equal(union, np.flatnonzero(rel.any(axis=0)))
print("impact closure matches the composed relation group-by-group — one "
      "RecomputePlan per erasure request, no per-row loop.")
