"""Federated lineage: prep pipeline -> boundary export -> serving -> raw row.

    PYTHONPATH=src python examples/federated_lineage.py

The deployment story the catalog exists for: a data-preparation pipeline
owns its :class:`ProvenanceIndex`; the serving tier owns ANOTHER.  The prep
side exports a read-only :class:`BoundaryHandle` over its clean output —
never the index itself — and the engine attaches it with ``upstream=``.
Each recorded request batch links to boundary rows through the
``request_ids`` alignment, so ``response_lineage`` traces a generated
response all the way back to the RAW source row across the index boundary:
one plan, split at the boundary, one cost-model-routed pass per side.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.models.registry import get_model
from repro.provenance import CapabilityError
from repro.serve.engine import ServeEngine

# --- the data-prep pipeline, in ITS OWN index ---------------------------------
rng = np.random.default_rng(7)
n_users = 16
raw = Table.from_columns({
    "user_id": np.arange(100, 100 + n_users, dtype=np.float32),
    "age": rng.integers(12, 70, n_users).astype(np.float32),
    "score": rng.normal(size=n_users).astype(np.float32),
})
prep = ProvenanceIndex("prep")
t = track(raw, prep, "raw_users")
t = t.filter_rows(np.asarray(t.table.col("age")) >= 18.0)   # drop minors
t = t.value_transform("score", "scale", factor=0.5)
clean = t.mark_sink()
print(f"prep pipeline: raw_users ({n_users} rows) -> {clean.dataset_id} "
      f"({clean.table.n_rows} rows), {len(prep.ops)} ops")

# --- export the boundary: a read-only capability, NOT the index ---------------
handle = prep.export(clean.dataset_id)
print("exported boundary:", handle)
try:
    handle.record([], "nope", None, None)
except CapabilityError:
    print("capability: prep index is read-only from the serving tier")

# --- the serving tier attaches upstream provenance via the handle -------------
cfg = get_smoke_config("gemma3-1b")
model = get_model(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))

B, SP, NEW = 4, 8, 6
prompts = rng.integers(1, cfg.vocab, (B, SP)).astype(np.int32)
engine = ServeEngine(cfg, params, max_seq=SP + NEW, dtype=jnp.float32,
                     upstream=handle)

# each request serves a row of the CLEAN dataset: request_ids are the row
# alignment across the boundary link
request_rows = np.array([0, 3, 3, 5]) % clean.table.n_rows
result = engine.generate(prompts, n_new=NEW, request_ids=request_rows,
                         record_provenance=True)
print("recorded:", result.request_dataset, "->", result.response_dataset,
      "| catalog:", engine.catalog)

# --- trace one response token back to the raw source row ----------------------
src_row = engine.response_lineage(result, rows=[2], upstream="raw_users")
uid = int(np.asarray(raw.col("user_id"))[src_row[0]])
print(f"response row 2 traces to raw user row {src_row.tolist()} "
      f"(user_id {uid}) across the boundary")

# batched: every response row traced in ONE pass per federation side
per_request = engine.response_lineage_batch(
    result, [[i] for i in range(B)], upstream="prep/raw_users")
print("batch trace-to-source:", {i: r.tolist() for i, r in enumerate(per_request)})

# --- the plan split is inspectable -------------------------------------------
from repro.provenance import prov  # noqa: E402

plan = (prov(engine.catalog)
        .source(f"serve/{result.response_dataset}").rows([2])
        .backward().to("prep/raw_users").plan())
ex = engine.federation.explain(plan)
print("explain: strategy", ex["strategy"], "| segments:",
      [(s["index"], s["segment"], s["strategy"]) for s in ex["segments"]],
      "| links:", ex["links"])
st = engine.federation.stats()
print("federation stats:", st["federation"])
print("per-index planner plans:",
      {name: s["planner"]["plans"] for name, s in st["indexes"].items()})
