"""Quickstart: TensProv on the paper's own running example (Tables II-V).

    PYTHONPATH=src python examples/quickstart.py

Builds the join of D^l and D^r, tracks a small preparation pipeline through
the decorator front-end, and answers Q1/Q2/Q4/Q9 against the index.
"""
import numpy as np

from repro.core import query as Q
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track

# --- the paper's datasets (Tables II and III) -------------------------------
dl = Table.from_columns({
    "ID": [10., 20., 30., 40.],
    "Birthdate": [1996.0712, 1994.0308, np.nan, 1987.1123],
    "Gender": [0., 1., 0., 1.],           # F=0, M=1
}, null={"Birthdate": [False, False, True, False]})
dr = Table.from_columns({"ID": [20., 40.], "Name": [0., 1.]})  # Alice, Bob

index = ProvenanceIndex("quickstart")
tl = track(dl, index, "D_l")
tr = track(dr, index, "D_r")

# --- the pipeline ------------------------------------------------------------
tj = tl.join(tr, on="ID", how="inner")          # Table IV
tf = tj.filter_rows(np.asarray(tj.table.col("Gender")) > 0.5)
to = tf.onehot("Gender", n_values=2).mark_sink()

print("join result rows:", tj.table.n_rows, "| final rows:", to.table.n_rows)
print("provenance stats:", index.stats())

# --- Q2: backward why-provenance ---------------------------------------------
print("\nQ2  output record 0 derives from:")
print("    D_l rows:", Q.q2_backward(index, to.dataset_id, [0], "D_l"))
print("    D_r rows:", Q.q2_backward(index, to.dataset_id, [0], "D_r"))

# --- Q1: forward — which outputs did D_l record 1 (ID=20) reach? -------------
print("\nQ1  D_l record 1 reaches output rows:",
      Q.q1_forward(index, "D_l", [1], to.dataset_id))
print("Q1  D_l record 0 (ID=10, dangling) reaches:",
      Q.q1_forward(index, "D_l", [0], to.dataset_id))

# --- Q4: attribute-value backward --------------------------------------------
gcol = to.table.columns.index("Gender=1")
cells = Q.q4_backward_attr(index, to.dataset_id, [0], [gcol], "D_l")
print(f"\nQ4  cell (row 0, '{to.table.columns[gcol]}') derives from D_l cells:",
      [tuple(c) for c in cells], "(row, attr) =",
      [(int(r), dl.columns[int(a)]) for r, a in cells])

# --- Q9: how-provenance (all transformations) ---------------------------------
print("\nQ9  transformations applied:",
      [o["op"] for o in Q.q9_all_transformations(index, to.dataset_id)])

# --- dataset-level composition (einsum path) ----------------------------------
from repro.core.compose import dataset_lineage
rel = dataset_lineage(index, "D_l", to.dataset_id, use_pallas=False)
print("\nwhole-dataset lineage relation D_l -> sink (the einsum path):")
print(rel.astype(int))

# --- batch queries: many probe sets, one vectorized pass ----------------------
probes = [[0], [1], [2, 3]]
print("\nbatched Q1 (one pass over the DAG, all probe sets at once):")
for p, res in zip(probes, Q.q1_forward(index, "D_l", probes, to.dataset_id)):
    print(f"    D_l rows {p} -> output rows {res.tolist()}")

# --- the composed hop-cache: multi-hop queries as one probe -------------------
ci = index.composed(memory_budget_bytes=16 << 20)   # LRU byte budget
print("\nhop-cached Q2 (single probe of the composed D_l -> sink relation):")
print("    output row 0 <-", ci.q2_backward(to.dataset_id, [0], "D_l").tolist())
print("    hop-cache stats:", ci.stats())
