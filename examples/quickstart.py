"""Quickstart: TensProv on the paper's own running example (Tables II-V).

    PYTHONPATH=src python examples/quickstart.py

Builds the join of D^l and D^r, tracks a small preparation pipeline through
the decorator front-end, and answers the Table-VII queries through the
unified lazy query API (``repro.provenance``): a fluent builder compiles
each query to a ``QueryPlan``, and the index's shared ``QuerySession``
picks the physical strategy (vectorized walk vs composed hop-cache probe)
and fuses batches that share endpoints into one pass.
"""
import numpy as np

from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.dataprep.tracked import track
from repro.provenance import prov

# --- the paper's datasets (Tables II and III) -------------------------------
dl = Table.from_columns({
    "ID": [10., 20., 30., 40.],
    "Birthdate": [1996.0712, 1994.0308, np.nan, 1987.1123],
    "Gender": [0., 1., 0., 1.],           # F=0, M=1
}, null={"Birthdate": [False, False, True, False]})
dr = Table.from_columns({"ID": [20., 40.], "Name": [0., 1.]})  # Alice, Bob

index = ProvenanceIndex("quickstart")
tl = track(dl, index, "D_l")
tr = track(dr, index, "D_r")

# --- the pipeline ------------------------------------------------------------
tj = tl.join(tr, on="ID", how="inner")          # Table IV
tf = tj.filter_rows(np.asarray(tj.table.col("Gender")) > 0.5)
to = tf.onehot("Gender", n_values=2).mark_sink()
sink = to.dataset_id

print("join result rows:", tj.table.n_rows, "| final rows:", to.table.n_rows)
print("provenance stats:", index.stats())

# --- Q2: backward why-provenance ---------------------------------------------
print("\nQ2  output record 0 derives from:")
print("    D_l rows:", prov(index).source(sink).rows([0]).backward().to("D_l").run())
print("    D_r rows:", prov(index).source(sink).rows([0]).backward().to("D_r").run())

# --- Q1: forward — which outputs did D_l record 1 (ID=20) reach? -------------
print("\nQ1  D_l record 1 reaches output rows:",
      prov(index).source("D_l").rows([1]).forward().to(sink).run())
print("Q1  D_l record 0 (ID=10, dangling) reaches:",
      prov(index).source("D_l").rows([0]).forward().to(sink).run())

# --- Q4: attribute-value backward --------------------------------------------
gcol = to.table.columns.index("Gender=1")
cells = prov(index).source(sink).rows([0]).attrs([gcol]).backward().to("D_l").run()
print(f"\nQ4  cell (row 0, '{to.table.columns[gcol]}') derives from D_l cells:",
      [tuple(c) for c in cells], "(row, attr) =",
      [(int(r), dl.columns[int(a)]) for r, a in cells])

# --- Q6: how-provenance — the same backward trace, plus the per-op hops -------
recs, hops = prov(index).source(sink).rows([0]).backward().to("D_l").how().run()
print("\nQ6  row 0 <- D_l rows", recs.tolist(), "via",
      " -> ".join(h.op_name for h in reversed(hops)))

# --- Q9: all transformations ---------------------------------------------------
print("\nQ9  transformations applied:",
      [o["op"] for o in prov(index).source(sink).transformations().run()])

# --- dataset-level composition (einsum path) ----------------------------------
from repro.core.compose import dataset_lineage
rel = dataset_lineage(index, "D_l", sink, use_pallas=False)
print("\nwhole-dataset lineage relation D_l -> sink (the einsum path):")
print(rel.astype(int))

# --- batch queries: one explicit .rows_batch, one vectorized pass --------------
probes = [[0], [1], [2, 3]]
print("\nbatched Q1 (one pass over the DAG, all probe sets at once):")
for p, res in zip(probes, prov(index).source("D_l").rows_batch(probes)
                  .forward().to(sink).run()):
    print(f"    D_l rows {p} -> output rows {res.tolist()}")

# --- run_many: mixed plans, fused by (source, target) into shared passes -------
session = index.session()
plans = [
    prov(index).source("D_l").rows([0]).forward().to(sink).plan(),
    prov(index).source("D_l").rows([1]).forward().to(sink).plan(),   # fuses w/ ^
    prov(index).source(sink).rows([0]).backward().to("D_r").plan(),
]
res = session.run_many(plans)
print("\nrun_many fused", session.stats()["planner"]["fused_plans"],
      "plans into", session.stats()["planner"]["fused_groups"], "group(s):",
      [r.tolist() for r in res])
print("session stats:", session.stats())
