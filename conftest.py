"""Root conftest: make the src/ layout importable from a clean checkout.

``python -m pytest`` then works without exporting PYTHONPATH (the tier-1
command keeps setting it explicitly; both paths resolve to the same tree).
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
