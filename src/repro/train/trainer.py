"""The training step: loss, microbatched grad accumulation, optimizer.

``make_train_step`` builds the jit-able (state, batch) -> (state, metrics)
function the launcher lowers for the dry-run and the examples run for real:

* next-token cross-entropy with -1-masked labels (pad / document joints);
* gradient accumulation over ``n_micro`` microbatches via lax.scan — the
  global batch is reshaped (n_micro, micro, S) so peak activation memory is
  the single-microbatch footprint (required to fit 405B train_4k on v5e);
* remat policy comes from the model config (per-block jax.checkpoint);
* AdamW from :mod:`repro.train.optimizer` (bf16 moments, optional int8
  error-feedback gradient compression for the cross-pod reduce).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

__all__ = ["TrainState", "make_train_step", "make_loss_fn", "init_train_state"]

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: OptState


def init_train_state(cfg: ModelConfig, key: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    model = get_model(cfg)
    params = model.init_params(cfg, key)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg))


def cross_entropy(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Masked mean NLL.  labels == -1 are ignored.  logits (B,S,V) f32."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom, denom


def make_loss_fn(cfg: ModelConfig, q_chunk: int = 0) -> Callable:
    model = get_model(cfg)

    def loss_fn(params: Pytree, batch: Dict[str, jax.Array]) -> jax.Array:
        if cfg.is_encdec:
            logits = model.forward(cfg, params, batch["frames"], batch["tokens"],
                                   q_chunk=q_chunk)
        else:
            logits = model.forward(cfg, params, batch["tokens"], q_chunk=q_chunk)
        loss, _ = cross_entropy(logits, batch["labels"])
        return loss

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    n_micro: int = 1,
    q_chunk: int = 0,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    loss_fn = make_loss_fn(cfg, q_chunk)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if n_micro > 1:
            def split(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            adt = opt_cfg.accum_dtype

            def acc(carry, mb):
                loss_sum, gsum = carry
                loss, g = grad_fn(state.params, mb)
                gsum = jax.tree.map(lambda a, b_: a + b_.astype(adt), gsum, g)
                return (loss_sum + loss, gsum), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), state.params)
            (loss_sum, gsum), _ = jax.lax.scan(acc, (jnp.float32(0), zeros), micro)
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
        else:
            loss, grads = grad_fn(state.params, batch)

        new_params, new_opt, metrics = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, **metrics}
        return TrainState(new_params, new_opt), metrics

    return train_step
