"""Async, atomic, mesh-elastic checkpointing (no orbax in this container).

Layout per step::

    <dir>/step_000123.tmp/          (written)
        arrays.npz                  (flat {path: np.ndarray})
        meta.msgpack                (step, tree structure, shapes, dtypes, crc)
    <dir>/step_000123/              (atomic rename on completion)

Fault-tolerance properties:

* ATOMIC: readers only ever see fully-written checkpoints (rename is the
  commit point; stale ``.tmp`` dirs from killed writers are garbage-collected
  on next save);
* ASYNC: ``save`` snapshots device arrays to host then hands the file write
  to a background thread — training resumes immediately (the snapshot is the
  only synchronous cost);
* ELASTIC: arrays are saved UNSHARDED (gathered per-leaf) with their logical
  shapes; ``restore`` re-shards onto WHATEVER mesh/sharding the restoring job
  provides — a 2-pod checkpoint restores onto 1 pod or 4 (the
  elastic-rescale path in EXPERIMENTS.md §Dry-run);
* INTEGRITY: per-array CRC32 verified on load;
* RETENTION: ``keep`` most-recent checkpoints, older ones pruned.

On multi-host deployments the gather becomes
``multihost_utils.process_allgather`` per leaf and only process 0 writes —
the layout and commit protocol are unchanged.
"""
from __future__ import annotations

import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

__all__ = ["CheckpointManager"]

Pytree = Any


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Pytree, blocking: bool = False) -> None:
        """Snapshot now, write in the background (or synchronously)."""
        self.wait()  # at most one outstanding writer
        flat = _flatten_with_paths(tree)           # synchronous device->host snapshot
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            arrays = {k: v for k, v in flat}
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            meta = {
                "step": step,
                "treedef": str(treedef),
                "keys": [k for k, _ in flat],
                "crc": {k: zlib.crc32(np.ascontiguousarray(v).tobytes()) for k, v in flat},
                "shapes": {k: list(v.shape) for k, v in flat},
                "dtypes": {k: str(v.dtype) for k, v in flat},
            }
            with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                  # the commit point
            self._prune()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def restore(
        self,
        step: int,
        like: Pytree,
        shardings: Optional[Pytree] = None,
    ) -> Pytree:
        """Restore into the structure of ``like``; if ``shardings`` is given
        (same tree structure, NamedSharding leaves) arrays are placed sharded
        — onto ANY mesh, not necessarily the one that saved (elastic)."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        npz = np.load(os.path.join(path, "arrays.npz"))
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(flat_like)
        )
        leaves = []
        for (kpath, leaf), shard in zip(flat_like, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kpath)
            arr = npz[key]
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc"][key]:
                raise IOError(f"checksum mismatch for {key} at step {step}")
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, shard) if shard is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- housekeeping ---------------------------------------------------------
    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
