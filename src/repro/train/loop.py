"""Fault-tolerant training loop: checkpoint/restart, stragglers, elasticity.

Wraps the jitted train_step with the operational machinery a 1000+-node run
needs.  Single-process semantics here; the multi-host hooks are marked where
a coordinator-backed deployment plugs in.

* RESUME: on start, restore the latest complete checkpoint (atomic dirs, so
  a crash mid-save never corrupts the resume point) and continue from its
  step; the data iterator is re-seeked deterministically from the step.
* PERIODIC + FINAL checkpoints, async writes (training never blocks on I/O).
* STRAGGLER MITIGATION: every step is timed against a deadline derived from
  a running p50; steps beyond `straggler_factor` x p50 are logged and
  counted.  On real fleets this signal feeds the coordinator that evicts or
  re-shards around the slow host; here it is surfaced in metrics and the
  step is never lost (synchronous SPMD cannot drop a participant — the
  mitigation is detection + re-scheduling, not skipping).
* CRASH INJECTION (tests): `fail_at_step` raises mid-run to prove restart
  resumes bit-exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainState

__all__ = ["LoopConfig", "run_training"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None   # test hook: simulated crash


def run_training(
    train_step: Callable,
    state: TrainState,
    batch_fn: Callable[[int], Dict[str, Any]],
    ckpt: CheckpointManager,
    cfg: LoopConfig,
    state_shardings: Optional[Any] = None,
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """``batch_fn(step)`` MUST be a pure function of the step (the data
    pipeline is deterministic/resumable), so restart re-seeks exactly."""
    start_step = 0
    ckpt.wait()  # an in-flight async save (e.g. crashed prior run on this
    # manager) must commit before we resolve the resume point
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(latest, like=state, shardings=state_shardings)
        start_step = latest
        log(f"[resume] restored step {latest}")

    step_times: List[float] = []
    stragglers = 0
    losses: List[float] = []

    for step in range(start_step, cfg.total_steps):
        batch = batch_fn(step)
        if cfg.fail_at_step is not None and step == cfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")

        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0

        if len(step_times) >= 5:
            p50 = float(np.median(step_times))
            if dt > cfg.straggler_factor * p50:
                stragglers += 1
                log(f"[straggler] step {step}: {dt*1e3:.1f} ms vs p50 {p50*1e3:.1f} ms")
        step_times.append(dt)
        losses.append(float(metrics["loss"]))

        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
            ckpt.save(step + 1, state)

    ckpt.wait()
    return {
        "final_state": state,
        "losses": losses,
        "step_times": step_times,
        "stragglers": stragglers,
        "resumed_from": start_step,
    }
