"""Sharded AdamW with the memory/communication tricks the mesh needs.

No optax in this container — implemented from scratch:

* AdamW with decoupled weight decay and global-norm clipping;
* configurable MOMENT dtype (bf16 moments halve optimizer HBM — this is what
  lets llama3-405b training state fit a single 16 GB-HBM v5e pod, see
  EXPERIMENTS.md §Dry-run);
* optional int8 GRADIENT COMPRESSION with error feedback for the cross-pod
  reduction: gradients are fake-quantized to per-leaf int8 scale before the
  (pod-axis) reduce, the quantization residual is carried in the state and
  added back next step.  On real multi-pod hardware the quantize/dequantize
  brackets the `psum` over the "pod" axis (32 GB/s DCI being the scarce
  resource); the arithmetic here is exactly that path's.
* linear-warmup + cosine LR schedule.

Optimizer state sharding mirrors the parameter sharding 1:1 (same tree
structure -> same PartitionSpecs), so FSDP splits moments as well.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update", "lr_schedule"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.bfloat16       # bf16 moments: 8 B/param total state
    accum_dtype: Any = jnp.float32          # microbatch grad-accumulation buffer
    grad_compress_bits: int = 0             # 0 = off, 8 = int8 error-feedback


class OptState(NamedTuple):
    step: jax.Array       # scalar int32
    mu: Pytree            # first moment (moment_dtype)
    nu: Pytree            # second moment (moment_dtype)
    err: Optional[Pytree]  # error-feedback residual (only when compressing)


def init_opt_state(params: Pytree, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.moment_dtype)
    err = jax.tree.map(jnp.zeros_like, params) if cfg.grad_compress_bits else None
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        err=err,
    )


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_peak * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


def _global_norm(tree: Pytree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _fake_quant_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 quantization of one gradient leaf.

    Returns (quantized-and-dequantized gradient, new residual).  The value
    returned is what the receiving side of an int8 all-reduce would see.
    """
    g = g.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    deq = q * scale
    return deq, (g - deq)


def adamw_update(
    params: Pytree,
    grads: Pytree,
    state: OptState,
    cfg: AdamWConfig,
) -> Tuple[Pytree, OptState, Dict[str, jax.Array]]:
    step = state.step

    # --- gradient compression (cross-pod reduce emulation + error feedback) --
    if cfg.grad_compress_bits == 8:
        pairs = jax.tree.map(_fake_quant_int8, grads, state.err)
        grads = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.err

    # --- global-norm clip -----------------------------------------------------
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    # --- Adam moments (kept in moment_dtype) -----------------------------------
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = lr_schedule(cfg, step)

    def upd(p, g, m, v):
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))

    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step + 1, new_mu, new_nu, new_err), metrics
