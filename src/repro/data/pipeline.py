"""Training-data pipeline with first-class TensProv provenance.

This is where the paper's technique becomes a FEATURE of the training
framework: the document -> batch dataflow is itself a data-preparation
pipeline (paper Table I categories in parentheses), and every step's
provenance is captured with the same tensors:

    raw corpus table
      -> quality filter          (horizontal reduction; masking tensor)
      -> dedup                   (horizontal reduction)
      -> tokenize + pack to S    (horizontal augmentation, MULTI-PARENT links:
                                  one packed sequence <- several documents)
      -> shuffle + shard + batch (horizontal reduction per step: the batch's
                                  sequence ids ARE the kept-rows payload)

So "which raw documents fed step 734's batch?" is a Q2 backward query, and
"which batches did flagged document 17 reach?" is Q1 — at any point during
training, in memory, exactly the paper's development-time use case.

The loader is DETERMINISTIC and RESUMABLE: batch t of epoch e is a pure
function of (seed, e, t), so checkpoint-restart re-seeks without state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table

__all__ = ["CorpusConfig", "TokenPipeline", "make_corpus"]


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 2048
    mean_len: int = 384
    vocab: int = 50_000
    seed: int = 0
    min_quality: float = 0.25


def make_corpus(cfg: CorpusConfig) -> Tuple[Table, List[np.ndarray]]:
    """Synthetic raw corpus: a metadata table (the provenance-visible record
    space) + per-doc token arrays (hash-tokenized payload)."""
    rng = np.random.default_rng(cfg.seed)
    lens = np.maximum(16, rng.poisson(cfg.mean_len, cfg.n_docs)).astype(np.int64)
    quality = rng.beta(4, 2, cfg.n_docs).astype(np.float32)
    source = rng.integers(0, 8, cfg.n_docs).astype(np.float32)
    # ~2% exact duplicates to make dedup non-trivial
    dup_of = np.full(cfg.n_docs, -1, np.int64)
    n_dup = max(1, cfg.n_docs // 50)
    dupes = rng.choice(np.arange(1, cfg.n_docs), n_dup, replace=False)
    for d in dupes:
        dup_of[d] = rng.integers(0, d)
    meta = Table.from_columns({
        "doc_id": np.arange(cfg.n_docs, dtype=np.float32),
        "length": lens.astype(np.float32),
        "quality": quality,
        "source": source,
        "consent": (rng.random(cfg.n_docs) > 0.05).astype(np.float32),
    })
    docs = []
    for i in range(cfg.n_docs):
        src = dup_of[i] if dup_of[i] >= 0 else i
        r = np.random.default_rng(cfg.seed * 1_000_003 + int(src))
        docs.append(r.integers(1, cfg.vocab, lens[src], dtype=np.int32))
        if dup_of[i] >= 0:
            meta.data[i] = meta.data[src].copy()
            meta.data[i, 0] = i  # doc_id stays unique
    return meta, docs


class TokenPipeline:
    """corpus -> packed sequences -> deterministic sharded batches,
    provenance captured end-to-end in a ProvenanceIndex."""

    def __init__(self, corpus_cfg: CorpusConfig, seq_len: int,
                 index: Optional[ProvenanceIndex] = None):
        self.cfg = corpus_cfg
        self.seq_len = seq_len
        self.index = index if index is not None else ProvenanceIndex("data-pipeline")
        self._build()

    # -- the tracked pipeline ------------------------------------------------
    def _build(self) -> None:
        cfg = self.cfg
        meta, docs = make_corpus(cfg)
        self.index.add_source("corpus", meta)

        # 1. quality filter (HREDUCE)
        kept = np.flatnonzero(meta.col("quality") >= cfg.min_quality)
        t1 = meta.take_rows(kept)
        self.index.record(
            ["corpus"], "filtered", t1,
            CaptureInfo(op_name="quality_filter", category=OpCategory.HREDUCE,
                        contextual=False, n_out=len(kept), n_in=[meta.n_rows],
                        kept_rows=kept.astype(np.int32),
                        attr_maps=[AttrMap(kind="identity")],
                        params={"min_quality": cfg.min_quality}),
        )

        # 2. dedup by content hash (HREDUCE; contextual — needs the whole set)
        hashes = {}
        uniq = []
        for j, i in enumerate(kept):
            h = docs[i][: min(64, len(docs[i]))].tobytes()
            if h not in hashes:
                hashes[h] = j
                uniq.append(j)
        uniq = np.asarray(uniq, dtype=np.int64)
        t2 = t1.take_rows(uniq)
        self.index.record(
            ["filtered"], "deduped", t2,
            CaptureInfo(op_name="dedup", category=OpCategory.HREDUCE,
                        contextual=True, n_out=len(uniq), n_in=[t1.n_rows],
                        kept_rows=uniq.astype(np.int32),
                        attr_maps=[AttrMap(kind="identity")],
                        params={}),
            input_tables=[t1],
        )
        self.doc_rows = kept[uniq]                       # deduped -> corpus row
        self.docs = [docs[i] for i in self.doc_rows]

        # 3. tokenize + pack (HAUGMENT with multi-parent links)
        S = self.seq_len
        stream = np.concatenate(self.docs) if self.docs else np.zeros(0, np.int32)
        owner = np.repeat(np.arange(len(self.docs), dtype=np.int32),
                          [len(d) for d in self.docs])
        n_seq = len(stream) // S
        self.tokens = stream[: n_seq * S].reshape(n_seq, S).astype(np.int32)
        owner = owner[: n_seq * S].reshape(n_seq, S)
        links = np.unique(
            np.stack([np.repeat(np.arange(n_seq, dtype=np.int32), S),
                      owner.reshape(-1)], axis=1), axis=0)
        seq_table = Table.from_columns({
            "seq_id": np.arange(n_seq, dtype=np.float32),
            "n_docs": np.asarray([(owner[i][1:] != owner[i][:-1]).sum() + 1
                                  for i in range(n_seq)], np.float32),
        })
        self.index.record(
            ["deduped"], "sequences", seq_table,
            CaptureInfo(op_name="pack", category=OpCategory.HAUGMENT,
                        contextual=False, n_out=n_seq, n_in=[len(self.docs)],
                        links=links,
                        attr_maps=[AttrMap(kind="identity")],
                        params={"seq_len": S}),
        )
        self.n_seq = n_seq
        self._batch_ops: Dict[Tuple[int, int], str] = {}

    # -- deterministic resumable batches ---------------------------------------
    def _order(self, epoch: int) -> np.ndarray:
        return np.random.default_rng((self.cfg.seed, epoch)).permutation(self.n_seq)

    def batch_at(self, step: int, batch_size: int,
                 record_provenance: bool = False) -> Dict[str, np.ndarray]:
        """Batch for global step ``step`` (pure function of seed/step)."""
        per_epoch = max(self.n_seq // batch_size, 1)
        epoch, off = divmod(step, per_epoch)
        order = self._order(epoch)
        rows = order[off * batch_size: (off + 1) * batch_size]
        toks = self.tokens[rows]
        batch = {
            "tokens": toks,
            "labels": np.concatenate([toks[:, 1:], np.full((len(rows), 1), -1, toks.dtype)], axis=1),
            "seq_rows": rows.astype(np.int64),
        }
        if record_provenance:
            self._record_batch(step, rows)
        return batch

    def batches(self, batch_size: int, record_provenance: bool = False
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step, batch_size, record_provenance)
            step += 1

    # -- per-batch provenance (HREDUCE of the sequence space) --------------------
    def _record_batch(self, step: int, rows: np.ndarray) -> None:
        ds = f"batch@{step}"
        if ds in self.index.datasets:
            return
        bt = Table.from_columns({"seq_id": rows.astype(np.float32)})
        self.index.record(
            ["sequences"], ds, bt,
            CaptureInfo(op_name=f"batch_select:{step}", category=OpCategory.HREDUCE,
                        contextual=False, n_out=len(rows), n_in=[self.n_seq],
                        kept_rows=rows.astype(np.int32),
                        attr_maps=[AttrMap(kind="identity")],
                        params={"step": step}),
        )

    # -- the paper's queries, specialized --------------------------------------
    def batch_to_documents(self, step: int) -> np.ndarray:
        """Q2: corpus rows that fed the batch at ``step``."""
        from repro.provenance import prov
        ds = f"batch@{step}"
        n = self.index.datasets[ds].n_rows
        return (prov(self.index).source(ds).rows(np.arange(n))
                .backward().to("corpus").run())

    def document_to_batches(self, corpus_row: int) -> List[int]:
        """Q1: steps whose batches a raw document reached."""
        from repro.core.query import forward_record_masks
        masks, _ = forward_record_masks(self.index, "corpus", [corpus_row])
        out = []
        for ds, m in masks.items():
            if ds.startswith("batch@") and m.any():
                out.append(int(ds.split("@")[1]))
        return sorted(out)
