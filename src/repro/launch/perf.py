"""§Perf hillclimb driver: lower one cell under named optimizer/layout
variants and report the roofline deltas.

    PYTHONPATH=src python -m repro.launch.perf --arch llama3-405b \
        --shape train_4k --mesh multi --variant base --variant lowmem

Variants:
  base      AdamW fp32-accum, bf16 moments (the default everywhere)
  lowmem    bf16 grad accumulation (halves the live accumulation buffer)
  compress  lowmem + int8 error-feedback gradient compression (cross-pod)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax.numpy as jnp

from repro.launch.dryrun import lower_cell, run_cell
from repro.launch.hloanal import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.train.optimizer import AdamWConfig

VARIANTS = {
    "base": (AdamWConfig(), None),
    "lowmem": (AdamWConfig(accum_dtype=jnp.bfloat16), None),
    "compress": (AdamWConfig(accum_dtype=jnp.bfloat16, grad_compress_bits=8), None),
    # sequence-parallel residual stream over the model axis (Megatron-SP)
    "sp": (AdamWConfig(accum_dtype=jnp.bfloat16), {"sp": "model"}),
    # pure data parallelism, no TP — the small-model layout (whisper)
    "dponly": (AdamWConfig(), "dp_only"),
    # remat policy: save matmul outputs instead of recomputing everything
    "rematdots": (AdamWConfig(), {"__cfg__": {"remat_policy": "dots"}}),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", action="append", default=None)
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    results = []
    for name in (args.variant or ["base"]):
        opt, pol = VARIANTS[name]
        layout = pol if isinstance(pol, str) else "fsdp_tp"
        extra = pol if isinstance(pol, dict) else None
        cfg_over = (extra or {}).pop("__cfg__", None) if extra else None
        lowered, compiled = lower_cell(args.arch, args.shape, mesh, opt_cfg=opt,
                                       policy_extra=extra or None, layout=layout,
                                       cfg_overrides=cfg_over,
                                       n_micro_override=args.n_micro)
        h = analyze_hlo(compiled.as_text()).as_dict()
        ma = compiled.memory_analysis()
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "variant": name,
            "hlo": h,
            "memory": {
                "argument_size_in_bytes": int(ma.argument_size_in_bytes),
                "temp_size_in_bytes": int(ma.temp_size_in_bytes),
                "output_size_in_bytes": int(ma.output_size_in_bytes),
            },
            "status": "ok",
        }
        results.append(rec)
        print(f"[{name:9s}] args={ma.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"dotF={h['dot_flops']:.3e} traffic={h['traffic_bytes']:.3e} "
              f"coll={h['collective_bytes']:.3e}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"results": results}, f, indent=1)


if __name__ == "__main__":
    main()
