"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; smoke tests
see the 1 real CPU device).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6 names explicit/auto axis types; older releases have neither
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_mesh_compat",
    "make_shard_mesh",
    "host_device_count",
    "request_host_devices",
]


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the install supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh():
    """All locally visible devices on ('data',) — tests and examples."""
    n = len(jax.devices())
    return make_mesh_compat((n,), ("data",))


def host_device_count() -> int:
    """Locally visible device count (after any XLA_FLAGS host-platform
    override — see :func:`request_host_devices`)."""
    return len(jax.devices())


def make_shard_mesh(n_shards: int, axis: str = "shards"):
    """A 1-D mesh over the first ``n_shards`` local devices for the sharded
    provenance index's collective walkers, or ``None`` when the host does
    not expose that many devices (callers fall back to the sequential
    per-shard engine — identical semantics, no mesh)."""
    devices = jax.devices()
    if n_shards < 1 or len(devices) < n_shards:
        return None
    import numpy as np

    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n_shards]), (axis,))


def request_host_devices(n: int) -> bool:
    """Ask XLA's host platform for ``n`` CPU devices by setting
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS``.

    Only effective BEFORE the jax backend initializes — CI's multi-device
    lane exports the flag in the job environment; this helper is for
    launchers that assemble the environment in-process.  Returns whether
    the request can still take effect (False once jax has initialized with
    a different count)."""
    import os

    flag = f"--xla_force_host_platform_device_count={int(n)}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
    import jax._src.xla_bridge as xb

    if xb._backends:  # backend already up: the flag cannot apply anymore
        return len(jax.devices()) >= int(n)
    return True
