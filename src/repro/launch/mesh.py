"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; smoke tests
see the 1 real CPU device).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6 names explicit/auto axis types; older releases have neither
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "make_mesh_compat"]


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the install supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh():
    """All locally visible devices on ('data',) — tests and examples."""
    n = len(jax.devices())
    return make_mesh_compat((n,), ("data",))
