"""Partition rules: FSDP x TP layout for every param / batch / cache leaf.

MaxText-style logical rules, resolved per-mesh with a DIVISIBILITY GUARD: a
dim is only sharded if its size divides the product of the proposed axes
(e.g. whisper's vocab 51865 is not 16-divisible -> the vocab dim of its
embedding falls back to replicated, the d_model dim still FSDPs).

Layout summary (fsdp = ("pod","data") when present, tp = "model"):

  embed        (V, D)        -> (tp, fsdp)     vocab-sharded embedding
  lm_head      (D, V)        -> (fsdp, tp)
  attn wq/wk/wv(D, H*hd)     -> (fsdp, tp)
  attn wo      (H*hd, D)     -> (tp, fsdp)
  mlp wi/wg    (D, F)        -> (fsdp, tp)
  mlp wo       (F, D)        -> (tp, fsdp)
  moe router   (D, E)        -> (fsdp, None)
  moe wi/wg    (E, D, F)     -> (tp, fsdp, None)   expert-parallel
  moe wo       (E, F, D)     -> (tp, None, fsdp)
  ssd in_proj  (D, X)        -> (fsdp, tp)
  ssd out_proj (di, D)       -> (tp, fsdp)
  ssd conv     (k, Cd)       -> (None, tp)
  norms/scalars              -> replicated
  stacked-layer leading axis -> None prepended (blocks / enc_layers / ...)

Batches: tokens/labels (B, S) -> (dp, None) when B divides; frames
(B, T, D) -> (dp, None, None).  Caches: batch over dp when divisible; KV
heads over tp when divisible, else SEQUENCE over tp (the flash-decode
layout for kv_heads < |tp|); long-context batch-1 cells shard the sequence
over (data, model) jointly.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "fsdp_axes", "tp_axis", "param_pspecs", "batch_pspecs", "cache_pspecs",
    "state_pspecs", "to_shardings",
]


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def layout_axes(mesh: Mesh, layout: str = "fsdp_tp"):
    """(fsdp_axes, tp_axis) for a named layout.

    fsdp_tp  — FSDP over (pod, data) x tensor-parallel over model (default).
    dp_only  — pure data parallelism over EVERY axis, no TP: the right
               layout for models far too small to fill a TP group (whisper:
               d_model 768 on a 16-wide model axis leaves 48-wide matmul
               shards and pays per-layer weight gathers; see §Perf iter D2).
    """
    if layout == "dp_only":
        return tuple(mesh.axis_names), None
    return fsdp_axes(mesh), tp_axis(mesh)


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _guard(mesh: Mesh, size: int, axes):
    """axes if size divides their product, else None (replicate)."""
    if axes is None:
        return None
    n = _axes_size(mesh, axes)
    return axes if (n > 1 and size % n == 0) else None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def _param_rule(mesh: Mesh, path: str, shape: Tuple[int, ...],
                layout: str = "fsdp_tp") -> P:
    fs, tp = layout_axes(mesh, layout)
    fs = fs or None
    stacked = any(seg in path for seg in ("blocks/", "enc_layers/", "dec_layers/"))
    core = shape[1:] if stacked else shape
    name = path.rsplit("/", 1)[-1]

    def spec(*dims) -> P:
        resolved = [_guard(mesh, core[i], d) for i, d in enumerate(dims)]
        if stacked:
            resolved = [None] + resolved
        return P(*resolved)

    if len(core) <= 1:
        return P(*([None] * len(shape)))

    if name == "embed":
        return spec(tp, fs)
    if name == "lm_head":
        return spec(fs, tp)
    if "moe" in path:
        if name == "router":
            return spec(fs, None)
        if name in ("wi", "wg"):
            return spec(tp, fs, None)
        if name == "wo":
            return spec(tp, None, fs)
    if "mlp" in path or "attn" in path or "cross" in path:
        if name in ("wi", "wg", "wq", "wk", "wv"):
            return spec(fs, tp)
        if name == "wo":
            return spec(tp, fs)
    if "ssd" in path:
        if name == "in_proj":
            return spec(fs, tp)
        if name == "out_proj":
            return spec(tp, fs)
        if name == "conv_w":
            return spec(None, tp)
    # fallback: FSDP the largest dim
    dims: list = [None] * len(core)
    big = int(np.argmax(core))
    dims[big] = _guard(mesh, core[big], fs)
    if stacked:
        dims = [None] + dims
    return P(*dims)


def param_pspecs(shapes: Any, mesh: Mesh, layout: str = "fsdp_tp") -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [_param_rule(mesh, _path_str(p), tuple(l.shape), layout)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------
def batch_pspecs(shapes: Any, mesh: Mesh, layout: str = "fsdp_tp") -> Any:
    dp = (tuple(mesh.axis_names) if layout == "dp_only" else fsdp_axes(mesh)) or None

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return P()
        b = _guard(mesh, shape[0], dp)
        return P(b, *([None] * (len(shape) - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------
_CACHE_RANK = {"k": 4, "v": 4, "ck": 4, "cv": 4, "state": 4, "conv": 3}


def _cache_rule(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    dp = fsdp_axes(mesh) or None
    tp = tp_axis(mesh)
    name = path.rsplit("/", 1)[-1]
    # stacked (scan) caches carry a leading layer axis above the core rank
    core_rank = _CACHE_RANK.get(name, len(shape))
    stacked = len(shape) == core_rank + 1
    core = shape[1:] if stacked else shape

    def wrap(resolved):
        return P(*(([None] + resolved) if stacked else resolved))

    if name in ("k", "v", "ck", "cv"):
        b, s, kv, hd = core
        bax = _guard(mesh, b, dp)
        if bax is None and _guard(mesh, s, dp + (tp,) if (dp and tp) else tp) is not None:
            # batch-1 long-context: sequence over (data, model) jointly
            joint = (dp + (tp,)) if dp else (tp,)
            return wrap([None, _guard(mesh, s, joint), None, None])
        if _guard(mesh, kv, tp) is not None:
            return wrap([bax, None, tp, None])
        return wrap([bax, _guard(mesh, s, tp), None, None])
    if name == "state":                      # SSD (B, H, P, N)
        b, h, p_, n = core
        return wrap([_guard(mesh, b, dp), _guard(mesh, h, tp), None, None])
    if name == "conv":                       # (B, k-1, Cd)
        b, k_, cd = core
        return wrap([_guard(mesh, b, dp), None, _guard(mesh, cd, tp)])
    return P(*([None] * len(shape)))


def cache_pspecs(shapes: Any, mesh: Mesh) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [_cache_rule(mesh, _path_str(p), tuple(l.shape)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# TrainState (params + optimizer) — moments mirror the param layout
# ---------------------------------------------------------------------------
def state_pspecs(state_shapes: Any, mesh: Mesh, layout: str = "fsdp_tp") -> Any:
    from repro.train.trainer import TrainState
    from repro.train.optimizer import OptState

    pspecs = param_pspecs(state_shapes.params, mesh, layout)
    err = (None if state_shapes.opt.err is None
           else param_pspecs(state_shapes.opt.err, mesh, layout))
    return TrainState(
        params=pspecs,
        opt=OptState(step=P(), mu=pspecs, nu=pspecs, err=err),
    )


def to_shardings(pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
