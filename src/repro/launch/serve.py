"""Serving launcher.

* LOCAL (default): run the batched ServeEngine on a reduced config —
  generates real tokens on this host and reports per-token latency.
* PROD (--mesh single|multi): lower + compile the FULL config's serve_step
  (decode_32k cell) on the production mesh and print the analyses.
* TIER (--tier): additionally record request/response provenance and drive
  per-request lineage probes through the async micro-batching
  :class:`~repro.serve.tier.ServingTier` (fuse-key batching + admission),
  reporting fused-batch stats and probe throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --tier
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-405b \
        --mesh single --dry-run
"""
import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", choices=["local", "single", "multi"], default="local")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-new", type=int, default=16)
    ap.add_argument("--tier", action="store_true",
                    help="record provenance and serve per-request lineage "
                         "probes through the micro-batching ServingTier")
    ap.add_argument("--probes", type=int, default=64,
                    help="lineage probes to push through the tier (--tier)")
    args = ap.parse_args()

    if args.mesh != "local":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rec = run_cell(args.arch, "decode_32k", mesh, args.mesh)
        print({k: v for k, v in rec.items() if k != "trace"})
        sys.exit(0 if rec["status"] in ("ok", "skip") else 1)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import get_model
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (args.batch, 8)).astype(np.int32)
    frames = (rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model))
              .astype(np.float32) if cfg.is_encdec else None)

    engine = ServeEngine(cfg, params, max_seq=8 + args.n_new, dtype=jnp.float32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, n_new=args.n_new, frames=frames,
                          record_provenance=args.tier)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.shape} in {dt:.2f}s "
          f"({dt / args.n_new * 1e3:.1f} ms/token incl. prompt pass)")
    print("first rows:", out.tokens[:2].tolist())

    if args.tier:
        from repro.provenance import prov
        from repro.serve.tier import ServingTier
        req = out.request_dataset
        resp = out.response_dataset
        with ServingTier(engine.as_backend(), max_batch=32,
                         max_wait_ms=2.0) as tier:
            t0 = time.perf_counter()
            futs = [
                tier.submit_nowait(
                    f"tenant-{i % 4}",
                    prov(engine.prov).source(resp).rows([i % args.batch])
                    .backward().to(req).plan())
                for i in range(args.probes)
            ]
            results = [f.result(timeout=60.0) for f in futs]
            dt = time.perf_counter() - t0
        stats = tier.stats()
        fused = stats["tier"]["batches"]
        print(f"tier: {len(results)} lineage probes in {dt * 1e3:.1f} ms "
              f"({len(results) / max(dt, 1e-9):.0f}/s) across {fused} fused "
              f"batches (max width {stats['tier']['max_batch_seen']})")
        print("tier stats:", {k: stats["tier"][k] for k in
                              ("submitted", "completed", "batches",
                               "flush_full", "flush_timer")})


if __name__ == "__main__":
    main()
