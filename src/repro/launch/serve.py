"""Serving launcher.

* LOCAL (default): run the batched ServeEngine on a reduced config —
  generates real tokens on this host and reports per-token latency.
* PROD (--mesh single|multi): lower + compile the FULL config's serve_step
  (decode_32k cell) on the production mesh and print the analyses.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-405b \
        --mesh single --dry-run
"""
import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", choices=["local", "single", "multi"], default="local")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-new", type=int, default=16)
    args = ap.parse_args()

    if args.mesh != "local":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rec = run_cell(args.arch, "decode_32k", mesh, args.mesh)
        print({k: v for k, v in rec.items() if k != "trace"})
        sys.exit(0 if rec["status"] in ("ok", "skip") else 1)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import get_model
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (args.batch, 8)).astype(np.int32)
    frames = (rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model))
              .astype(np.float32) if cfg.is_encdec else None)

    engine = ServeEngine(cfg, params, max_seq=8 + args.n_new, dtype=jnp.float32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, n_new=args.n_new, frames=frames)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.shape} in {dt:.2f}s "
          f"({dt / args.n_new * 1e3:.1f} ms/token incl. prompt pass)")
    print("first rows:", out.tokens[:2].tolist())


if __name__ == "__main__":
    main()
