"""Training launcher.

Two modes:

* LOCAL (default): actually trains a reduced config of ``--arch`` on the
  host devices with the provenance-carrying data pipeline, fault-tolerant
  loop and async checkpoints — runnable end-to-end on this CPU container.

* PROD (--mesh single|multi): builds the production mesh (placeholder
  devices), lowers + compiles the FULL config's train step with the
  FSDP x TP layout, and prints the memory/cost analysis — the launch path a
  real TPU fleet would take (on hardware the same code runs instead of
  stopping at compile).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 30
    PYTHONPATH=src python -m repro.launch.train --arch llama3-405b \
        --mesh multi --dry-run

XLA flags for a real run: --xla_tpu_enable_latency_hiding_scheduler=true
--xla_tpu_megacore_fusion_allow_ags=true (compute/comm overlap; set them in
the deployment environment, they are inert on CPU).
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", choices=["local", "single", "multi"], default="local")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    if args.mesh != "local":
        # production path: device-count env var must precede jax init
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rec = run_cell(args.arch, "train_4k", mesh, args.mesh)
        print({k: v for k, v in rec.items() if k != "trace"})
        sys.exit(0 if rec["status"] in ("ok", "skip") else 1)

    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import CorpusConfig, TokenPipeline
    from repro.train.checkpoint import CheckpointManager
    from repro.train.loop import LoopConfig, run_training
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import init_train_state, make_train_step

    cfg = get_smoke_config(args.arch)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=args.steps,
                      grad_compress_bits=8 if args.grad_compress else 0)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt, n_micro=2))
    tp = TokenPipeline(CorpusConfig(n_docs=256, mean_len=128, vocab=cfg.vocab,
                                    seed=0), seq_len=args.seq)

    def batch_fn(s):
        b = tp.batch_at(s, args.batch, record_provenance=True)
        out = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
        if cfg.is_encdec:
            out["frames"] = jax.random.normal(
                jax.random.PRNGKey(s), (args.batch, cfg.enc_seq, cfg.d_model))
        return out

    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, args.arch), keep=2)
    out = run_training(step, state, batch_fn, ckpt,
                       LoopConfig(total_steps=args.steps, ckpt_every=10))
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"({len(out['losses'])} steps, resumed_from={out['resumed_from']})")
    print(f"batch 0 raw-document lineage: {len(tp.batch_to_documents(0))} docs")


if __name__ == "__main__":
    main()
