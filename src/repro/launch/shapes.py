"""The assigned input-shape cells and their ShapeDtypeStruct stand-ins.

Four shapes per architecture (40 cells total):

    train_4k      seq 4096,    global_batch 256   -> lowers train_step
    prefill_32k   seq 32768,   global_batch 32    -> lowers prefill
    decode_32k    seq 32768,   global_batch 128   -> lowers serve_step
    long_500k     seq 524288,  global_batch 1     -> lowers serve_step

``long_500k`` needs a sub-quadratic mechanism: it RUNS for ssm/hybrid
(constant-size SSD state) and for gemma3's 5:1 local:global interleave
(bounded window caches; the few global layers hold an O(S) cache sharded
over the model axis), and is SKIPPED for pure full-attention stacks —
the skip table below mirrors DESIGN.md §6.

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — nothing
is ever allocated for the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config

__all__ = ["SHAPES", "ShapeCell", "input_specs", "cell_skip_reason", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs with a sub-quadratic mechanism (bounded state or bounded window)
_LONG_OK = {"mamba2-370m", "jamba-1.5-large-398b", "gemma3-12b", "gemma3-1b"}


def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in _LONG_OK:
        return ("pure full attention on every layer: no sub-quadratic "
                "mechanism for a 500k-token cache (DESIGN.md §6)")
    return None


def all_cells():
    for arch in _ARCH_ORDER:
        for shape in SHAPES:
            yield arch, shape


_ARCH_ORDER = [
    "gemma3-12b", "llama3-405b", "gemma3-1b", "olmo-1b", "whisper-small",
    "qwen3-moe-235b-a22b", "dbrx-132b", "mamba2-370m",
    "jamba-1.5-large-398b", "chameleon-34b",
]


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32

    if cell.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs

    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs

    # decode: one new token against an S-long cache
    return {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(arch: str, shape: str) -> Any:
    """ShapeDtypeStructs of the decode cache for a decode cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    from repro.models.registry import get_model
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(cfg, cell.global_batch, cell.seq_len,
                                 dtype=jnp.bfloat16)
    )


# per-(arch, shape) lowering knobs: microbatch count + query chunking,
# chosen so the per-device activation footprint fits 16 GB HBM
_N_MICRO = {
    ("llama3-405b", "train_4k"): 16,
    ("jamba-1.5-large-398b", "train_4k"): 16,
    ("chameleon-34b", "train_4k"): 8,
    ("dbrx-132b", "train_4k"): 8,
    ("qwen3-moe-235b-a22b", "train_4k"): 8,
    ("gemma3-12b", "train_4k"): 4,
    # enc-dec: per-microbatch encoder recompute is the price of fitting
    # 16 GB HBM (temp 107 -> 13.5 GB at nm=16; EXPERIMENTS.md §Perf A3)
    ("whisper-small", "train_4k"): 16,
}


def n_micro(arch: str, shape: str) -> int:
    return _N_MICRO.get((arch, shape), 2 if shape == "train_4k" else 1)


def q_chunk(arch: str, shape: str) -> int:
    cell = SHAPES[shape]
    if cell.kind in ("train", "prefill") and cell.seq_len > 8192:
        return 2048
    return 0
