"""Scan-aware HLO accounting for the roofline analysis.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — but our
models scan over layer blocks (and the trainer scans over microbatches), so
raw numbers undercount by the product of trip counts (e.g. 126x for
llama3-405b).  This module parses the post-optimization HLO text and fixes
that:

1. split the module into named computations;
2. build the call graph: ``while`` ops link to their body/condition
   computations (trip count = the loop bound constant in the condition),
   fusions link via ``calls=``, conditionals via branch computations;
3. propagate a MULTIPLIER from the entry computation (x trip count through
   while bodies, x1 elsewhere);
4. tally, per computation and weighted by multiplier:
   * dot FLOPs (2 x numel(result) x contraction size — the MXU term),
   * collective bytes by kind (result-shape bytes of all-gather/all-reduce/
     reduce-scatter/all-to-all/collective-permute),
   * HBM traffic ~= sum over top-level ops of result+operand bytes (each
     post-fusion op's boundary IS memory traffic to first order; fusion
     bodies are skipped for bytes, included for dot FLOPs).

All counts are PER DEVICE (the HLO is the per-partition SPMD module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloStats", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4, "c64": 8,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%[\w.\-]+")


def _type_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """(total bytes, [(dtype, dims), ...]) for a possibly-tuple type."""
    total = 0
    shapes = []
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, ds))
    return total, shapes


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    type_str: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    is_fusion_body: bool = False


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "per_collective": dict(self.per_collective),
            "n_while": self.n_while,
            "trip_counts": list(self.trip_counts),
        }


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line or line.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\(|\{)", line.strip())
            if m:
                name = m.group(1).lstrip("%")
                cur = _Computation(name=name, ops=[])
                comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if d:
            cur.ops.append(_Op(name=d.group(1).lstrip("%"), kind=d.group(3),
                               type_str=d.group(2), line=line.strip()))
    return comps


def _entry_name(text: str, comps: Dict[str, _Computation]) -> Optional[str]:
    m = re.search(r"^ENTRY\s+(%?[\w.\-]+)", text, re.M)
    if m:
        return m.group(1).lstrip("%")
    # fallback: a computation never referenced by others
    referenced = set()
    for c in comps.values():
        for op in c.ops:
            for key in ("condition=", "body=", "calls=", "to_apply=",
                        "branch_computations="):
                if key in op.line:
                    for nm in re.findall(key.rstrip("=") + r"=\{?([^,)}]+)", op.line):
                        referenced.add(nm.strip().lstrip("%"))
    for name in comps:
        if name not in referenced:
            return name
    return None


def _trip_count(cond: _Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                v = int(m.group(1))
                if 1 <= v <= 1_000_000:
                    best = max(best, v)
    return best


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    entry = _entry_name(text, comps)
    stats = HloStats(per_collective={k: 0.0 for k in _COLLECTIVES})
    if entry is None or entry not in comps:
        return stats

    # symbol table: op name -> type string (module-wide; names are unique)
    types: Dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            types[op.name] = op.type_str

    # multipliers via worklist from the entry
    mult: Dict[str, float] = {entry: 1.0}
    fusion_body: Dict[str, bool] = {name: False for name in comps}
    work = [entry]
    seen_edges = set()
    while work:
        cname = work.pop()
        c = comps.get(cname)
        if c is None:
            continue
        m = mult.get(cname, 1.0)
        for op in c.ops:
            line = op.line
            if op.kind == "while":
                stats.n_while += 1
                mb = re.search(r"body=(%?[\w.\-]+)", line)
                mc = re.search(r"condition=(%?[\w.\-]+)", line)
                if not (mb and mc):
                    continue
                body = mb.group(1).lstrip("%")
                cond = mc.group(1).lstrip("%")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                stats.trip_counts.append(trips)
                edge = (cname, body)
                if edge not in seen_edges:
                    seen_edges.add(edge)
                    mult[body] = mult.get(body, 0.0) + m * trips
                    work.append(body)
            else:
                for key, is_fusion in (("calls=", True), ("to_apply=", False),
                                       ("branch_computations=", False)):
                    if key in line:
                        for nm in re.findall(key.rstrip("=") + r"=\{?([%\w.\-, ]+)\}?", line):
                            for part in nm.split(","):
                                callee = part.strip().lstrip("%")
                                if callee in comps:
                                    edge = (cname, callee)
                                    if edge not in seen_edges:
                                        seen_edges.add(edge)
                                        mult[callee] = mult.get(callee, 0.0) + m
                                        fusion_body[callee] = fusion_body.get(callee, False) or is_fusion
                                        work.append(callee)

    _SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call"}

    for cname, c in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        in_fusion = fusion_body.get(cname, False)
        for op in c.ops:
            # --- dot FLOPs (everywhere, incl. fusion bodies) ----------------
            if op.kind in ("dot", "convolution"):
                out_bytes, out_shapes = _type_info(op.type_str)
                numel = 1
                for _, ds in out_shapes:
                    for d in ds:
                        numel *= d
                # contraction size from the first operand's type
                operands = _OPERAND_RE.findall(op.line.split("(", 1)[1])
                csize = 1
                if operands:
                    lhs_t = types.get(operands[0].lstrip("%"), "")
                    _, lhs_shapes = _type_info(lhs_t)
                    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                    if lhs_shapes and mdims:
                        dims = [int(x) for x in mdims.group(1).split(",") if x]
                        for d in dims:
                            if d < len(lhs_shapes[0][1]):
                                csize *= lhs_shapes[0][1][d]
                stats.dot_flops += m * 2.0 * numel * csize
            if in_fusion:
                continue
            # --- collective bytes -------------------------------------------
            for kind in _COLLECTIVES:
                if op.kind == kind or op.kind.startswith(kind + "-"):
                    b, _ = _type_info(op.type_str)
                    stats.per_collective[kind] += m * b
                    stats.collective_bytes += m * b
                    break
            # --- HBM traffic ------------------------------------------------
            if op.kind in _SKIP_BYTES:
                continue
            out_b, _ = _type_info(op.type_str)
            in_b = 0
            args = op.line.split("(", 1)[1]
            for ref in _OPERAND_RE.findall(args.split("metadata")[0]):
                t = types.get(ref.lstrip("%"))
                if t:
                    b, _ = _type_info(t)
                    in_b += b
            stats.traffic_bytes += m * (out_b + in_b)
    return stats
