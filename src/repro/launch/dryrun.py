"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first two lines — jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import shapes as SH
from repro.launch import sharding as SD
from repro.models import pshard as PS
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import init_train_state, make_train_step


def _act_policy(mesh) -> dict:
    """Default activation policy: batch over (pod,data), width over model.

    moe_groups = |data shards|: MoE dispatch sorts within each data shard
    (local argsort) instead of one global sort (see models/moe.py).
    """
    fs = SD.fsdp_axes(mesh)
    dp_size = 1
    for a in fs:
        dp_size *= mesh.shape[a]
    return {"dp": fs or None, "tp": SD.tp_axis(mesh), "moe_groups": dp_size}


def _fit_n_micro(requested: int, global_batch: int, mesh,
                 layout: str = "fsdp_tp") -> int:
    """Largest n_micro <= requested with (batch/n_micro) divisible by |dp|
    (a microbatch smaller than the data axis forces GSPMD to replicate)."""
    dp_axes, _ = SD.layout_axes(mesh, layout)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    nm = max(1, min(requested, global_batch))
    while nm > 1 and (global_batch % nm or (global_batch // nm) % dp):
        nm -= 1
    return nm

__all__ = ["lower_cell", "run_dryrun", "collective_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO result type, incl. tuples."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the compiled module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", s)
        if not m:
            continue
        type_str, op = m.groups()
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                out[kind] += _shape_bytes(type_str)
                break
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------
def _abstract_state(cfg, opt_cfg):
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, opt_cfg=opt_cfg),
        jax.random.PRNGKey(0),
    )


def lower_cell(arch: str, shape: str, mesh, opt_cfg: Optional[AdamWConfig] = None,
               policy_extra: Optional[dict] = None, layout: str = "fsdp_tp",
               cfg_overrides: Optional[dict] = None,
               n_micro_override: Optional[int] = None):
    """Returns (lowered, compiled) for one cell on one mesh."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = SH.SHAPES[shape]
    model = get_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    nm = _fit_n_micro(n_micro_override or SH.n_micro(arch, shape),
                      cell.global_batch, mesh, layout)
    qc = SH.q_chunk(arch, shape)
    policy = {**_act_policy(mesh), **(policy_extra or {})}
    if layout == "dp_only":
        policy["dp"] = tuple(mesh.axis_names)
        policy["tp"] = None
        policy["moe_groups"] = 1

    with jax.set_mesh(mesh), PS.use_policy(policy):
        if cell.kind == "train":
            state_shapes = _abstract_state(cfg, opt_cfg)
            batch_shapes = SH.input_specs(arch, shape)
            state_sh = SD.to_shardings(SD.state_pspecs(state_shapes, mesh, layout), mesh)
            batch_sh = SD.to_shardings(SD.batch_pspecs(batch_shapes, mesh, layout), mesh)
            step = make_train_step(cfg, opt_cfg, n_micro=nm, q_chunk=qc)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch_shapes)

        elif cell.kind == "prefill":
            params_shapes = _abstract_state(cfg, opt_cfg).params
            param_sh = SD.to_shardings(SD.param_pspecs(params_shapes, mesh, layout), mesh)
            ins = SH.input_specs(arch, shape)
            in_sh = SD.to_shardings(SD.batch_pspecs(ins, mesh, layout), mesh)
            if cfg.is_encdec:
                fn = lambda p, tokens, frames: model.prefill(cfg, p, frames, tokens,
                                                             q_chunk=qc)
                jitted = jax.jit(fn, in_shardings=(param_sh, in_sh["tokens"],
                                                   in_sh["frames"]))
                lowered = jitted.lower(params_shapes, ins["tokens"], ins["frames"])
            else:
                fn = lambda p, tokens: model.prefill(cfg, p, tokens, q_chunk=qc)
                jitted = jax.jit(fn, in_shardings=(param_sh, in_sh["tokens"]))
                lowered = jitted.lower(params_shapes, ins["tokens"])

        else:  # decode
            params_shapes = _abstract_state(cfg, opt_cfg).params
            param_sh = SD.to_shardings(SD.param_pspecs(params_shapes, mesh, layout), mesh)
            cache_shapes = SH.cache_specs(arch, shape)
            cache_sh = SD.to_shardings(SD.cache_pspecs(cache_shapes, mesh), mesh)
            ins = SH.input_specs(arch, shape)
            tok_sh = SD.to_shardings(SD.batch_pspecs(ins, mesh, layout), mesh)

            def serve_step(p, cache, token, pos):
                return model.decode_step(cfg, p, token, pos, cache)

            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, tok_sh["token"], tok_sh["pos"]),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shapes, cache_shapes, ins["token"], ins["pos"])

        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: str, mesh, mesh_name: str) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name}
    skip = SH.cell_skip_reason(arch, shape)
    if skip:
        rec["status"] = "skip"
        rec["reason"] = skip
        return rec
    t0 = time.time()
    try:
        lowered, compiled = lower_cell(arch, shape, mesh)
        rec["status"] = "ok"
        rec["compile_s"] = round(time.time() - t0, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["cost"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
            }
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}
        try:
            from repro.launch.hloanal import analyze_hlo
            txt = compiled.as_text()
            rec["collectives"] = collective_bytes(txt)        # raw (loops once)
            rec["hlo"] = analyze_hlo(txt).as_dict()           # scan-corrected
        except Exception as e:
            rec["hlo"] = {"error": str(e)}
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    return rec


def run_dryrun(archs=None, shapes=None, meshes=("single", "multi"),
               out_path: Optional[str] = None) -> Dict[str, Any]:
    archs = archs or SH._ARCH_ORDER
    shapes = shapes or list(SH.SHAPES)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, mesh_name)
                status = rec["status"]
                extra = (f" {rec.get('compile_s', '')}s" if status == "ok"
                         else f" ({rec.get('reason', rec.get('error', ''))[:80]})")
                print(f"[{mesh_name:6s}] {arch:24s} {shape:12s} {status}{extra}",
                      flush=True)
                results.append(rec)
    summary = {
        "results": results,
        "ok": sum(r["status"] == "ok" for r in results),
        "skip": sum(r["status"] == "skip" for r in results),
        "fail": sum(r["status"] == "fail" for r in results),
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(summary, f, indent=1)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="reports/dryrun.json")
    args = ap.parse_args()
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    summary = run_dryrun(args.arch, args.shape, meshes, args.out)
    print(f"\nok={summary['ok']} skip={summary['skip']} fail={summary['fail']}")
    if summary["fail"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
