"""Config for --arch dbrx-132b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "dbrx-132b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
