"""Config for --arch chameleon-34b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "chameleon-34b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
