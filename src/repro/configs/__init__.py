"""Assigned-architecture configs: one module per arch, ``--arch <id>``."""
from repro.configs.registry import ARCHS, get_config, get_smoke_config
