"""The 10 assigned architectures — exact published configs + reduced smokes.

Every entry is from the assignment table (sources bracketed there).  The
``smoke_*`` variants keep the FAMILY structure (same block pattern, same
mixer kinds, same MoE/SSD topology) at toy width/depth so one forward/train
step runs on CPU in milliseconds; the FULL configs are only ever lowered
via ShapeDtypeStructs (no allocation) in the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import LayerSpec, ModelConfig

__all__ = ["ARCHS", "SMOKES"]

A = LayerSpec(mixer="attn", ffn="mlp")
AL = LayerSpec(mixer="attn_local", ffn="mlp")
AM = LayerSpec(mixer="attn", ffn="moe")
SSD = LayerSpec(mixer="ssd", ffn="none")
SSD_MLP = LayerSpec(mixer="ssd", ffn="mlp")
SSD_MOE = LayerSpec(mixer="ssd", ffn="moe")


# ---------------------------------------------------------------------------
# Dense transformers
# ---------------------------------------------------------------------------
gemma3_12b = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262_144,
    block=(AL, AL, AL, AL, AL, A),       # 5 local : 1 global
    window=1024, rope_theta=1_000_000.0,
)

llama3_405b = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab=128_256,
    block=(A,), rope_theta=500_000.0, tie_embeddings=False,
)

gemma3_1b = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262_144,
    block=(AL, AL, AL, AL, AL, A),       # 4 blocks of 6 ...
    tail=(AL, AL),                       # ... + 2 trailing locals (26 = 4*6+2)
    window=1024, rope_theta=1_000_000.0,
)

olmo_1b = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50_304,
    block=(A,), norm="nonparam_ln",      # OLMo's non-parametric LN
)

# ---------------------------------------------------------------------------
# Audio (enc-dec; conv/mel frontend STUBBED — input_specs provides frames)
# ---------------------------------------------------------------------------
whisper_small = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51_865,
    block=(A,), enc_layers=12, enc_seq=1500,
    gated_mlp=False,                     # whisper uses plain GELU MLPs
)

# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
qwen3_moe = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151_936,
    block=(AM,), n_experts=128, top_k=8,
    head_dim=128, qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
)

dbrx = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100_352,
    block=(AM,), n_experts=16, top_k=4,
    rope_theta=500_000.0, tie_embeddings=False,
)

# ---------------------------------------------------------------------------
# SSM / hybrid
# ---------------------------------------------------------------------------
mamba2_370m = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50_280,
    block=(SSD,), ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

jamba_15_large = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65_536,
    # 1 attn : 7 mamba per block of 8 (attn at index 4), MoE every 2nd layer
    block=(SSD_MLP, SSD_MOE, SSD_MLP, SSD_MOE, AM, SSD_MOE, SSD_MLP, SSD_MOE),
    n_experts=16, top_k=2,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

# ---------------------------------------------------------------------------
# VLM (early fusion; VQ image tokens share the text stream — frontend STUB)
# ---------------------------------------------------------------------------
chameleon_34b = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65_536,
    block=(A,), qk_norm=True,            # chameleon's QK-norm stabilization
)

ARCHS = {
    "gemma3-12b": gemma3_12b,
    "llama3-405b": llama3_405b,
    "gemma3-1b": gemma3_1b,
    "olmo-1b": olmo_1b,
    "whisper-small": whisper_small,
    "qwen3-moe-235b-a22b": qwen3_moe,
    "dbrx-132b": dbrx,
    "mamba2-370m": mamba2_370m,
    "jamba-1.5-large-398b": jamba_15_large,
    "chameleon-34b": chameleon_34b,
}


# ---------------------------------------------------------------------------
# Reduced smoke variants (same family topology, toy size, CPU-runnable)
# ---------------------------------------------------------------------------
def _smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    base = dict(
        n_layers=len(cfg.block) * 2 + len(cfg.tail),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16 if cfg.head_dim else None,
        window=8 if cfg.window else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=2 if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        remat=False,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


SMOKES = {
    name: _smoke(cfg) for name, cfg in ARCHS.items()
}
# gemma3-1b keeps its tail so the remainder path is exercised:
SMOKES["gemma3-1b"] = _smoke(ARCHS["gemma3-1b"], n_layers=2 * 6 + 2)
