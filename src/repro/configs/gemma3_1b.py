"""Config for --arch gemma3-1b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "gemma3-1b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
