"""Model configuration schema shared by all 10 assigned architectures.

A model is a stack of layers described by a repeating BLOCK of
:class:`LayerSpec`s (scanned with stacked params) plus an optional unrolled
TAIL (for layer counts not divisible by the block length, e.g. gemma3-1b's
26 = 4x6 + 2).  Each LayerSpec names its mixer (attention kinds / Mamba2 SSD)
and its FFN (dense MLP / MoE).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["LayerSpec", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer: mixer + ffn.

    mixer:  'attn' (full causal) | 'attn_local' (sliding window) |
            'attn_bidir' (encoder) | 'ssd' (Mamba2)
    ffn:    'mlp' | 'moe' | 'none' (ssd layers fold gating into the mixer in
            pure-Mamba stacks)
    """

    mixer: str = "attn"
    ffn: str = "mlp"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    block: Tuple[LayerSpec, ...] = (LayerSpec(),)
    tail: Tuple[LayerSpec, ...] = ()

    head_dim: Optional[int] = None   # default d_model // n_heads
    vocab_pad_multiple: int = 128    # embedding rows padded so the vocab dim
                                     # shards on any mesh (padded logits are
                                     # masked to -inf; labels never hit them)
    window: int = 0                  # sliding window for 'attn_local'
    qk_norm: bool = False            # chameleon / qwen3-style
    gated_mlp: bool = True           # SwiGLU; False = GELU 2-matrix (whisper)
    norm: str = "rmsnorm"            # rmsnorm | nonparam_ln (olmo)
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # encoder-decoder (whisper): encoder consumes STUB frame embeddings
    enc_layers: int = 0
    enc_seq: int = 0                 # 1500 for whisper

    # training-time defaults (overridable per shape at lowering time)
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)
    scan_layers: bool = True

    # -- derived ---------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m if m else self.vocab

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def n_blocks(self) -> int:
        assert (self.n_layers - len(self.tail)) % len(self.block) == 0, (
            f"{self.name}: {self.n_layers} layers != k*{len(self.block)} + {len(self.tail)}"
        )
        return (self.n_layers - len(self.tail)) // len(self.block)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def layer_specs(self) -> List[LayerSpec]:
        return list(self.block) * self.n_blocks + list(self.tail)

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D bookkeeping)."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        per_mlp = (3 if self.gated_mlp else 2) * d * self.d_ff
        per_moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
        per_ssd = d * (2 * di + 2 * N + H) + self.ssm_conv * (di + 2 * N) \
            + 3 * H + di + di * d
        for spec in self.layer_specs():
            if spec.mixer in ("attn", "attn_local", "attn_bidir"):
                n += per_attn
            elif spec.mixer == "ssd":
                n += per_ssd
            if spec.ffn == "mlp":
                n += per_mlp
            elif spec.ffn == "moe":
                n += per_moe
            n += 2 * d  # the two norms
        if self.is_encdec:
            n += self.enc_layers * (per_attn + per_mlp + 2 * d)   # encoder
            n += self.n_layers * (per_attn + d)                   # cross-attn
        n += d  # final norm
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        inactive = (self.n_experts - self.top_k) * 3 * d * self.d_ff
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        return self.n_params() - n_moe_layers * inactive
