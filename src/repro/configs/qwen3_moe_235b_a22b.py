"""Config for --arch qwen3-moe-235b-a22b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "qwen3-moe-235b-a22b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
