"""Config for --arch gemma3-12b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "gemma3-12b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
