"""Config for --arch jamba-1.5-large-398b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "jamba-1.5-large-398b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
