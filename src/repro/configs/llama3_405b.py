"""Config for --arch llama3-405b (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "llama3-405b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
