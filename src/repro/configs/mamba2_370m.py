"""Config for --arch mamba2-370m (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "mamba2-370m"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
