"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from repro.configs.archs import ARCHS, SMOKES
from repro.configs.base import ModelConfig

__all__ = ["ARCHS", "get_config", "get_smoke_config"]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch]


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in SMOKES:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(SMOKES)}")
    return SMOKES[arch]
