"""Config for --arch whisper-small (exact assignment spec; see archs.py)."""
from repro.configs.archs import ARCHS, SMOKES

ARCH_ID = "whisper-small"
CONFIG = ARCHS[ARCH_ID]
SMOKE = SMOKES[ARCH_ID]
