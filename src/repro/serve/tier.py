"""The async micro-batching serving tier: cross-request plan fusion.

``QuerySession.run_many`` fuses plans that share a fuse key into one packed
physical pass — but nothing in the repo *drove* it under concurrent load:
``ServeEngine`` answers one request at a time, synchronously, in the
caller's thread.  This module is the serving loop that turns the fuse-key
machinery into throughput:

* requests from many logical **tenants** enter through a bounded admission
  gate (:mod:`repro.serve.admission` — typed rejection, per-tenant caps,
  capability scoping via ``BoundaryHandle``-derived :class:`TenantScope`);
* admitted plans accumulate in per-fuse-key **buckets**; a bucket flushes
  when it reaches ``max_batch`` plans or its oldest entry has waited
  ``max_wait_ms`` — the classic micro-batching latency/throughput dial;
* each flushed bucket executes as ONE ``backend.run_many`` call on a
  single executor thread (sessions are not thread-safe; serializing the
  executor is what makes the shared ``QuerySession`` / ``FederatedSession``
  safe to put behind a concurrent front door), and the fused results fan
  back out to each request's future;
* ``shutdown(drain=True)`` closes admission, flushes every bucket, and
  waits for the executor to go idle; ``drain=False`` rejects everything
  still queued with :class:`~repro.serve.admission.TierClosedError`.

The tier is backend-agnostic: anything with ``run_many(plans)`` serves —
a ``QuerySession`` (single index), a ``FederatedSession`` (catalog), a
``BoundaryHandle`` (pre-scoped), or ``ServeEngine.as_backend()`` (which
also qualifies bare serving-local refs).  A backend may expose
``prepare(plan)`` to normalize plans before admission (ref qualification
happens there so capability scoping and bucketing see canonical refs).

Two usage surfaces over one implementation:

* **async** — ``await tier.submit(tenant, plan)`` inside a running event
  loop (``await tier.aclose()`` to shut down);
* **threaded** — ``tier.start()`` hosts the loop in a daemon thread;
  ``tier.submit_sync`` blocks for the result, ``tier.submit_nowait``
  returns a ``concurrent.futures.Future`` (the open-loop load generator's
  entry point), ``tier.shutdown()`` drains and joins.  The tier is also a
  context manager: ``with ServingTier(backend) as tier: ...``.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.provenance.plan import QueryPlan
from repro.serve.admission import AdmissionController, TierClosedError

__all__ = ["ServingTier"]


@dataclasses.dataclass
class _Request:
    """One admitted plan riding a bucket toward a fused pass.

    ``future`` is EITHER an ``asyncio.Future`` (async ``submit``) or a
    ``concurrent.futures.Future`` (threaded burst submission) — both are
    settled from the loop thread, where asyncio futures require it and
    concurrent futures are thread-safe anyway."""

    tenant: str
    plan: QueryPlan
    future: object
    t_submit: float


class ServingTier:
    """Bounded, capability-scoped, micro-batching front door over one
    query backend.

    Tuning knobs:

    ``max_batch``
        flush a bucket at this many plans (the fusion width cap);
    ``max_wait_ms``
        flush a non-full bucket once its oldest plan has waited this long
        (the latency bound a lone probe pays for batching);
    ``max_queue`` / ``max_inflight_per_tenant``
        admission bounds (see :mod:`repro.serve.admission`).
    """

    def __init__(self, backend, *,
                 max_batch: int = 32,
                 max_wait_ms: float = 2.0,
                 max_queue: int = 1024,
                 max_inflight_per_tenant: Optional[int] = None,
                 allow_unregistered: bool = True,
                 name: str = "tier") -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.backend = backend
        self.name = name
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.admission = AdmissionController(
            max_queue, max_inflight_per_tenant, allow_unregistered)
        self.counters: Dict[str, int] = {
            "submitted": 0,
            "completed": 0,
            "failed": 0,
            "batches": 0,
            "batched_plans": 0,
            "flush_full": 0,
            "flush_timer": 0,
            "flush_drain": 0,
            "convoys": 0,
            "max_batch_seen": 0,
        }
        self._buckets: Dict[Tuple, List[_Request]] = {}
        self._timers: Dict[Tuple, "asyncio.TimerHandle"] = {}
        self._ready: Optional[asyncio.Queue] = None
        self._space: Optional[asyncio.Event] = None
        self._executor_task: Optional[asyncio.Task] = None
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"{name}-exec")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()

    # -- tenants ---------------------------------------------------------------
    def register_tenant(self, name: str, scope=None,
                        max_inflight: Optional[int] = None) -> "ServingTier":
        """Register a tenant with a capability scope (``None`` =
        unrestricted, a ``BoundaryHandle``, a :class:`~repro.serve.\
admission.TenantScope`, or an iterable of allowed refs) and an optional
        in-flight cap."""
        self.admission.register(name, scope, max_inflight)
        return self

    # -- async core ------------------------------------------------------------
    def _ensure_loop_state(self) -> None:
        if self._ready is None:
            self._ready = asyncio.Queue()
            self._space = asyncio.Event()

    async def serve(self) -> None:
        """Bind the tier to the RUNNING event loop and start the batch
        executor.  Called automatically by the first ``submit`` (async use)
        or by :meth:`start` (threaded use)."""
        self._loop = asyncio.get_running_loop()
        self._ensure_loop_state()
        if self._executor_task is None or self._executor_task.done():
            self._executor_task = self._loop.create_task(self._executor())

    async def submit(self, tenant: str, plan, *, wait: bool = False):
        """Admit one plan for ``tenant`` and return its result.

        Raises the typed admission errors
        (:class:`~repro.serve.admission.QueueFullError`,
        :class:`~repro.serve.admission.TenantOverloadError`,
        :class:`~repro.serve.admission.TierClosedError`) or
        :class:`~repro.provenance.catalog.CapabilityError` on an
        out-of-scope ref.  ``wait=True`` turns the queue-full rejection
        into backpressure: the submission blocks until capacity frees.
        """
        fut = await self._enqueue(tenant, plan, wait=wait)
        return await fut

    async def _enqueue(self, tenant: str, plan, *,
                       wait: bool = False) -> "asyncio.Future":
        if self._executor_task is None or self._executor_task.done():
            await self.serve()
        if wait:
            # backpressure: park until a release frees capacity.  The
            # clear-then-wait pair has no await between the predicate check
            # and the wait registration, so a wake-up set by a completion
            # callback (which only runs between awaits on this loop) can
            # never be lost.
            while not (self.admission.has_capacity(tenant)
                       or self.admission.closed):
                self._space.clear()
                await self._space.wait()
        return self._admit_and_bucket(tenant, plan)

    def _admit_and_bucket(self, tenant: str, plan,
                          future=None) -> "asyncio.Future":
        """The synchronous enqueue core (loop thread only): normalize,
        admit, bucket, flush-or-arm-timer.  ``future`` lets the burst path
        ride a pre-made ``concurrent.futures.Future`` straight through —
        no per-request chaining callback."""
        plan = plan if isinstance(plan, QueryPlan) else plan.plan()
        prepare = getattr(self.backend, "prepare", None)
        if prepare is not None:
            plan = prepare(plan)
        self.admission.admit(tenant, plan)     # raises the typed rejections
        self.counters["submitted"] += 1
        req = _Request(tenant, plan,
                       self._loop.create_future() if future is None
                       else future,
                       time.perf_counter())
        key = plan.fuse_key()
        bucket = self._buckets.setdefault(key, [])
        bucket.append(req)
        if len(bucket) >= self.max_batch:
            self._flush(key, "flush_full")
        elif len(bucket) == 1:
            self._timers[key] = self._loop.call_later(
                self.max_wait_ms / 1e3, self._flush, key, "flush_timer")
        return req.future

    def _release_batch(self, batch: List[_Request], failed: bool) -> None:
        """Admission bookkeeping for a settled batch, in ONE pass (loop
        thread) — per-future done-callbacks would cost a loop hop per
        request at saturation."""
        for r in batch:
            self.admission.release(r.tenant)
        self.counters["failed" if failed else "completed"] += len(batch)
        if self._space is not None:
            self._space.set()       # wake any backpressured submitters

    def _flush(self, key: Tuple, reason: str) -> None:
        timer = self._timers.pop(key, None)
        if timer is not None:
            timer.cancel()
        batch = self._buckets.pop(key, None)
        if not batch:
            return
        self.counters[reason] += 1
        self.counters["batches"] += 1
        self.counters["batched_plans"] += len(batch)
        self.counters["max_batch_seen"] = max(
            self.counters["max_batch_seen"], len(batch))
        self._ready.put_nowait(batch)

    def _flush_all(self, reason: str = "flush_drain") -> None:
        for key in list(self._buckets):
            self._flush(key, reason)

    async def _executor(self) -> None:
        """Drain ready batches: everything already flushed rides ONE
        ``backend.run_many`` call on the (single-threaded) pool — a convoy
        of same-key batches still splits into per-key fused passes inside
        ``run_many``, and distinct-key batches share the pass overhead.
        The pool serializes backend access, so the shared session never
        sees concurrency while the event loop keeps admitting the next
        wave."""
        while True:
            batch = await self._ready.get()
            if batch is None:       # shutdown sentinel
                self._ready.task_done()
                return
            batches = [batch]
            while True:             # convoy: grab every batch already ready
                try:
                    nxt = self._ready.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if nxt is None:     # keep the sentinel for the next round
                    self._ready.put_nowait(None)
                    self._ready.task_done()
                    break
                batches.append(nxt)
            if len(batches) > 1:
                self.counters["convoys"] += 1
            plans = [r.plan for b in batches for r in b]
            try:
                results = await self._loop.run_in_executor(
                    self._pool, self.backend.run_many, plans)
            except Exception as exc:        # noqa: BLE001 — fan the real
                for b in batches:           # error out to every caller
                    for r in b:
                        if not r.future.done():
                            r.future.set_exception(exc)
                    self._release_batch(b, failed=True)
            else:
                it = iter(results)
                for b in batches:
                    for r in b:
                        res = next(it)
                        if not r.future.done():
                            r.future.set_result(res)
                    self._release_batch(b, failed=False)
            finally:
                for _ in batches:
                    self._ready.task_done()

    async def aclose(self, drain: bool = True) -> None:
        """Stop admitting; ``drain=True`` executes everything already
        admitted before returning, ``drain=False`` rejects it."""
        self.admission.closed = True
        if self._loop is None:
            return
        if drain:
            self._flush_all()
            await self._ready.join()
        else:
            self._flush_all()
            while not self._ready.empty():
                batch = self._ready.get_nowait()
                if batch:
                    for r in batch:
                        if not r.future.done():
                            r.future.set_exception(TierClosedError(
                                "tier shut down without draining"))
                    self._release_batch(batch, failed=True)
                self._ready.task_done()
        if self._executor_task is not None and not self._executor_task.done():
            self._ready.put_nowait(None)
            await self._executor_task
        self._space.set()           # release any backpressured waiters

    # -- threaded facade ---------------------------------------------------------
    def start(self) -> "ServingTier":
        """Host the tier's event loop in a daemon thread (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return self

        def _run() -> None:
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)

            async def _main() -> None:
                await self.serve()
                self._started.set()
                await self._stop_event.wait()

            self._stop_event = asyncio.Event()
            try:
                loop.run_until_complete(_main())
            finally:
                loop.close()

        self._started.clear()
        self._thread = threading.Thread(
            target=_run, name=f"{self.name}-loop", daemon=True)
        self._thread.start()
        self._started.wait()
        return self

    def _require_thread_loop(self) -> asyncio.AbstractEventLoop:
        if self._thread is None or not self._thread.is_alive() \
                or self._loop is None:
            raise TierClosedError(
                "tier loop is not running: call start() (threaded use) or "
                "submit from inside an event loop (async use)")
        return self._loop

    def submit_nowait(self, tenant: str, plan, *,
                      wait: bool = False) -> "concurrent.futures.Future":
        """Submit from any thread; returns a ``concurrent.futures.Future``
        for the result.  Admission errors surface on the future.

        The default (reject-on-full) path is a single ``call_soon`` hop —
        no coroutine per request, so an open-loop load generator can
        sustain tens of thousands of submissions per second.  ``wait=True``
        needs the async backpressure machinery and pays the coroutine."""
        loop = self._require_thread_loop()
        if wait:
            async def _go():
                fut = await self._enqueue(tenant, plan, wait=True)
                return await fut

            return asyncio.run_coroutine_threadsafe(_go(), loop)

        cfut: "concurrent.futures.Future" = concurrent.futures.Future()

        def _one() -> None:
            try:
                self._admit_and_bucket(tenant, plan, future=cfut)
            except Exception as exc:        # noqa: BLE001 — typed admission
                cfut.set_exception(exc)

        loop.call_soon_threadsafe(_one)
        return cfut

    def submit_many_nowait(
            self, tenant: str, plans) -> List["concurrent.futures.Future"]:
        """Burst submission: enqueue a whole list of plans in ONE hop onto
        the loop thread (a single ``call_soon_threadsafe``), so high-rate
        injection pays one scheduling round-trip per burst instead of one
        per request.  Per-plan admission still applies — a rejected plan
        surfaces its typed error on ITS future without failing the rest."""
        loop = self._require_thread_loop()
        cfuts = [concurrent.futures.Future() for _ in plans]

        def _go() -> None:
            for plan, cfut in zip(plans, cfuts):
                try:
                    self._admit_and_bucket(tenant, plan, future=cfut)
                except Exception as exc:    # noqa: BLE001 — typed admission
                    cfut.set_exception(exc)

        loop.call_soon_threadsafe(_go)
        return cfuts

    def submit_sync(self, tenant: str, plan, *, wait: bool = False,
                    timeout: Optional[float] = None):
        """Blocking submit from any thread (the drop-in replacement for a
        direct ``session.run`` call)."""
        return self.submit_nowait(tenant, plan, wait=wait).result(timeout)

    def shutdown(self, drain: bool = True, timeout: Optional[float] = 60.0) -> None:
        """Threaded-mode shutdown: close admission, drain (or reject), stop
        the loop thread, and tear down the executor pool."""
        if self._thread is None or self._loop is None:
            self.admission.closed = True
            self._pool.shutdown(wait=False)
            return
        loop = self._loop
        done = asyncio.run_coroutine_threadsafe(self.aclose(drain), loop)
        done.result(timeout)
        loop.call_soon_threadsafe(self._stop_event.set)
        self._thread.join(timeout)
        self._thread = None
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ServingTier":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    # -- introspection -----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Tier counters + admission counters (+ backend stats when the
        backend exposes them) — the serving-path observability surface."""
        out: Dict[str, object] = {
            "tier": dict(self.counters),
            "admission": self.admission.stats(),
            "queued_buckets": len(self._buckets),
        }
        backend_stats = getattr(self.backend, "stats", None)
        if callable(backend_stats):
            try:
                out["backend"] = backend_stats()
            except Exception:       # noqa: BLE001 — stats must never raise
                pass
        return out
