"""Batched serving engine: prefill + decode with a jitted step.

The serving counterpart of the trainer: holds the KV cache (or SSD state)
for a batch of requests, advances them one token per jitted ``serve_step``,
and traces every emitted token back to its REQUEST RECORD — record-level
why-provenance of the serving path, captured with the same ProvTensor
machinery as the data pipeline (each generated token derives from its
request row: an identity-tensor-per-step collapsed to one HAUGMENT link).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import get_model

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, n_new)
    request_ids: np.ndarray   # (B,) provenance: emitted row -> request row


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, max_seq: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.dtype = dtype
        self.model = get_model(cfg)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: self.model.decode_step(cfg, p, tok, pos, cache,
                                                              dtype=dtype)
        )

    def generate(
        self,
        prompts: np.ndarray,           # (B, S_prompt) int32, -1 padded on the LEFT
        n_new: int,
        request_ids: Optional[np.ndarray] = None,
        greedy: bool = True,
        frames: Optional[np.ndarray] = None,   # enc-dec: stub frontend output
    ) -> GenerationResult:
        cfg = self.cfg
        b, sp = prompts.shape
        cache = self.model.init_cache(cfg, b, self.max_seq, dtype=self.dtype)
        if cfg.is_encdec:
            from repro.models import whisper as W
            assert frames is not None, "enc-dec serving needs frames"
            cache = W.encode_into_cache(cfg, self.params, jnp.asarray(frames, self.dtype),
                                        cache)

        toks = jnp.asarray(np.where(prompts < 0, 0, prompts), jnp.int32)
        # prompt consumption token-by-token through the decode path (simple,
        # exact; bulk prefill is the lowered prefill() used by the dry-run)
        logits = None
        for t in range(sp):
            logits, cache = self._decode(self.params, toks[:, t], jnp.int32(t), cache)

        out = []
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32) if greedy else None
        for i in range(n_new):
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, jnp.int32(sp + i), cache)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        if request_ids is None:
            request_ids = np.arange(b, dtype=np.int64)
        return GenerationResult(
            tokens=np.stack(out, axis=1),
            request_ids=np.asarray(request_ids),
        )
