"""Batched serving engine: prefill + decode with a jitted step.

The serving counterpart of the trainer: holds the KV cache (or SSD state)
for a batch of requests, advances them one token per jitted ``serve_step``,
and traces every emitted token back to its REQUEST RECORD — record-level
why-provenance of the serving path, captured with the same ProvTensor
machinery as the data pipeline (each generated token derives from its
request row: an identity-tensor-per-step collapsed to one HAUGMENT link).

The engine owns a :class:`ProvenanceIndex` and shares its
:class:`~repro.provenance.session.QuerySession`: ``generate(...,
record_provenance=True)`` registers the (response -> request) op, and the
lineage helpers (:meth:`response_lineage`, :meth:`response_lineage_batch`)
compile to :class:`QueryPlan`\\ s and route through the session — so
per-request lineage at scale probes ONE shared composed relation instead of
walking the op DAG per request, and an upstream data-preparation index can
be handed in (``prov_index=...``) to trace responses all the way back to
raw sources.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.models.registry import get_model

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, n_new)
    request_ids: np.ndarray   # (B,) provenance: emitted row -> request row
    # set when the generation was recorded into the engine's index:
    request_dataset: Optional[str] = None
    response_dataset: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, max_seq: int,
                 dtype=jnp.bfloat16,
                 prov_index: Optional[ProvenanceIndex] = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.dtype = dtype
        self.model = get_model(cfg)
        # provenance of the serving path: shared index (hand in the data-prep
        # pipeline's index to trace responses back to raw sources) + the
        # index's shared QuerySession for composed-relation probes
        self.prov = prov_index if prov_index is not None else ProvenanceIndex(
            f"serve:{cfg.name}")
        self._n_generations = 0
        self._decode = jax.jit(
            lambda p, tok, pos, cache: self.model.decode_step(cfg, p, tok, pos, cache,
                                                              dtype=dtype)
        )

    @property
    def session(self):
        """The engine's (index-shared) provenance QuerySession."""
        return self.prov.session()

    def generate(
        self,
        prompts: np.ndarray,           # (B, S_prompt) int32, -1 padded on the LEFT
        n_new: int,
        request_ids: Optional[np.ndarray] = None,
        greedy: bool = True,
        frames: Optional[np.ndarray] = None,   # enc-dec: stub frontend output
        record_provenance: bool = False,
        request_source: Optional[str] = None,  # existing dataset the requests
                                               # are rows of (else auto-added)
    ) -> GenerationResult:
        cfg = self.cfg
        b, sp = prompts.shape
        cache = self.model.init_cache(cfg, b, self.max_seq, dtype=self.dtype)
        if cfg.is_encdec:
            from repro.models import whisper as W
            assert frames is not None, "enc-dec serving needs frames"
            cache = W.encode_into_cache(cfg, self.params, jnp.asarray(frames, self.dtype),
                                        cache)

        toks = jnp.asarray(np.where(prompts < 0, 0, prompts), jnp.int32)
        # prompt consumption token-by-token through the decode path (simple,
        # exact; bulk prefill is the lowered prefill() used by the dry-run)
        logits = None
        for t in range(sp):
            logits, cache = self._decode(self.params, toks[:, t], jnp.int32(t), cache)

        out = []
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32) if greedy else None
        for i in range(n_new):
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, jnp.int32(sp + i), cache)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        if request_ids is None:
            request_ids = np.arange(b, dtype=np.int64)
        result = GenerationResult(
            tokens=np.stack(out, axis=1),
            request_ids=np.asarray(request_ids),
        )
        if record_provenance:
            self._record_generation(result, prompt_len=sp, n_new=n_new,
                                    request_source=request_source)
        return result

    # -- provenance capture ----------------------------------------------------
    def _record_generation(self, result: GenerationResult, prompt_len: int,
                           n_new: int, request_source: Optional[str]) -> None:
        """Register the (response row -> request row) HAUGMENT op.

        With ``request_source`` the responses link to rows of an EXISTING
        dataset (``request_ids`` are row indices into it) — lineage then
        continues upstream through whatever pipeline produced it."""
        b = result.tokens.shape[0]
        # unique per INDEX, not per engine: several engines may share one
        # prov_index (the documented pattern), or the index may already hold
        # earlier generations
        gid = self._n_generations
        while (f"responses@{gid}" in self.prov.datasets
               or f"requests@{gid}" in self.prov.datasets):
            gid += 1
        self._n_generations = gid + 1
        if request_source is None:
            req_ds = f"requests@{gid}"
            self.prov.add_source(req_ds, Table.from_columns({
                "request_id": np.asarray(result.request_ids, np.float32),
                "prompt_len": np.full(b, prompt_len, np.float32),
            }))
            src_rows = np.arange(b, dtype=np.int32)
        else:
            if request_source not in self.prov.datasets:
                raise KeyError(f"unknown request dataset {request_source!r}")
            req_ds = request_source
            src_rows = np.asarray(result.request_ids, dtype=np.int32)
        resp_ds = f"responses@{gid}"
        self.prov.record(
            [req_ds], resp_ds,
            Table.from_columns({
                "request_id": np.asarray(result.request_ids, np.float32),
                "n_tokens": np.full(b, n_new, np.float32),
            }),
            CaptureInfo(op_name="generate", category=OpCategory.HAUGMENT,
                        contextual=False, n_out=b,
                        n_in=[self.prov.datasets[req_ds].n_rows],
                        src_rows=src_rows,
                        attr_maps=[AttrMap(kind="identity")],
                        params={"n_new": n_new, "prompt_len": prompt_len}),
            keep_output=True,
        )
        result.request_dataset = req_ds
        result.response_dataset = resp_ds

    # -- lineage queries (route through the shared session) ---------------------
    def response_lineage(self, result: GenerationResult, rows=None,
                         upstream: Optional[str] = None) -> np.ndarray:
        """Rows of ``upstream`` (default: the request dataset) that the given
        response rows derive from — ONE composed-relation probe once the
        relation is cached (shared across every request and session user)."""
        if result.response_dataset is None:
            raise ValueError("generation was not recorded "
                             "(generate(..., record_provenance=True))")
        from repro.provenance import prov

        if rows is None:
            rows = np.ones(result.tokens.shape[0], dtype=bool)
        dst = upstream if upstream is not None else result.request_dataset
        return (prov(self.prov).source(result.response_dataset)
                .rows(rows).backward().to(dst).run(self.session))

    def response_lineage_batch(self, result: GenerationResult, rows_batch,
                               upstream: Optional[str] = None) -> List[np.ndarray]:
        """Per-request lineage for MANY probe sets in one fused pass (one
        plan, one packed-bitplane probe of the shared composed relation)."""
        if result.response_dataset is None:
            raise ValueError("generation was not recorded "
                             "(generate(..., record_provenance=True))")
        from repro.provenance import prov

        dst = upstream if upstream is not None else result.request_dataset
        return (prov(self.prov).source(result.response_dataset)
                .rows_batch(rows_batch).backward().to(dst).run(self.session))
