"""Batched serving engine: prefill + decode with a jitted step.

The serving counterpart of the trainer: holds the KV cache (or SSD state)
for a batch of requests, advances them one token per jitted ``serve_step``,
and traces every emitted token back to its REQUEST RECORD — record-level
why-provenance of the serving path, captured with the same ProvTensor
machinery as the data pipeline (each generated token derives from its
request row: an identity-tensor-per-step collapsed to one HAUGMENT link).

The engine owns its OWN :class:`ProvenanceIndex` (the serving pipeline's)
and a :class:`~repro.provenance.catalog.ProvCatalog` around it.  Upstream
data-preparation provenance attaches through ``upstream=``:

* a :class:`~repro.provenance.catalog.BoundaryHandle` minted by
  ``prep_index.export(dataset_id)`` — the engine registers the read-only
  capability, never the prep index object itself, and links each recorded
  request batch to boundary rows through the ``request_ids`` alignment;
* or ``(catalog, "name/dataset")`` — the engine registers its serving
  index into an EXISTING catalog and uses that qualified ref as the
  boundary.

``generate(..., record_provenance=True)`` registers the
(response -> request) op; :meth:`response_lineage` /
:meth:`response_lineage_batch` compile to :class:`QueryPlan`\\ s — serving-
local targets route through the index's shared ``QuerySession`` (ONE
composed relation per endpoint pair), upstream targets route through the
catalog's :class:`~repro.provenance.federation.FederatedSession`, tracing
responses all the way back to raw prep sources across the boundary.

The legacy ``prov_index=`` attach — handing the engine the whole prep
index to record into — is DEPRECATED (it grants the serving tier mutation
rights over data-prep provenance): it still works, wrapped in a
single-entry catalog, and warns once per process.
"""
from __future__ import annotations

import dataclasses
import sys
import warnings
from typing import Any, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.opcat import AttrMap, CaptureInfo, OpCategory
from repro.core.pipeline import ProvenanceIndex
from repro.dataprep.table import Table
from repro.models.registry import get_model
from repro.provenance.catalog import (
    BoundaryHandle,
    ProvCatalog,
    qualify,
    split_ref,
)

__all__ = ["ServeEngine", "GenerationResult"]

_DEPRECATION_WARNED: Set[str] = set()


def _warn_once(key: str, message: str) -> None:
    """Once-per-process deprecation (the q1-q11 shim pattern).

    The stacklevel is computed, not hardcoded: the ``prov_index=`` path
    reaches here through ``__init__`` → ``_init_provenance`` (level 4)
    while tests drive ``_init_provenance`` directly (level 3) — a fixed
    level points one of the two at an engine-internal frame instead of the
    caller's ``ServeEngine(...)`` line.  Walking out of this module's
    frames attributes the warning to the first external call site on
    either path."""
    if key in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(key)
    level, frame = 2, sys._getframe(1)
    while frame.f_back is not None and frame.f_globals.get("__name__") == __name__:
        frame = frame.f_back
        level += 1
    warnings.warn(message, DeprecationWarning, stacklevel=level)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, n_new)
    request_ids: np.ndarray   # (B,) provenance: emitted row -> request row
    # set when the generation was recorded into the engine's index:
    request_dataset: Optional[str] = None
    response_dataset: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, max_seq: int,
                 dtype=jnp.bfloat16,
                 upstream=None,
                 prov_index: Optional[ProvenanceIndex] = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.dtype = dtype
        self.model = get_model(cfg)
        self._init_provenance(f"serve:{cfg.name}", upstream=upstream,
                              prov_index=prov_index)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: self.model.decode_step(cfg, p, tok, pos, cache,
                                                              dtype=dtype)
        )

    # -- provenance wiring ------------------------------------------------------
    def _init_provenance(self, name: str, upstream=None,
                         prov_index: Optional[ProvenanceIndex] = None) -> None:
        """Build the serving index + catalog.  Split out of ``__init__`` so
        the capture path is testable without instantiating a model."""
        self._n_generations = 0
        self._upstream: Optional[Tuple[str, str]] = None  # (member, boundary ds)
        if prov_index is not None:
            if upstream is not None:
                raise ValueError(
                    "pass either upstream= (catalog attach) or the deprecated "
                    "prov_index=, not both")
            _warn_once(
                "prov_index",
                "ServeEngine(prov_index=...) is deprecated: handing the "
                "serving tier the whole data-prep index grants it record() "
                "rights over prep provenance.  Attach upstream lineage with "
                "upstream=prep_index.export(dataset_id) (a read-only "
                "BoundaryHandle) or upstream=(catalog, 'name/dataset') "
                "instead; the passed index is wrapped in a single-entry "
                "catalog for now.",
            )
            self.prov = prov_index
            self._serve_name = "serve"
            self.catalog = ProvCatalog(name)
            self.catalog.register(self._serve_name, self.prov)
            return
        self.prov = ProvenanceIndex(name)
        if upstream is None:
            self._serve_name = "serve"
            self.catalog = ProvCatalog(name)
            self.catalog.register(self._serve_name, self.prov)
        elif isinstance(upstream, BoundaryHandle):
            self._serve_name = "serve"
            self.catalog = ProvCatalog(name)
            up_name = upstream.index_name
            if not up_name or "/" in up_name or up_name == self._serve_name:
                up_name = "upstream"
            self.catalog.register(up_name, upstream)
            self.catalog.register(self._serve_name, self.prov)
            self._upstream = (up_name, upstream.boundary)
        elif (isinstance(upstream, tuple) and len(upstream) == 2
                and isinstance(upstream[0], ProvCatalog)):
            catalog, ref = upstream
            catalog.datasets[ref]   # resolve the DATASET now: a typo'd ref
                                    # must fail here, not at first generate()
            serve_name, i = "serve", 2
            while serve_name in catalog.members:
                serve_name, i = f"serve{i}", i + 1
            catalog.register(serve_name, self.prov)
            self.catalog = catalog
            self._serve_name = serve_name
            self._upstream = split_ref(ref)
        else:
            raise TypeError(
                f"upstream= takes a BoundaryHandle or (ProvCatalog, "
                f"'name/dataset'), got {type(upstream).__name__}")

    @property
    def session(self):
        """The engine's (index-shared) provenance QuerySession."""
        return self.prov.session()

    @property
    def federation(self):
        """The catalog's shared FederatedSession (cross-index lineage)."""
        return self.catalog.session()

    def generate(
        self,
        prompts: np.ndarray,           # (B, S_prompt) int32, -1 padded on the LEFT
        n_new: int,
        request_ids: Optional[np.ndarray] = None,
        greedy: bool = True,
        sample_seed: int = 0,          # greedy=False: PRNG seed (temperature 1)
        frames: Optional[np.ndarray] = None,   # enc-dec: stub frontend output
        record_provenance: bool = False,
        request_source: Optional[str] = None,  # existing dataset the requests
                                               # are rows of (else auto-added)
    ) -> GenerationResult:
        cfg = self.cfg
        b, sp = prompts.shape
        cache = self.model.init_cache(cfg, b, self.max_seq, dtype=self.dtype)
        if cfg.is_encdec:
            from repro.models import whisper as W
            assert frames is not None, "enc-dec serving needs frames"
            cache = W.encode_into_cache(cfg, self.params, jnp.asarray(frames, self.dtype),
                                        cache)

        toks = jnp.asarray(np.where(prompts < 0, 0, prompts), jnp.int32)
        # prompt consumption token-by-token through the decode path (simple,
        # exact; bulk prefill is the lowered prefill() used by the dry-run)
        logits = None
        for t in range(sp):
            logits, cache = self._decode(self.params, toks[:, t], jnp.int32(t), cache)

        # greedy: argmax.  greedy=False: temperature-1 categorical sampling
        # with a SEEDED key split per step — deterministic for a given
        # (params, prompts, sample_seed), the reproducibility contract the
        # provenance record rests on.
        key = jax.random.PRNGKey(sample_seed)

        def _next_token(logits, key):
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits.astype(jnp.float32),
                                         axis=-1)
            return tok.astype(jnp.int32), key

        out = []
        cur, key = _next_token(logits, key)
        for i in range(n_new):
            out.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cur, jnp.int32(sp + i), cache)
            cur, key = _next_token(logits, key)

        request_ids_given = request_ids is not None
        if request_ids is None:
            request_ids = np.arange(b, dtype=np.int64)
        result = GenerationResult(
            tokens=np.stack(out, axis=1),
            request_ids=np.asarray(request_ids),
        )
        if record_provenance:
            self._record_generation(result, prompt_len=sp, n_new=n_new,
                                    request_source=request_source,
                                    request_ids_given=request_ids_given)
        return result

    # -- provenance capture ----------------------------------------------------
    def _record_generation(self, result: GenerationResult, prompt_len: int,
                           n_new: int, request_source: Optional[str],
                           request_ids_given: bool = True) -> None:
        """Register the (response row -> request row) HAUGMENT op.

        With ``request_source`` the responses link to rows of an EXISTING
        dataset of the serving index (``request_ids`` are row indices into
        it).  With an ``upstream=`` attach and no ``request_source``, the
        fresh request dataset is LINKED to the upstream boundary through the
        ``request_ids`` row alignment (each request row came from that
        boundary row; ``-1`` marks a request with no upstream origin) —
        lineage then continues into the data-prep pipeline across the
        federation."""
        b = result.tokens.shape[0]
        # unique per INDEX, not per engine: several engines may share one
        # prov index (the documented pattern), or the index may already hold
        # earlier generations
        gid = self._n_generations
        while (f"responses@{gid}" in self.prov.datasets
               or f"requests@{gid}" in self.prov.datasets):
            gid += 1
        self._n_generations = gid + 1
        if request_source is None:
            upstream = getattr(self, "_upstream", None)
            alignment = None
            if upstream is not None:
                # the boundary link is a lineage ASSERTION — never fabricate
                # it from the arange() default, and validate the alignment
                # BEFORE any index mutation so a bad batch can't leave an
                # orphan requests@N dataset behind
                if not request_ids_given:
                    raise ValueError(
                        "upstream-attached engines need explicit request_ids "
                        "(rows of the boundary dataset, -1 for requests with "
                        "no upstream origin) to record provenance")
                up_name, boundary = upstream
                n_up = self.catalog.datasets[qualify(up_name, boundary)].n_rows
                alignment = np.asarray(result.request_ids, np.int64)
                if alignment.size and (alignment.max() >= n_up
                                       or alignment.min() < -1):
                    raise ValueError(
                        f"request_ids must be rows of the boundary dataset "
                        f"{qualify(up_name, boundary)!r} (in [-1, {n_up})), "
                        f"got range [{alignment.min()}, {alignment.max()}]")
            req_ds = f"requests@{gid}"
            self.prov.add_source(req_ds, Table.from_columns({
                "request_id": np.asarray(result.request_ids, np.float32),
                "prompt_len": np.full(b, prompt_len, np.float32),
            }))
            src_rows = np.arange(b, dtype=np.int32)
            if alignment is not None:
                self.catalog.link(
                    qualify(up_name, boundary),
                    qualify(self._serve_name, req_ds),
                    alignment=alignment,
                )
        else:
            if request_source not in self.prov.datasets:
                raise KeyError(f"unknown request dataset {request_source!r}")
            req_ds = request_source
            src_rows = np.asarray(result.request_ids, dtype=np.int32)
        resp_ds = f"responses@{gid}"
        self.prov.record(
            [req_ds], resp_ds,
            Table.from_columns({
                "request_id": np.asarray(result.request_ids, np.float32),
                "n_tokens": np.full(b, n_new, np.float32),
            }),
            CaptureInfo(op_name="generate", category=OpCategory.HAUGMENT,
                        contextual=False, n_out=b,
                        n_in=[self.prov.datasets[req_ds].n_rows],
                        src_rows=src_rows,
                        attr_maps=[AttrMap(kind="identity")],
                        params={"n_new": n_new, "prompt_len": prompt_len}),
            keep_output=True,
        )
        result.request_dataset = req_ds
        result.response_dataset = resp_ds

    # -- lineage queries (shared session / federation) ---------------------------
    def _lineage_target(self, dst: str) -> Tuple[bool, str]:
        """Resolve a lineage target dataset: ``(federated?, ref)``.

        Accepts a dataset of the serving index (local plan), a qualified
        catalog ref (``"prep/raw"``), or a bare dataset of the attached
        upstream member (auto-qualified)."""
        if dst in self.prov.datasets:
            return False, dst
        if "/" in dst and dst in self.catalog.datasets:
            return True, dst
        upstream = getattr(self, "_upstream", None)
        if upstream is not None:
            ref = qualify(upstream[0], dst)
            if ref in self.catalog.datasets:
                return True, ref
        raise KeyError(
            f"unknown lineage target {dst!r}: not a serving dataset, a "
            f"qualified catalog ref, or an upstream dataset")

    def _lineage_builder(self, result: GenerationResult, dst: str):
        from repro.provenance import prov

        if result.response_dataset is None:
            raise ValueError("generation was not recorded "
                             "(generate(..., record_provenance=True))")
        federated, ref = self._lineage_target(dst)
        if not federated:
            qb = prov(self.prov).source(result.response_dataset)
            return qb.backward().to(ref), self.session
        qb = prov(self.catalog).source(
            qualify(self._serve_name, result.response_dataset))
        return qb.backward().to(ref), self.federation

    def response_lineage(self, result: GenerationResult, rows=None,
                         upstream: Optional[str] = None) -> np.ndarray:
        """Rows of ``upstream`` (default: the request dataset) that the given
        response rows derive from.  Serving-local targets probe ONE shared
        composed relation; upstream targets cross the boundary through the
        catalog's FederatedSession (plan split + mask stitch), so a response
        token traces to raw prep sources without the engine ever holding the
        prep index."""
        if rows is None:
            rows = np.ones(result.tokens.shape[0], dtype=bool)
        qb, sess = self._lineage_builder(
            result, upstream if upstream is not None else result.request_dataset)
        return qb.rows(rows).run(sess)

    def response_lineage_batch(self, result: GenerationResult, rows_batch,
                               upstream: Optional[str] = None) -> List[np.ndarray]:
        """Per-request lineage for MANY probe sets in one fused pass — one
        packed probe per member segment, even across the boundary."""
        qb, sess = self._lineage_builder(
            result, upstream if upstream is not None else result.request_dataset)
        return qb.rows_batch(rows_batch).run(sess)

    def erasure_impact(self, rows, source: Optional[str] = None,
                       apply: bool = False):
        """Deletion-propagation plan for erasing ``rows`` of ``source`` —
        the serving tier's GDPR entry point.

        ``source`` defaults to the attached upstream boundary dataset;
        bare names are resolved like lineage targets (serving dataset
        first, then the upstream member).  The closure crosses every
        boundary link downstream — an upstream erasure reaches through
        request batches into recorded responses — and the returned
        :class:`~repro.provenance.impact.RecomputePlan` lists affected
        datasets in execution order plus the stale composed relations
        (member hop-caches AND the catalog's stitched cross-relations).
        ``apply=True`` drops those stale entries before returning."""
        from repro.provenance.impact import apply_invalidations, erasure_plan

        if source is None:
            upstream = getattr(self, "_upstream", None)
            if upstream is None:
                raise ValueError(
                    "no upstream provenance attached; pass source=")
            ref = qualify(*upstream)
        else:
            _, ref = self._lineage_target(source)
            if "/" not in ref:
                ref = qualify(self._serve_name, ref)
        plan = erasure_plan(self.catalog, ref, rows)
        if apply:
            apply_invalidations(self.catalog, plan)
        return plan

    # -- serving-tier integration -------------------------------------------------
    def as_backend(self) -> "_EngineBackend":
        """This engine as a :class:`~repro.serve.tier.ServingTier` backend.

        Plans execute through the catalog's shared ``FederatedSession`` —
        serving-local probes delegate to the engine's own ``QuerySession``
        (single-member plans always do), upstream targets split and stitch
        across the boundary, and ``run_many`` fuses either kind across
        requests.  Bare (unqualified) refs naming serving-index datasets
        are qualified with the engine's member name in ``prepare`` so
        tenants can submit ``responses@0 -> requests@0`` probes without
        knowing the catalog layout — and so capability scopes and fuse
        buckets see one canonical spelling per dataset.
        """
        return _EngineBackend(self)


class _EngineBackend:
    """Tier backend adapter over one engine's federation session."""

    def __init__(self, engine: ServeEngine) -> None:
        self._engine = engine

    def _qualify_ref(self, ref: Optional[str]) -> Optional[str]:
        if ref is None or "/" in ref:
            return ref
        if ref in self._engine.prov.datasets:
            return qualify(self._engine._serve_name, ref)
        return ref      # unknown bare ref: let the session raise its error

    def prepare(self, plan):
        refs = {r: self._qualify_ref(r) for r in plan.refs()}
        if all(k == v for k, v in refs.items()):
            return plan
        sub = lambda r: refs.get(r, r) if r is not None else None  # noqa: E731
        return dataclasses.replace(
            plan, source=sub(plan.source), target=sub(plan.target),
            via=sub(plan.via), anchor=sub(plan.anchor))

    def run_many(self, plans) -> List:
        return self._engine.federation.run_many(plans)

    def stats(self):
        return self._engine.federation.stats()
