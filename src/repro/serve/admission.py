"""Admission control for the async serving tier.

The tier sits in front of shared query machinery (one ``QuerySession`` /
``FederatedSession`` per backend), so the failure mode of an unbounded
front door is global: one chatty tenant fills the accumulation buckets and
every other tenant's p99 explodes.  This module is the bounded front door:

* a **global queue bound** (``max_queue`` requests admitted-but-uncompleted)
  — submission past it either fails fast with :class:`QueueFullError`
  (typed rejection, the load-shedding default) or, with ``wait=True`` at
  the tier surface, blocks until capacity frees (backpressure);
* **per-tenant in-flight caps** (``max_inflight``) — a tenant that already
  holds its cap's worth of admitted requests gets
  :class:`TenantOverloadError` regardless of global headroom, so no tenant
  can monopolize the queue;
* **capability scoping** — each tenant holds a :class:`TenantScope`
  (typically derived from a :class:`~repro.provenance.catalog.\
BoundaryHandle`, never the index itself); a submitted plan whose refs
  leave the scope raises the same typed
  :class:`~repro.provenance.catalog.CapabilityError` the federation layer
  uses, *at admission time*, before the plan ever reaches a bucket;
* a **closed latch** — after shutdown begins every submission is rejected
  with :class:`TierClosedError` so drain can complete deterministically.

Everything here is plain single-threaded bookkeeping: the tier calls it
only from its event loop, so there are no locks to reason about.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, Optional

from repro.provenance.catalog import CapabilityError

__all__ = [
    "AdmissionError",
    "QueueFullError",
    "TenantOverloadError",
    "TierClosedError",
    "TenantScope",
    "TenantState",
    "AdmissionController",
]


class AdmissionError(RuntimeError):
    """Base of every typed admission rejection (never raised directly)."""


class QueueFullError(AdmissionError):
    """The tier's global admission queue is at ``max_queue``; the request
    was shed.  Retry later or submit with ``wait=True`` for backpressure."""


class TenantOverloadError(AdmissionError):
    """This tenant already holds ``max_inflight`` admitted requests; the
    request was shed without touching global capacity."""


class TierClosedError(AdmissionError):
    """The tier is shutting down (or was never started); no new requests
    are admitted."""


# ---------------------------------------------------------------------------
# Capability scoping
# ---------------------------------------------------------------------------
class TenantScope:
    """The set of dataset refs one tenant's plans may touch.

    ``allowed=None`` means unrestricted (the operator tenant).  Build from
    a :class:`~repro.provenance.catalog.BoundaryHandle` with
    :meth:`from_handle` — the scope copies the handle's ancestor-closure
    ref set at registration time and holds NO reference to the handle or
    its index afterwards, so a tier tenant can never reach provenance the
    export did not grant.
    """

    def __init__(self, allowed: Optional[Iterable[str]] = None) -> None:
        self.allowed: Optional[FrozenSet[str]] = (
            None if allowed is None else frozenset(allowed))

    @classmethod
    def from_handle(cls, handle, member: Optional[str] = None) -> "TenantScope":
        """Scope = the handle's ancestor closure.  ``member`` prefixes every
        dataset with the catalog name the handle is registered under, so the
        scope matches the qualified refs a federated backend's plans carry
        (bare refs are also kept, covering single-index backends)."""
        refs = set(handle.datasets)
        if member:
            refs |= {f"{member}/{ds}" for ds in set(refs)}
        return cls(refs)

    def check(self, plan) -> None:
        """Raise :class:`CapabilityError` when any ref of ``plan`` leaves
        the scope.  Mirrors ``BoundaryHandle._check_plan`` but over the
        tier's (possibly qualified) ref strings."""
        if self.allowed is None:
            return
        for ref in plan.refs():
            if ref not in self.allowed:
                raise CapabilityError(
                    f"ref {ref!r} is outside this tenant's capability scope "
                    f"({len(self.allowed)} granted refs); the serving tier "
                    "rejected the plan at admission"
                )

    def __repr__(self) -> str:
        n = "unrestricted" if self.allowed is None else f"{len(self.allowed)} refs"
        return f"TenantScope({n})"


@dataclasses.dataclass
class TenantState:
    """Per-tenant admission bookkeeping (all mutated on the tier's loop)."""

    name: str
    scope: TenantScope
    max_inflight: Optional[int]     # None = only the global bound applies
    inflight: int = 0
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    denied: int = 0                 # capability denials (CapabilityError)

    def snapshot(self) -> Dict[str, object]:
        return {
            "inflight": self.inflight,
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "denied": self.denied,
            "scope": repr(self.scope),
        }


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------
class AdmissionController:
    """Bounded admission over the tier's request stream.

    ``admit`` runs the full gate (closed latch → capability → global bound
    → tenant cap) and on success charges both counters; every admitted
    request MUST eventually be returned through ``release`` exactly once
    (the tier does this when the request's future settles, success or
    failure).
    """

    def __init__(self, max_queue: int,
                 max_inflight_per_tenant: Optional[int] = None,
                 allow_unregistered: bool = True) -> None:
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = int(max_queue)
        self.default_max_inflight = max_inflight_per_tenant
        self.allow_unregistered = allow_unregistered
        self.pending = 0            # admitted, not yet released
        self.closed = False
        self.tenants: Dict[str, TenantState] = {}
        self.counters: Dict[str, int] = {
            "admitted": 0,
            "rejected_queue_full": 0,
            "rejected_tenant_cap": 0,
            "rejected_closed": 0,
            "capability_denied": 0,
        }

    # -- registration --------------------------------------------------------
    def register(self, name: str, scope=None,
                 max_inflight: Optional[int] = None) -> TenantState:
        """Register (or re-scope) a tenant.  ``scope`` is a
        :class:`TenantScope`, a ``BoundaryHandle`` (converted — the handle
        itself is not retained), an iterable of allowed refs, or ``None``
        for unrestricted."""
        if isinstance(scope, TenantScope):
            ts = scope
        elif scope is None:
            ts = TenantScope(None)
        elif hasattr(scope, "datasets") and getattr(scope, "is_handle", False):
            ts = TenantScope.from_handle(scope)
        else:
            ts = TenantScope(scope)
        cap = max_inflight if max_inflight is not None \
            else self.default_max_inflight
        state = self.tenants.get(name)
        if state is None:
            state = TenantState(name, ts, cap)
            self.tenants[name] = state
        else:
            state.scope, state.max_inflight = ts, cap
        return state

    def _resolve(self, tenant: str) -> TenantState:
        state = self.tenants.get(tenant)
        if state is None:
            if not self.allow_unregistered:
                raise CapabilityError(
                    f"unknown tenant {tenant!r}: this tier only serves "
                    "registered tenants"
                )
            state = self.register(tenant)
        return state

    # -- the gate ------------------------------------------------------------
    def has_capacity(self, tenant: str) -> bool:
        """Whether ``admit`` would succeed right now on capacity grounds
        (the backpressure wait predicate; capability is not consulted)."""
        if self.closed or self.pending >= self.max_queue:
            return False
        state = self.tenants.get(tenant)
        return (state is None or state.max_inflight is None
                or state.inflight < state.max_inflight)

    def admit(self, tenant: str, plan) -> TenantState:
        if self.closed:
            self.counters["rejected_closed"] += 1
            raise TierClosedError(
                "the serving tier is shut down; no new requests are admitted")
        state = self._resolve(tenant)
        try:
            state.scope.check(plan)
        except CapabilityError:
            state.denied += 1
            self.counters["capability_denied"] += 1
            raise
        if self.pending >= self.max_queue:
            state.rejected += 1
            self.counters["rejected_queue_full"] += 1
            raise QueueFullError(
                f"admission queue full ({self.pending}/{self.max_queue} "
                "in flight); retry later or submit with wait=True")
        if state.max_inflight is not None and state.inflight >= state.max_inflight:
            state.rejected += 1
            self.counters["rejected_tenant_cap"] += 1
            raise TenantOverloadError(
                f"tenant {tenant!r} at its in-flight cap "
                f"({state.inflight}/{state.max_inflight})")
        self.pending += 1
        state.inflight += 1
        state.submitted += 1
        self.counters["admitted"] += 1
        return state

    def release(self, tenant: str) -> None:
        """One admitted request settled (result OR failure)."""
        self.pending -= 1
        state = self.tenants.get(tenant)
        if state is not None:
            state.inflight -= 1
            state.completed += 1

    # -- introspection -------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "pending": self.pending,
            "max_queue": self.max_queue,
            "closed": self.closed,
            **{k: v for k, v in self.counters.items()},
            "tenants": {n: s.snapshot() for n, s in self.tenants.items()},
        }
