"""Serving: the batched generation engine and the async query tier.

* :class:`~repro.serve.engine.ServeEngine` — prefill/decode generation with
  request-level provenance capture (one engine, one model);
* :class:`~repro.serve.tier.ServingTier` — the async micro-batching front
  door that fuses lineage queries across requests and tenants into single
  ``run_many`` passes, with bounded admission and per-tenant capability
  scoping (:mod:`repro.serve.admission`).
"""
from repro.serve.admission import (
    AdmissionError,
    QueueFullError,
    TenantOverloadError,
    TenantScope,
    TierClosedError,
)
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.tier import ServingTier

__all__ = [
    "ServeEngine",
    "GenerationResult",
    "ServingTier",
    "TenantScope",
    "AdmissionError",
    "QueueFullError",
    "TenantOverloadError",
    "TierClosedError",
]
