"""Chapman-et-al.-style BASELINE: eager cell-level why-provenance.

Re-implementation of the comparison system of the paper's §V (Chapman et al.,
TODS 2024) *in our own substrate*, so Table IX / Fig 3 / Table XI numbers
isolate the representation difference rather than the host language:

* the tracked frame is captured BOTH before and after each manipulation
  (both copies retained in memory — the paper calls out exactly this cost);
* provenance is derived by comparing the two versions and materialized
  EAGERLY per CELL: one explicit (out_row, out_col, in_row, in_col, op)
  record per derived attribute value;
* the join reconstructs row matches observationally by hashing record keys
  (the paper's description of the observation-based approach), not by
  instrumented row-ids.

This is intentionally the memory-greedy design TensProv improves on; it is
correct, and the query answers must AGREE with TensProv's (tests assert so).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.opcat import CaptureInfo, OpCategory
from repro.dataprep.table import Table

__all__ = ["CellProv", "ChapmanIndex"]


@dataclasses.dataclass
class CellProv:
    """Eager cell-level provenance of ONE operation: int64 quintuple rows
    (out_row, out_col, in_slot, in_row, in_col)."""

    op_name: str
    records: np.ndarray  # (n, 5) int64

    def nbytes(self) -> int:
        return int(self.records.nbytes)


class ChapmanIndex:
    """Cell-level eager provenance store with before/after frame retention."""

    def __init__(self) -> None:
        self.cells: List[CellProv] = []
        self.frames: Dict[str, Table] = {}     # EVERY version retained
        self.op_io: List[Tuple[List[str], str]] = []

    # -- capture (observation-based: diff the frames) --------------------------
    def capture(
        self,
        input_ids: Sequence[str],
        inputs: Sequence[Table],
        output_id: str,
        output: Table,
        info: CaptureInfo,
    ) -> None:
        # Retain both versions (the design cost the paper measures).
        for d, t in zip(input_ids, inputs):
            self.frames.setdefault(d, t.copy())
        self.frames[output_id] = output.copy()
        self.op_io.append((list(input_ids), output_id))

        rows = self._derive_rows(inputs, output, info)
        # cell-level expansion through the schema correspondence (pairs are
        # per-slot, computed once; the row loop is the eager per-cell cost)
        pair_cache = {slot: np.asarray(self._attr_pairs(inputs[slot], output, info, slot),
                                       dtype=np.int64).reshape(-1, 2)
                      for slot in range(len(inputs))}
        chunks = []
        for slot, (orow, irow) in rows:
            pairs = pair_cache[slot]
            chunk = np.empty((len(pairs), 5), dtype=np.int64)
            chunk[:, 0] = orow
            chunk[:, 1] = pairs[:, 0]
            chunk[:, 2] = slot
            chunk[:, 3] = irow
            chunk[:, 4] = pairs[:, 1]
            chunks.append(chunk)
        arr = np.concatenate(chunks, axis=0) if chunks else np.zeros((0, 5), np.int64)
        self.cells.append(CellProv(op_name=info.op_name, records=arr))

    # -- row matching by content hashing (observation-based) -------------------
    @staticmethod
    def _hash_rows(t: Table, cols: Optional[Sequence[int]] = None) -> np.ndarray:
        data = t.data if cols is None else t.data[:, list(cols)]
        null = t.null if cols is None else t.null[:, list(cols)]
        clean = np.where(null, np.float32(np.nan), data).copy()
        view = np.ascontiguousarray(clean).view(np.uint32).reshape(len(clean), -1)
        h = np.zeros(len(clean), dtype=np.uint64)
        for j in range(view.shape[1]):
            h = h * np.uint64(1099511628211) + view[:, j].astype(np.uint64)
        return h

    def _derive_rows(
        self, inputs: Sequence[Table], output: Table, info: CaptureInfo
    ) -> List[Tuple[int, Tuple[int, int]]]:
        """(slot, (out_row, in_row)) links derived by frame comparison."""
        cat = info.category
        links: List[Tuple[int, Tuple[int, int]]] = []
        if cat in (OpCategory.TRANSFORM, OpCategory.VREDUCE, OpCategory.VAUGMENT):
            for i in range(output.n_rows):
                links.append((0, (i, i)))
            return links
        if cat is OpCategory.HREDUCE:
            # observational: match preserved indices by scanning (what a
            # frame-diffing system does; O(n^2) avoided via index hash map)
            pos = {int(v): k for k, v in enumerate(inputs[0].index)}
            for i in range(output.n_rows):
                links.append((0, (i, pos[int(output.index[i])])))
            return links
        if cat is OpCategory.HAUGMENT:
            pos = {int(v): k for k, v in enumerate(inputs[0].index)}
            for i in range(output.n_rows):
                src = pos.get(int(output.index[i]))
                if src is None and info.src_rows is not None:
                    s = int(info.src_rows[i])
                    src = s if s >= 0 else None
                if src is not None:
                    links.append((0, (i, src)))
            return links
        if cat is OpCategory.JOIN:
            # hash-match each output row's left/right projections
            left, right = inputs
            on_out = 0  # join key is column 0 of the output by construction
            lcols_out = list(range(0, 1 + (left.n_cols - 1)))
            rcols_out = [0] + list(range(1 + (left.n_cols - 1), output.n_cols))
            lh = self._hash_rows(left)
            rh = self._hash_rows(right)
            loh = self._hash_rows(output, lcols_out)
            roh = self._hash_rows(output, rcols_out)
            lmap: Dict[int, List[int]] = {}
            for k, v in enumerate(lh):
                lmap.setdefault(int(v), []).append(k)
            rmap: Dict[int, List[int]] = {}
            for k, v in enumerate(rh):
                rmap.setdefault(int(v), []).append(k)
            for i in range(output.n_rows):
                for j in lmap.get(int(loh[i]), []):
                    links.append((0, (i, j)))
                for j in rmap.get(int(roh[i]), []):
                    links.append((1, (i, j)))
            # fall back to captured pairs for rows whose hash had no match
            if info.join_pairs is not None:
                seen = {(s, o) for s, (o, _) in links}
                for i, (l, r) in enumerate(info.join_pairs):
                    if l >= 0 and (0, i) not in seen:
                        links.append((0, (i, int(l))))
                    if r >= 0 and (1, i) not in seen:
                        links.append((1, (i, int(r))))
            return links
        if cat is OpCategory.APPEND:
            n_l = info.n_in[0]
            for i in range(output.n_rows):
                if i < n_l:
                    links.append((0, (i, i)))
                else:
                    links.append((1, (i, i - n_l)))
            return links
        raise ValueError(cat)

    @staticmethod
    def _attr_pairs(
        inp: Table, out: Table, info: CaptureInfo, slot: int
    ) -> List[Tuple[int, int]]:
        """(out_col, in_col) correspondences for one input slot."""
        amap = info.attr_maps[slot]
        if amap.kind == "identity":
            n = min(inp.n_cols, out.n_cols)
            return [(j, j) for j in range(n)]
        if amap.perm is not None:
            return [(j, int(a)) for j, a in enumerate(amap.perm) if a >= 0]
        if amap.kind == "vreduce":
            kept = amap.bitset.indices()
            return [(j, int(a)) for j, a in enumerate(kept)]
        if amap.kind == "vaugment":
            m = amap.m
            pairs = [(j, j) for j in range(m)]
            srcs = [int(a) for a in amap.bitset.indices() if a < m]
            for j in range(m, out.n_cols):
                pairs.extend((j, a) for a in srcs)
            return pairs
        if amap.kind == "join":
            bits = amap.bitset
            pairs = []
            for j in range(out.n_cols):
                if bits.test(j):
                    pairs.append((j, bits.rank(j) - 1))
            return pairs
        raise ValueError(amap.kind)

    # -- accounting (what Table IX/XI measure for the baseline) ----------------
    def prov_nbytes(self) -> int:
        return sum(c.nbytes() for c in self.cells)

    def frames_nbytes(self) -> int:
        return sum(t.nbytes() for t in self.frames.values())

    def total_nbytes(self) -> int:
        return self.prov_nbytes() + self.frames_nbytes()

    # -- queries over the eager cell store (O(T) scans — the paper's point) ----
    def backward_rows(self, op_seq: Sequence[int], out_rows: Sequence[int]) -> np.ndarray:
        """Backward record lineage through a chain of op ids (scan-based)."""
        cur = set(int(r) for r in out_rows)
        for oi in reversed(list(op_seq)):
            recs = self.cells[oi].records
            nxt = set()
            for r in recs:  # the O(T) scan TensProv's CSR avoids
                if int(r[0]) in cur:
                    nxt.add(int(r[3]))
            cur = nxt
        return np.asarray(sorted(cur), dtype=np.int64)

    def forward_rows(self, op_seq: Sequence[int], in_rows: Sequence[int]) -> np.ndarray:
        cur = set(int(r) for r in in_rows)
        for oi in op_seq:
            recs = self.cells[oi].records
            nxt = set()
            for r in recs:
                if int(r[3]) in cur:
                    nxt.add(int(r[0]))
            cur = nxt
        return np.asarray(sorted(cur), dtype=np.int64)
