"""Prospective schema metadata: bitsets + attribute-mapping functions.

Implements the paper's Table VI annotations and the forward/backward
attribute maps of Section IV ("Processing Attribute-Based Provenance
Queries").  A bitset costs one machine word per 32 attributes — this is the
paper's key trick for attribute-value provenance without per-cell tracking.

Host (numpy) versions here; the batched rank/select used on-device lives in
``repro.kernels`` (``bitset_rank``) and is validated against these.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = [
    "Bitset",
    "map_vr_f",
    "map_vr_b",
    "map_va_f",
    "map_va_b",
    "map_join_f",
    "map_join_b",
    "perm_forward",
    "perm_backward",
    "rank_positions",
]


@dataclasses.dataclass(frozen=True)
class Bitset:
    """Packed little-endian bitset over attribute positions [0, n)."""

    n: int
    words: np.ndarray  # uint32 (ceil(n/32),)

    @staticmethod
    def from_bits(bits) -> "Bitset":
        bits = np.asarray(bits, dtype=bool)
        n = len(bits)
        nw = max((n + 31) // 32, 1)
        padded = np.zeros(nw * 32, dtype=bool)
        padded[:n] = bits
        shifts = np.arange(32, dtype=np.uint32)
        words = (padded.reshape(nw, 32).astype(np.uint32) << shifts[None, :]).sum(
            axis=-1, dtype=np.uint32
        )
        return Bitset(n=n, words=words)

    @staticmethod
    def from_indices(indices, n: int) -> "Bitset":
        bits = np.zeros(n, dtype=bool)
        bits[np.asarray(list(indices), dtype=np.int64)] = True
        return Bitset.from_bits(bits)

    @staticmethod
    def from_string(s: str) -> "Bitset":
        """Paper notation, e.g. '10011' = attrs 0, 3, 4 set."""
        return Bitset.from_bits([c == "1" for c in s])

    def to_bits(self) -> np.ndarray:
        shifts = np.arange(32, dtype=np.uint32)
        bits = (self.words[:, None] >> shifts[None, :]) & np.uint32(1)
        return bits.reshape(-1)[: self.n].astype(bool)

    def test(self, i: int) -> bool:
        return bool((self.words[i // 32] >> np.uint32(i % 32)) & np.uint32(1))

    def rank(self, i: int) -> int:
        """Number of set bits in positions [0, i] (inclusive) — paper's
        ``sum_{k<=i} b_k``."""
        if i < 0:
            return 0
        i = min(i, self.n - 1)
        w, b = i // 32, i % 32
        full = int(sum(int(x).bit_count() for x in self.words[:w]))
        mask = np.uint32(0xFFFFFFFF) >> np.uint32(31 - b)
        return full + int(self.words[w] & mask).bit_count()

    def select(self, r: int) -> Optional[int]:
        """Position of the r-th (1-based) set bit, or None."""
        if r <= 0:
            return None
        bits = self.to_bits()
        idx = np.flatnonzero(bits)
        return int(idx[r - 1]) if r <= len(idx) else None

    def popcount(self) -> int:
        return int(sum(int(x).bit_count() for x in self.words))

    def indices(self) -> np.ndarray:
        return np.flatnonzero(self.to_bits())

    def __str__(self) -> str:  # paper notation
        return "".join("1" if b else "0" for b in self.to_bits())

    def nbytes(self) -> int:
        return int(self.words.nbytes)


# ---------------------------------------------------------------------------
# Attribute maps (paper §IV).  All positions are 0-based here; the paper is
# 1-based — rank() compensates.
# ---------------------------------------------------------------------------
def map_vr_f(b: Bitset, i: int) -> Optional[int]:
    """Vertical reduction, forward: input attr i -> output attr or None."""
    if not b.test(i):
        return None
    return b.rank(i) - 1  # 0-based position among kept attributes


def map_vr_b(b: Bitset, i: int) -> int:
    """Vertical reduction, backward: output attr i -> input attr j with
    rank(j) == i+1 and b_j = 1 (the paper's select)."""
    j = b.select(i + 1)
    if j is None:
        raise IndexError(f"output attribute {i} out of range for bitset {b}")
    return j


def map_va_f(m: int, i: int) -> int:
    """Vertical augmentation, forward: identity (all input attrs preserved)."""
    if i >= m:
        raise IndexError(f"input attribute {i} >= m={m}")
    return i


def map_va_b(b: Bitset, m: int, i: int) -> List[int]:
    """Vertical augmentation, backward: output attr i -> source input attrs.
    i < m: same position.  i >= m: the set-bit positions of b within [0, m)
    (the input attrs used to engineer the new features)."""
    if i < m:
        return [i]
    return [int(j) for j in b.indices() if j < m]


def map_join_f(b: Bitset, i: int) -> Optional[int]:
    """Join, forward: input attr i (0-based within this input dataset) ->
    output attr position j with rank(j) == i+1, b_j = 1."""
    return b.select(i + 1)


def map_join_b(b: Bitset, i: int) -> Optional[int]:
    """Join, backward: output attr i -> attr position within this input
    dataset, or None if attr i does not originate from it."""
    if i >= b.n or not b.test(i):
        return None
    return b.rank(i) - 1


def rank_positions(b: Bitset) -> np.ndarray:
    """Vectorized rank map: int32 (n,) with entry ``rank(i) - 1`` where bit i
    is set and ``-1`` elsewhere.

    This single array realizes BOTH of the paper's rank-based maps at once:
    for a vreduce bitset (over input attrs) it is ``map_vr_f`` applied to every
    input position; for a join bitset (over output attrs) it is ``map_join_b``
    applied to every output position.
    """
    bits = b.to_bits()
    return np.where(bits, np.cumsum(bits) - 1, -1).astype(np.int32)


# ---------------------------------------------------------------------------
# Order-changing vertical reduction (paper: "a list of integers can be used
# instead of a bitset") — a permutation list [4,2,5] style annotation.
# ---------------------------------------------------------------------------
def perm_forward(perm: np.ndarray, i: int) -> Optional[int]:
    """perm[j] = input attr that landed at output position j."""
    hits = np.flatnonzero(np.asarray(perm) == i)
    return int(hits[0]) if len(hits) else None


def perm_backward(perm: np.ndarray, j: int) -> int:
    return int(np.asarray(perm)[j])
