"""Einstein-summation composition of provenance chains (paper §IV).

For whole-dataset lineage (the paper's fairness/consent audits), chaining
``slice → project`` per record is wasteful; the paper instead contracts the
tensors of consecutive operations:

    T_1 ⊗ T_2 ⊗ ... ⊗ T_n   (contracting out_dim(T_j) = in_dim(T_{j+1}))

Over binary relations this is the (OR, AND) boolean semiring.  We realize it
as bit-packed boolean matmul — the :mod:`repro.kernels.bitmatmul` Pallas
kernel on TPU, its jnp oracle elsewhere — giving one (|D_src| × |D_dst|)
relation bitplane for the whole dataflow path.

Two chain orders are supported and chosen by a flop model:

* forward  (src→dst):  R = R_1 · R_2 · ... · R_n, accumulating left-to-right;
* backward (dst→src):  transposed accumulation right-to-left.

The associativity freedom matters: intermediate relation widths vary by orders
of magnitude (a filter shrinks, a join blows up).  ``plan_chain`` does the
classic matrix-chain dynamic program on the (rows, cols/32-word) dims.
"""
from __future__ import annotations

import sys
import warnings
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline import OpRecord, ProvenanceIndex
from repro.core.provtensor import ProvTensor, pack_bitplane, unpack_bitplane

try:  # host-side sparse composition backend (the hop-cache default off-TPU)
    import scipy.sparse as _sp
except ImportError:  # pragma: no cover - environment-dependent
    _sp = None

__all__ = [
    "path_tensors",
    "op_bitplane",
    "op_csr",
    "op_gather",
    "resolve_use_pallas",
    "compose_pair",
    "compose_pair_csr",
    "compose_gather",
    "chain_gather",
    "extend_tail",
    "extend_tail_csr",
    "extend_tail_bitplane",
    "compose_chain",
    "plan_chain",
    "dataset_lineage",
    "HAVE_SCIPY",
]

HAVE_SCIPY = _sp is not None


def resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """Resolve the tri-state kernel flag without forcing a jax import.

    ``None`` — the default everywhere above the kernel layer — means
    "Pallas iff this process already runs on TPU": hosts resolve ``False``
    without ever importing jax, so numpy-only paths stay jax-free.
    Explicit ``True`` off-TPU still works (interpret-mode emulation, the
    parity-test path) but is deprecated as a routing choice — emulation is
    never the faster backend — and warns.
    """
    if use_pallas is None:
        if "jax" not in sys.modules:
            return False
        from repro.kernels import ops as K

        return K.on_tpu()
    if use_pallas:
        # the caller wants Pallas kernels, so importing jax costs nothing new
        from repro.kernels import ops as K

        if not K.on_tpu():
            warnings.warn(
                "use_pallas=True off-TPU runs kernels in interpret mode; "
                "pass use_pallas=None to let the kernel-launch guard pick "
                "the backend (Pallas on TPU, the jnp oracle elsewhere)",
                DeprecationWarning,
                stacklevel=3,
            )
    return bool(use_pallas)


def path_tensors(index: ProvenanceIndex, src: str, dst: str) -> List[Tuple[OpRecord, int]]:
    """The op chain linking ``src`` to ``dst``: [(op, input_slot), ...].

    Follows the (unique-producer) dataflow backward from ``dst`` and keeps the
    ops on a path that reaches ``src``.  For multi-input ops the slot records
    WHICH input lies on the path.

    The reachable-from-``src`` set is computed ONCE up front (one pass over
    the op list) instead of re-running ``index.path_exists`` per visited op —
    the old per-hop rescans made this O(depth²) in pipeline length.
    """
    reach = {src}
    for op in index.ops:
        if any(d in reach for d in op.input_ids):
            reach.add(op.output_id)
    chain: List[Tuple[OpRecord, int]] = []
    cur = dst
    while cur != src:
        if cur not in index.producer:
            raise KeyError(f"no dataflow path {src} -> {dst} (stuck at {cur})")
        op = index.ops[index.producer[cur]]
        slot = None
        for k, in_id in enumerate(op.input_ids):
            if in_id in reach:
                slot = k
                break
        if slot is None:
            raise KeyError(f"no dataflow path {src} -> {dst} (op {op.info.op_name})")
        chain.append((op, slot))
        cur = op.input_ids[slot]
    return list(reversed(chain))


def op_bitplane(t: ProvTensor, slot: int) -> np.ndarray:
    """R[i, o] forward bitplane of one op tensor for one input slot
    (memoized on the tensor — the hop-cache recomposes from these)."""
    return t.bitplane_fwd(slot)


_relation_bitplane = op_bitplane  # backward-compat alias


def op_csr(t: ProvTensor, slot: int):
    """The same forward relation as scipy CSR — zero-copy view over the
    tensor's bidirectional index (shares row_ptr/col_idx).

    float32 values keep the boolean semiring exact under composition: path
    counts are sums of positives, so ``> 0`` never misclassifies (an integer
    dtype could overflow and wrap a count to zero).
    """
    if _sp is None:
        raise ImportError("scipy is required for the CSR composition backend")
    c = t.fwd(slot)
    data = np.ones(c.nnz, dtype=np.float32)
    return _sp.csr_matrix((data, c.col_idx, c.row_ptr), shape=(c.n_rows, c.n_cols))


def compose_pair_csr(a, b):
    """(OR,AND)-compose two CSR relations: sparse matmul, then clamp the
    path counts back to the binary relation."""
    c = (a @ b).tocsr()
    c.data = np.ones_like(c.data)
    return c


def op_gather(t: ProvTensor, slot: int) -> Optional[np.ndarray]:
    """The op relation's implicit destination→source gather (int32
    ``(n_out,)``, -1 = no link) when the slot is structured, else None."""
    return t.slot_gather(slot)


def compose_gather(g_pre: np.ndarray, g_step: np.ndarray) -> np.ndarray:
    """Closed-form ``prefix ∘ step`` over gather relations: ONE ``np.take``.

    ``g_pre`` maps mid→src, ``g_step`` maps dst→mid; the composition maps
    dst→src, propagating the -1 "no link" sentinel through both hops.
    Gather∘gather stays a gather, so a whole identity/selection chain folds
    without ever leaving the implicit representation.
    """
    valid = g_step >= 0
    return np.where(valid, g_pre[np.where(valid, g_step, 0)], np.int32(-1))


def chain_gather(chain: Sequence[Tuple[object, int]]) -> Optional[np.ndarray]:
    """Fold a whole op chain of structured slots into one dst→src gather;
    None when any hop lacks structure (a multi-parent raw-COO relation).
    Identity hops are eliminated outright (no take at all)."""
    from repro.core.provtensor import SlotIdentity  # local: avoid wide import

    acc: Optional[np.ndarray] = None  # None = identity so far
    for op, slot in chain:
        s = op.tensor.slot_structure(slot)
        if s is None:
            return None
        if isinstance(s, SlotIdentity):
            continue
        g = op.tensor.slot_gather(slot)
        acc = g if acc is None else compose_gather(acc, g)
    if acc is None and chain:
        acc = np.arange(chain[-1][0].tensor.n_out, dtype=np.int32)
    return acc


def extend_tail_csr(rel, g: np.ndarray):
    """Closed-form ``prefix ∘ gather-step`` for a CSR prefix: a column
    gather, NOT a sparse matmul.

    ``rel`` is the composed (n_src × n_mid) forward relation; ``g`` maps
    dst→mid (int32 (n_dst,), -1 = no link).  Since every dst column of the
    result is exactly one mid column of the prefix (or empty), the extension
    is ``out[:, d] = rel[:, g[d]]`` — one ragged gather over the prefix's
    CSC columns, O(nnz_out), no flops.  This is what makes appending a
    structured op to a DENSE warm relation cheap: the whole-chain recompose
    it replaces pays a full spmm per hop.
    """
    if _sp is None:
        raise ImportError("scipy is required for the CSR composition backend")
    g = np.asarray(g, dtype=np.int64).reshape(-1)
    csc = rel.tocsc()
    n_src = rel.shape[0]
    n_dst = len(g)
    valid = g >= 0
    cols = g[valid]
    starts = csc.indptr[cols].astype(np.int64)
    degs = (csc.indptr[cols + 1] - csc.indptr[cols]).astype(np.int64)
    total = int(degs.sum())
    indptr = np.zeros(n_dst + 1, dtype=np.int64)
    indptr[1:][valid] = degs
    np.cumsum(indptr, out=indptr)
    if total:
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])),
                         degs) + np.arange(total)
        indices = csc.indices[flat]
        data = csc.data[flat].copy()
    else:
        indices = np.zeros(0, dtype=csc.indices.dtype)
        data = np.zeros(0, dtype=csc.data.dtype)
    out = _sp.csc_matrix((data, indices, indptr), shape=(n_src, n_dst))
    return out.tocsr()


def extend_tail_bitplane(plane: np.ndarray, g: np.ndarray,
                         n_mid: int) -> np.ndarray:
    """Closed-form ``prefix ∘ gather-step`` for a packed-bitplane prefix:
    a column take through the dst→mid gather, blocked so the transient
    dense unpack stays ~4 MB regardless of relation size."""
    g = np.asarray(g, dtype=np.int64).reshape(-1)
    n_src = plane.shape[0]
    n_dst = len(g)
    valid = g >= 0
    safe = np.where(valid, g, 0)
    out = np.empty((n_src, (n_dst + 31) // 32), dtype=np.uint32)
    block = max(1, (4 << 20) // max(n_mid + n_dst, 1))
    for lo in range(0, max(n_src, 1), block):
        hi = min(lo + block, n_src)
        if hi <= lo:
            break
        dense = unpack_bitplane(plane[lo:hi], n_mid)
        out[lo:hi] = pack_bitplane(dense[:, safe] & valid[None, :])
    return out


def extend_tail(rel, g: np.ndarray, backend: str,
                n_mid: Optional[int] = None):
    """Dispatch the closed-form one-step extension by prefix backend
    (``"csr"`` | ``"bitplane"``); structured prefixes use
    :func:`compose_gather` directly and never come through here."""
    if backend == "csr":
        return extend_tail_csr(rel, g)
    if backend == "bitplane":
        if n_mid is None:
            raise ValueError("bitplane extension needs n_mid")
        return extend_tail_bitplane(rel, g, n_mid)
    raise ValueError(f"unknown backend {backend!r}")


def compose_pair(a_bits: np.ndarray, b_bits: np.ndarray, n_mid: int,
                 use_pallas: Optional[bool] = None) -> np.ndarray:
    """(OR,AND)-compose packed relations A (R×mid) · B (mid×C) -> (R×C) packed.

    ``a_bits`` packs its columns (mid dim); ``b_bits`` is (mid, C/32).
    ``use_pallas=None`` lets :func:`repro.kernels.ops.bitmatmul` apply its
    kernel-launch guard (Pallas on TPU, jnp oracle elsewhere).
    """
    from repro.kernels import ops as K  # late import: keeps numpy-only paths jax-free

    return np.asarray(K.bitmatmul(a_bits, b_bits, use_pallas=use_pallas))


def plan_chain(dims: Sequence[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """DIMS-ONLY matrix-chain-order DP (legacy).  Kept for callers that only
    know shapes; :func:`compose_chain` now plans with the nnz-aware DP in
    :mod:`repro.core.costmodel` (``plan_chain_stats``), which costs merges by
    sparse-matmul work instead of dense dims.

    Input is relation shapes [(r0,c0),(r1,c1)..] where c_j == r_{j+1}.
    Returns the multiplication order as (i, j) merges over a working list —
    standard O(n^3) DP, n is tiny (pipeline length)."""
    n = len(dims)
    if n <= 1:
        return []
    p = [dims[0][0]] + [d[1] for d in dims]  # dimension vector
    INF = float("inf")
    cost = [[0.0] * n for _ in range(n)]
    split = [[0] * n for _ in range(n)]
    for length in range(2, n + 1):
        for i in range(0, n - length + 1):
            j = i + length - 1
            cost[i][j] = INF
            for k in range(i, j):
                c = cost[i][k] + cost[k + 1][j] + p[i] * p[k + 1] * p[j + 1]
                if c < cost[i][j]:
                    cost[i][j] = c
                    split[i][j] = k
    order: List[Tuple[int, int]] = []

    def emit(i: int, j: int) -> None:
        if i == j:
            return
        k = split[i][j]
        emit(i, k)
        emit(k + 1, j)
        order.append((i, k))  # merge [i..k] with [k+1..j]

    emit(0, n - 1)
    return order


def compose_chain(
    index: ProvenanceIndex,
    src: str,
    dst: str,
    use_pallas: Optional[bool] = None,
    optimize: bool = True,
) -> np.ndarray:
    """Packed (|src| × |dst|/32) relation bitplane for the whole path.

    ``use_pallas=None`` (default) applies the kernel-launch guard — see
    :func:`resolve_use_pallas`.  ``optimize=True`` applies the matrix-chain
    DP (associativity); otherwise left-to-right accumulation (the paper's
    literal chain)."""
    use_pallas = resolve_use_pallas(use_pallas)
    chain = path_tensors(index, src, dst)
    if not chain:
        n = index.datasets[src].n_rows
        return pack_bitplane(np.eye(n, dtype=bool))
    g = chain_gather(chain)
    if g is not None:
        # the whole path is structured: fold the gathers closed-form (one
        # take per non-identity hop) and expand to the packed plane once
        n_src = index.datasets[src].n_rows
        dense = np.zeros((n_src, len(g)), dtype=bool)
        dst_rows = np.flatnonzero(g >= 0)
        dense[g[dst_rows], dst_rows] = True
        return pack_bitplane(dense)
    planes = [_relation_bitplane(op.tensor, slot) for op, slot in chain]
    rowdims = [op.tensor.n_in[slot] for op, slot in chain]
    coldims = [op.tensor.n_out for op, _ in chain]

    if not optimize or len(planes) == 1:
        acc = planes[0]
        for j in range(1, len(planes)):
            acc = compose_pair(acc, planes[j], rowdims[j], use_pallas=use_pallas)
        return acc

    # Stats-propagating matrix-chain DP from the cost model, priced in THIS
    # executor's backend: the merges below run compose_pair (packed
    # bitplane), whose word-op cost scales with dims, so bitplane pricing —
    # which provably reduces to the classic dims DP — is the correct model
    # here.  The nnz-scaled spmm pricing binds where CSR composition
    # actually runs: CostModel.composed_estimate / the auto hop-cache.
    from repro.core.costmodel import RelStats, plan_chain_stats

    stats = [RelStats.from_slot(op.tensor, slot) for op, slot in chain]
    order = plan_chain_stats(stats, backend="bitplane")
    # working list of (plane, n_rows, n_cols)
    work: List[Optional[Tuple[np.ndarray, int, int]]] = [
        (planes[i], rowdims[i], coldims[i]) for i in range(len(planes))
    ]

    for (i, _k) in order:
        # merge segment starting at i with the next live segment to its right
        j = i + 1
        while work[j] is None:
            j += 1
        a, ra, ca = work[i]
        b, rb, cb = work[j]
        merged = compose_pair(a, b, ca, use_pallas=use_pallas)
        work[i] = (merged, ra, cb)
        work[j] = None
    final = next(w for w in work if w is not None)
    return final[0]


def dataset_lineage(
    index: ProvenanceIndex, src: str, dst: str, use_pallas: Optional[bool] = None
) -> np.ndarray:
    """Dense bool (|src|, |dst|) lineage relation for the whole dataset —
    the paper's einsum use case (fairness / consent audits)."""
    bits = compose_chain(index, src, dst, use_pallas=use_pallas)
    return unpack_bitplane(bits, index.datasets[dst].n_rows)
