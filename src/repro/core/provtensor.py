"""Sparse binary provenance tensors (the paper's Section III).

A :class:`ProvTensor` encodes the why-provenance of ONE data-processing
operation: an order-(k+1) binary tensor ``T(o, i_1..i_k) = 1`` iff output
record ``o`` derives from the tuple of input records ``(i_1..i_k)``.

Two storage regimes, honoring the paper's "minimal memory" capture claim:

* **Structured (implicit) representation** — the default capture output.
  Most Table-I operations have relations with KNOWN structure: a
  transformation / vertical op is the identity ``I_n`` (:class:`SlotIdentity`
  — O(1) bytes, not ``8n``); a horizontal reduction or augmentation maps each
  output to at most one input (:class:`SlotGather` — ONE int32 array, the
  op's own ``kept``/``src`` payload, with ``-1`` sentinels); append's two
  block-diagonal tensors are two offsets (:class:`SlotRange`); a join's two
  slots are gathers over the pair list.  Nothing else is allocated at
  capture time.
* **Explicit COO** — ``(nnz, 1+k)`` int32 tuples ``(out, in_1, .., in_k)``,
  ``-1`` marking "no link".  The fallback for relations with no usable
  structure (multi-parent augmentation links), and a lazily-materialized
  MIRROR of structured tensors for the few consumers that want the raw
  index list (set-semantics canonicalization, parity baselines).

Derived mirrors — bidirectional CSR per input slot (the array-resident
realization of the paper's 3-level rooted DAG, Fig. 1) and packed uint32
relation bitplanes (32 boolean entries per lane word, for the
Einstein-summation composition path) — are built on demand from WHICHEVER
regime the tensor holds and are byte-identical between the two (the
structured parity suite pins this).  Structured slots additionally answer
the mask-propagation hot path directly — a forward probe is one ``take``,
a backward probe one scatter — so filter/gather-heavy query walks never
build a CSR at all.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "CSR",
    "ProvTensor",
    "SlotIdentity",
    "SlotGather",
    "SlotRange",
    "shard_ranges",
    "identity_tensor",
    "hreduce_tensor",
    "haugment_tensor",
    "join_tensor",
    "append_tensor",
    "pack_bitplane",
    "unpack_bitplane",
    "pack_mask",
    "unpack_mask",
    "bitplane_or_reduce",
    "bitplane_popcount",
]


# ---------------------------------------------------------------------------
# CSR half of the bidirectional index
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse rows: ``row_ptr`` (n_rows+1,), ``col_idx`` (nnz,).

    ``neighbors(q)`` = ``col_idx[row_ptr[q] : row_ptr[q + 1]]``.
    """

    n_rows: int
    n_cols: int
    row_ptr: np.ndarray  # int32 (n_rows+1,)
    col_idx: np.ndarray  # int32 (nnz,)

    @staticmethod
    def from_pairs(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int) -> "CSR":
        rows = np.asarray(rows, dtype=np.int32)
        cols = np.asarray(cols, dtype=np.int32)
        keep = (rows >= 0) & (cols >= 0)
        rows, cols = rows[keep], cols[keep]
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        counts = np.bincount(rows, minlength=n_rows).astype(np.int64)
        row_ptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.cumsum(counts, out=row_ptr[1:])
        return CSR(n_rows=n_rows, n_cols=n_cols, row_ptr=row_ptr, col_idx=cols)

    def neighbors(self, q: int) -> np.ndarray:
        return self.col_idx[self.row_ptr[q] : self.row_ptr[q + 1]]

    def batch_neighbors(self, qs: np.ndarray, max_deg: Optional[int] = None) -> np.ndarray:
        """Padded (-1) batched probe: ``(len(qs), max_deg)`` int32."""
        qs = np.asarray(qs, dtype=np.int32)
        starts = self.row_ptr[qs]
        ends = self.row_ptr[qs + 1]
        degs = ends - starts
        if max_deg is None:
            max_deg = int(degs.max()) if len(degs) else 0
        max_deg = max(max_deg, 1)
        out = np.full((len(qs), max_deg), -1, dtype=np.int32)
        for i, (s, e) in enumerate(zip(starts, ends)):  # host path; jit path in kernels
            d = min(e - s, max_deg)
            out[i, :d] = self.col_idx[s : s + d]
        return out

    def gather_rows(self, qs: np.ndarray) -> np.ndarray:
        """Sorted-unique neighbors of a query-row set — one ragged gather,
        no dense (n_cols,) mask allocated (the ``forward_rows`` /
        ``backward_rows`` fast path).  Out-of-range / negative query rows
        are ignored; an empty probe answers an empty int64 array."""
        qs = np.asarray(qs, dtype=np.int64).reshape(-1)
        qs = qs[(qs >= 0) & (qs < self.n_rows)]
        if qs.size == 0:
            return np.zeros(0, dtype=np.int64)
        starts = self.row_ptr[qs]
        degs = self.row_ptr[qs + 1] - starts
        total = int(degs.sum())
        if total == 0:
            return np.zeros(0, dtype=np.int64)
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])), degs) + np.arange(total)
        return np.unique(self.col_idx[flat]).astype(np.int64)

    def neighbor_mask(self, qs: np.ndarray) -> np.ndarray:
        """OR of neighbor indicator rows for a query set -> bool (n_cols,)."""
        mask = np.zeros(self.n_cols, dtype=bool)
        qs = np.asarray(qs, dtype=np.int64)
        qs = qs[(qs >= 0) & (qs < self.n_rows)]
        if qs.size == 0:
            return mask
        # Vectorized ragged gather via repeat/arange.
        starts = self.row_ptr[qs]
        degs = self.row_ptr[qs + 1] - starts
        total = int(degs.sum())
        if total == 0:
            return mask
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])), degs) + np.arange(total)
        mask[self.col_idx[flat]] = True
        return mask

    def neighbor_mask_many(self, masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`neighbor_mask`: bool (B, n_rows) -> bool (B, n_cols).

        One ragged gather covers the whole batch — the probe rows of every
        batch element share a single repeat/arange expansion, so batch size
        adds no Python-level work.
        """
        masks = np.asarray(masks, dtype=bool)
        out = np.zeros((masks.shape[0], self.n_cols), dtype=bool)
        bs, qs = np.nonzero(masks[:, : self.n_rows])
        if qs.size == 0:
            return out
        starts = self.row_ptr[qs]
        degs = self.row_ptr[qs + 1] - starts
        total = int(degs.sum())
        if total == 0:
            return out
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])), degs) + np.arange(total)
        out[np.repeat(bs, degs), self.col_idx[flat]] = True
        return out

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    def nbytes(self) -> int:
        return int(self.row_ptr.nbytes + self.col_idx.nbytes)


# ---------------------------------------------------------------------------
# Bit-packing helpers (uint32 lanes, little-endian within the word)
# ---------------------------------------------------------------------------
def pack_bitplane(dense: np.ndarray) -> np.ndarray:
    """Pack bool (R, C) -> uint32 (R, ceil(C/32)); bit j of word w = col 32w+j."""
    dense = np.asarray(dense, dtype=bool)
    r, c = dense.shape
    cw = (c + 31) // 32
    padded = np.zeros((r, cw * 32), dtype=bool)
    padded[:, :c] = dense
    bits = padded.reshape(r, cw, 32).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits << shifts[None, None, :]).sum(axis=-1, dtype=np.uint32)


def unpack_bitplane(words: np.ndarray, n_cols: int) -> np.ndarray:
    words = np.asarray(words, dtype=np.uint32)
    r, cw = words.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(r, cw * 32)[:, :n_cols].astype(bool)


def pack_mask(mask: np.ndarray) -> np.ndarray:
    """Pack one bool vector (n,) -> uint32 (⌈n/32⌉,)."""
    return pack_bitplane(np.asarray(mask, dtype=bool)[None, :])[0]


def unpack_mask(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_mask`."""
    return unpack_bitplane(np.asarray(words, dtype=np.uint32)[None, :], n)[0]


def bitplane_or_reduce(sel_words: np.ndarray, plane: np.ndarray, n_mid: int) -> np.ndarray:
    """(OR,AND)-contract packed selectors against a packed relation, on host.

    ``sel_words`` is (B, ⌈n_mid/32⌉) — B packed row-selector masks;
    ``plane`` is (n_mid, W) — a packed relation bitplane.  Returns (B, W):
    row b = OR of the plane rows whose selector bit is set.  This is the numpy
    twin of :func:`repro.kernels.ops.bitmatmul` (same contraction), used where
    kernel-launch latency would dominate the tiny host-side masks.

    Per-probe cost is O(selected rows × W) — a buffered
    ``np.bitwise_or.reduce`` over just the selected plane rows.  (A batch-
    vectorized ``np.bitwise_or.at`` scatter was tried and measured 2-8x
    SLOWER: ufunc.at is unbuffered and pays far more per element than the
    buffered reduce; the per-probe temp here also stays bounded at one
    probe's selection, never (B, n_mid, W).)
    """
    sel_words = np.atleast_2d(np.asarray(sel_words, dtype=np.uint32))
    sel = unpack_bitplane(sel_words, n_mid)                   # (B, n_mid) bool
    out = np.zeros((sel.shape[0], plane.shape[1]), dtype=np.uint32)
    for b in range(sel.shape[0]):
        picked = plane[sel[b]]
        if picked.shape[0]:
            out[b] = np.bitwise_or.reduce(picked, axis=0)
    return out


def bitplane_popcount(words: np.ndarray) -> int:
    """Number of set bits in a packed bitplane (the relation's nnz)."""
    return int(np.unpackbits(np.ascontiguousarray(words).view(np.uint8)).sum())


# ---------------------------------------------------------------------------
# Structured (implicit) per-slot relation forms
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SlotIdentity:
    """The relation is ``I_n`` — transformation / vertical ops.  O(1) bytes."""

    n: int

    def n_links(self) -> int:
        return self.n

    def nbytes(self) -> int:
        return 0

    def out_to_in(self, n_out: int) -> np.ndarray:
        return np.arange(n_out, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class SlotGather:
    """Each output derives from AT MOST one input: ``src[o]`` = input row of
    output ``o``, ``-1`` = no link.  Horizontal reduction stores its ``kept``
    list here, horizontal augmentation its ``src`` map, a join one gather per
    side — the op's own capture payload, nothing re-encoded."""

    src: np.ndarray  # int32 (n_out,)

    def n_links(self) -> int:
        return int(np.count_nonzero(self.src >= 0))

    def nbytes(self) -> int:
        return int(self.src.nbytes)

    def out_to_in(self, n_out: int) -> np.ndarray:
        return self.src


@dataclasses.dataclass(frozen=True)
class SlotRange:
    """One identity block: outputs ``[start, start+length)`` map to inputs
    ``[0, length)`` — append's block-diagonal tensors as two offsets."""

    start: int
    length: int

    def n_links(self) -> int:
        return self.length

    def nbytes(self) -> int:
        return 0

    def out_to_in(self, n_out: int) -> np.ndarray:
        g = np.full(n_out, -1, dtype=np.int32)
        g[self.start : self.start + self.length] = np.arange(self.length, dtype=np.int32)
        return g


SlotStructure = Union[SlotIdentity, SlotGather, SlotRange]


def _identity_csr(n: int) -> CSR:
    i = np.arange(n, dtype=np.int32)
    return CSR(n_rows=n, n_cols=n, row_ptr=np.arange(n + 1, dtype=np.int32), col_idx=i)


def _gather_bwd_csr(g: np.ndarray, n_in: int) -> CSR:
    """out→in CSR of a gather: every row has ≤1 entry — a cumsum, no sort.
    Byte-identical to ``CSR.from_pairs(arange, g, ...)``."""
    valid = g >= 0
    row_ptr = np.zeros(len(g) + 1, dtype=np.int32)
    np.cumsum(valid, out=row_ptr[1:])
    return CSR(n_rows=len(g), n_cols=n_in, row_ptr=row_ptr,
               col_idx=g[valid].astype(np.int32))


# ---------------------------------------------------------------------------
# The provenance tensor itself
# ---------------------------------------------------------------------------
class ProvTensor:
    """Order-(k+1) sparse binary tensor for one data-processing operation.

    Construct with EITHER an explicit ``coo`` index list (the legacy
    representation, still first-class) or implicit per-slot ``slots``
    structures (the capture fast path).  All derived views — CSR halves,
    bitplanes, the COO mirror itself — materialize lazily and identically
    from either regime.
    """

    def __init__(
        self,
        n_out: int,
        n_in: tuple,
        coo: Optional[np.ndarray] = None,
        *,
        slots: Optional[Sequence[SlotStructure]] = None,
    ) -> None:
        self.n_out = int(n_out)
        self.n_in = tuple(int(n) for n in n_in)
        if (coo is None) == (slots is None):
            raise ValueError("pass exactly one of coo= or slots=")
        self._slots: Optional[Tuple[SlotStructure, ...]] = None
        self._coo: Optional[np.ndarray] = None
        if slots is not None:
            slots = tuple(slots)
            if len(slots) != len(self.n_in):
                raise ValueError(
                    f"{len(slots)} slot structures inconsistent with "
                    f"k={len(self.n_in)} inputs"
                )
            self._slots = slots
        else:
            coo = np.asarray(coo, dtype=np.int32)
            if coo.ndim != 2 or coo.shape[1] != 1 + len(self.n_in):
                raise ValueError(
                    f"coo shape {coo.shape} inconsistent with k={len(self.n_in)} inputs"
                )
            self._coo = coo
        self._fwd: Optional[list] = None
        self._bwd: Optional[list] = None
        self._bpf: Optional[list] = None
        self._bpb: Optional[list] = None
        self._slot_nnz: Optional[list] = None
        self._sg: Optional[list] = None  # memoized out→in gather per slot

    def __repr__(self) -> str:  # keep the old dataclass-era readability
        tag = "structured" if self.structured else "coo"
        return (f"ProvTensor(n_out={self.n_out}, n_in={self.n_in}, "
                f"nnz={self.nnz}, repr={tag})")

    @property
    def k(self) -> int:
        return len(self.n_in)

    @property
    def structured(self) -> bool:
        """Whether this tensor holds an implicit structured representation
        (the explicit COO, if ever requested, is only a lazy mirror)."""
        return self._slots is not None

    @property
    def nnz(self) -> int:
        """Rows of the (possibly virtual) COO index list — one per output
        record carrying at least a sentinel, exactly the legacy count."""
        if self._slots is not None:
            return self.n_out
        return int(self._coo.shape[0])

    # -- representation access ----------------------------------------------
    def slot_structure(self, inp: int) -> Optional[SlotStructure]:
        """The implicit structure of the input-``inp`` relation, or None when
        the tensor is explicit COO."""
        return self._slots[inp] if self._slots is not None else None

    def slot_gather(self, inp: int) -> Optional[np.ndarray]:
        """int32 (n_out,) output→input map of a STRUCTURED slot (-1 = no
        link), memoized; None for explicit-COO tensors.  Gather slots hand
        back their own payload array — no copy."""
        s = self.slot_structure(inp)
        if s is None:
            return None
        if isinstance(s, SlotGather):
            return s.src
        if self._sg is None:
            self._sg = [None] * self.k
        if self._sg[inp] is None:
            self._sg[inp] = s.out_to_in(self.n_out)
        return self._sg[inp]

    @property
    def coo(self) -> np.ndarray:
        """(nnz, 1+k) int32 explicit index list.  For structured tensors
        this mirror materializes ON FIRST ACCESS (one row per output record,
        matching the legacy constructors byte for byte) and is retained."""
        if self._coo is None:
            cols = [np.arange(self.n_out, dtype=np.int32)]
            cols += [self.slot_gather(i) for i in range(self.k)]
            self._coo = np.stack(cols, axis=1)
        return self._coo

    def as_coo(self) -> "ProvTensor":
        """A forced-COO twin of this tensor (parity baselines / benches)."""
        return ProvTensor(n_out=self.n_out, n_in=self.n_in, coo=self.coo.copy())

    # -- per-slot relation statistics (the cost model reads these) -----------
    def slot_nnz(self, inp: int) -> int:
        """nnz of the input-``inp`` → output relation: links that are real
        (not the -1 sentinel).  Memoized; structured slots answer O(1)/O(n)
        off the implicit form — no COO, CSR, or bitplane is materialized."""
        if self._slot_nnz is None:
            self._slot_nnz = [None] * self.k
        if self._slot_nnz[inp] is None:
            s = self.slot_structure(inp)
            if s is not None:
                self._slot_nnz[inp] = s.n_links()
            else:
                self._slot_nnz[inp] = int(np.count_nonzero(self._coo[:, 1 + inp] >= 0))
        return self._slot_nnz[inp]

    def slot_shape(self, inp: int) -> tuple:
        """(rows, cols) of the input-``inp`` forward relation."""
        return (self.n_in[inp], self.n_out)

    def slot_density(self, inp: int) -> float:
        """nnz / (rows·cols) of the input-``inp`` forward relation."""
        cells = self.n_in[inp] * self.n_out
        return self.slot_nnz(inp) / cells if cells else 0.0

    def slot_nnz_range(self, inp: int, lo: int, hi: int) -> int:
        """nnz of the input-``inp`` relation restricted to output rows
        ``[lo, hi)`` — the shard-local statistic the sharded hop-cache's
        cost model reads.  Structured slots answer without materializing
        the slice (an identity/range block is interval arithmetic, a
        gather one ``count_nonzero`` over the window)."""
        lo = max(int(lo), 0)
        hi = min(int(hi), self.n_out)
        if hi <= lo:
            return 0
        s = self.slot_structure(inp)
        if isinstance(s, SlotIdentity):
            return max(0, min(hi, s.n) - lo)
        if isinstance(s, SlotRange):
            return max(0, min(hi, s.start + s.length) - max(lo, s.start))
        if isinstance(s, SlotGather):
            return int(np.count_nonzero(s.src[lo:hi] >= 0))
        out = self._coo[:, 0]
        inn = self._coo[:, 1 + inp]
        return int(np.count_nonzero((out >= lo) & (out < hi) & (inn >= 0)))

    def slice_rows(self, lo: int, hi: int) -> "ProvTensor":
        """The tensor restricted to output rows ``[lo, hi)``: a ProvTensor
        with ``n_out = hi - lo`` over the SAME (global) input spaces.

        This is the shard-construction primitive: partitioning every op
        tensor by contiguous output-row range yields per-shard tensors whose
        derived CSR/bitplane mirrors are the row slices of the full mirrors,
        so per-shard mask propagation concatenated (forward) or OR-reduced
        (backward) over shards is byte-identical to the merged walk.

        Structured slots stay structured: an identity/range block becomes a
        window gather, a gather slot slices its payload (zero-copy view).
        Explicit COO keeps the rows landing in the window, out-column
        shifted to shard-local coordinates."""
        lo = max(int(lo), 0)
        hi = min(int(hi), self.n_out)
        if hi < lo:
            raise ValueError(f"bad row range [{lo}, {hi})")
        if self._slots is not None:
            sliced = []
            for s in self._slots:
                if isinstance(s, SlotGather):
                    sliced.append(SlotGather(s.src[lo:hi]))
                else:
                    sliced.append(SlotGather(s.out_to_in(self.n_out)[lo:hi]))
            return ProvTensor(n_out=hi - lo, n_in=self.n_in, slots=sliced)
        out = self._coo[:, 0]
        keep = (out >= lo) & (out < hi)
        sub = self._coo[keep].copy()
        sub[:, 0] -= lo
        return ProvTensor(n_out=hi - lo, n_in=self.n_in, coo=sub)

    def _slot_pairs(self, inp: int) -> Tuple[np.ndarray, np.ndarray]:
        """Valid (out, in) link pairs of one slot, from whichever regime."""
        g = self.slot_gather(inp)
        if g is not None:
            out = np.flatnonzero(g >= 0).astype(np.int32)
            return out, g[out]
        return self._coo[:, 0], self._coo[:, 1 + inp]

    # -- the paper's optimized representation (bidirectional CSR) -----------
    def fwd(self, inp: int) -> CSR:
        """input-record -> output-records CSR for input ``inp`` (solid edges)."""
        if self._fwd is None:
            self._fwd = [None] * self.k
        if self._fwd[inp] is None:
            s = self.slot_structure(inp)
            if isinstance(s, SlotIdentity):
                self._fwd[inp] = _identity_csr(s.n)
            else:
                out, inn = self._slot_pairs(inp)
                self._fwd[inp] = CSR.from_pairs(inn, out, self.n_in[inp], self.n_out)
        return self._fwd[inp]

    def bwd(self, inp: int) -> CSR:
        """output-record -> input-records CSR for input ``inp`` (dashed edges)."""
        if self._bwd is None:
            self._bwd = [None] * self.k
        if self._bwd[inp] is None:
            s = self.slot_structure(inp)
            if isinstance(s, SlotIdentity):
                self._bwd[inp] = _identity_csr(s.n)
            elif s is not None:
                self._bwd[inp] = _gather_bwd_csr(self.slot_gather(inp), self.n_in[inp])
            else:
                self._bwd[inp] = CSR.from_pairs(
                    self._coo[:, 0], self._coo[:, 1 + inp], self.n_out, self.n_in[inp]
                )
        return self._bwd[inp]

    # -- paper §IV: slice + project, expressed on masks ---------------------
    # Structured slots answer WITHOUT building a CSR: a forward probe is one
    # take along the gather, a backward probe one scatter through it — the
    # query walkers (repro.core.query) inherit these fast paths per hop.
    def forward_mask(self, inp: int, in_mask: np.ndarray) -> np.ndarray:
        """project(slice(T, p_in, rows), p_out) with rows given as a mask."""
        s = self.slot_structure(inp)
        if s is not None:
            return self._forward_structured(
                s, np.asarray(in_mask, dtype=bool)[None, :], inp)[0]
        rows = np.flatnonzero(np.asarray(in_mask, dtype=bool))
        return self.fwd(inp).neighbor_mask(rows)

    def backward_mask(self, inp: int, out_mask: np.ndarray) -> np.ndarray:
        """project(slice(T, p_out, rows), p_in)."""
        s = self.slot_structure(inp)
        if s is not None:
            return self._backward_structured(
                s, np.asarray(out_mask, dtype=bool)[None, :], inp)[0]
        rows = np.flatnonzero(np.asarray(out_mask, dtype=bool))
        return self.bwd(inp).neighbor_mask(rows)

    def forward_mask_batch(self, inp: int, in_masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`forward_mask`: bool (B, n_in[inp]) -> (B, n_out)."""
        s = self.slot_structure(inp)
        if s is not None:
            return self._forward_structured(
                s, np.asarray(in_masks, dtype=bool), inp)
        return self.fwd(inp).neighbor_mask_many(in_masks)

    def backward_mask_batch(self, inp: int, out_masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`backward_mask`: bool (B, n_out) -> (B, n_in[inp])."""
        s = self.slot_structure(inp)
        if s is not None:
            return self._backward_structured(
                s, np.asarray(out_masks, dtype=bool), inp)
        return self.bwd(inp).neighbor_mask_many(out_masks)

    def _forward_structured(self, s: SlotStructure, masks: np.ndarray,
                            inp: int) -> np.ndarray:
        n_in = self.n_in[inp]
        if isinstance(s, SlotIdentity):
            return masks[:, : s.n].copy()
        if isinstance(s, SlotRange):
            out = np.zeros((masks.shape[0], self.n_out), dtype=bool)
            out[:, s.start : s.start + s.length] = masks[:, : s.length]
            return out
        g = s.src
        valid = g >= 0
        safe = np.where(valid, g, 0)
        return masks[:, :n_in][:, safe] & valid[None, :]

    def _backward_structured(self, s: SlotStructure, masks: np.ndarray,
                             inp: int) -> np.ndarray:
        n_in = self.n_in[inp]
        if isinstance(s, SlotIdentity):
            return masks[:, : s.n].copy()
        if isinstance(s, SlotRange):
            out = np.zeros((masks.shape[0], n_in), dtype=bool)
            out[:, : s.length] = masks[:, s.start : s.start + s.length]
            return out
        g = s.src
        out = np.zeros((masks.shape[0], n_in), dtype=bool)
        sel = masks[:, : self.n_out] & (g >= 0)[None, :]
        bs, os_ = np.nonzero(sel)
        out[bs, g[os_]] = True
        return out

    def forward_rows(self, inp: int, rows) -> np.ndarray:
        """Sorted-unique output rows linked to the given input rows.  Direct
        CSR row-gather (or the structured fast path) — no ``list()``
        round-trip, no dense mask; an empty probe answers empty."""
        rows = _as_row_indices(rows, self.n_in[inp])
        s = self.slot_structure(inp)
        if isinstance(s, SlotIdentity):
            return np.unique(rows)
        if isinstance(s, SlotRange):
            rows = rows[rows < s.length]
            return np.unique(rows) + s.start
        if isinstance(s, SlotGather):
            return np.flatnonzero(np.isin(s.src, rows)).astype(np.int64)
        return self.fwd(inp).gather_rows(rows)

    def backward_rows(self, inp: int, rows) -> np.ndarray:
        """Sorted-unique input rows the given output rows derive from."""
        rows = _as_row_indices(rows, self.n_out)
        s = self.slot_structure(inp)
        if isinstance(s, SlotIdentity):
            return np.unique(rows)
        if isinstance(s, SlotRange):
            rows = rows[(rows >= s.start) & (rows < s.start + s.length)]
            return np.unique(rows) - s.start
        if isinstance(s, SlotGather):
            vals = s.src[rows]
            return np.unique(vals[vals >= 0]).astype(np.int64)
        return self.bwd(inp).gather_rows(rows)

    # -- bitplane views (for the einsum composition path) -------------------
    def bitplane_fwd(self, inp: int) -> np.ndarray:
        """uint32 (n_in[inp], ceil(n_out/32)) relation matrix R[i, o].
        Memoized — the hop-cache recomposes from these repeatedly."""
        if self._bpf is None:
            self._bpf = [None] * self.k
        if self._bpf[inp] is None:
            out, inn = self._slot_pairs(inp)
            valid = (out >= 0) & (inn >= 0)
            dense = np.zeros((self.n_in[inp], self.n_out), dtype=bool)
            dense[inn[valid], out[valid]] = True
            self._bpf[inp] = pack_bitplane(dense)
        return self._bpf[inp]

    def bitplane_bwd(self, inp: int) -> np.ndarray:
        """uint32 (n_out, ceil(n_in[inp]/32)) relation matrix R[o, i]."""
        if self._bpb is None:
            self._bpb = [None] * self.k
        if self._bpb[inp] is None:
            out, inn = self._slot_pairs(inp)
            valid = (out >= 0) & (inn >= 0)
            dense = np.zeros((self.n_out, self.n_in[inp]), dtype=bool)
            dense[out[valid], inn[valid]] = True
            self._bpb[inp] = pack_bitplane(dense)
        return self._bpb[inp]

    # -- set-semantics canonicalization (paper §III-C.a) ---------------------
    def canonicalize(self, duplicate_groups: np.ndarray) -> "ProvTensor":
        """Bag -> set semantics: map each output index to the smallest index of
        its duplicate group.  ``duplicate_groups[o]`` = canonical (smallest)
        output index of o's duplicate-value group."""
        groups = np.asarray(duplicate_groups, dtype=np.int32)
        if groups.shape != (self.n_out,):
            raise ValueError("duplicate_groups must have one entry per output record")
        coo = self.coo.copy()
        coo[:, 0] = groups[coo[:, 0]]
        coo = np.unique(coo, axis=0)
        return ProvTensor(n_out=self.n_out, n_in=self.n_in, coo=coo)

    # -- spill serialization (repro.core.spill) ------------------------------
    def resident(self) -> "ProvTensor":
        """This tensor, guaranteed resident.  A real tensor answers itself;
        a spill-tier :class:`~repro.core.spill._TensorFault` intercepts this
        to rehydrate — callers about to read capture payload aliases off
        ``op.info`` (recompute) touch it first."""
        return self

    def to_payload(self) -> Tuple[dict, dict]:
        """(meta, arrays) of the CANONICAL regime only — lazily-built
        mirrors (COO of a structured tensor, CSR halves, bitplanes) are
        deliberately dropped; they rebuild byte-identically after
        :meth:`from_payload`.  Structured slots serialize as their int
        payloads (identity/range as pure meta, gathers as the one int32
        array), explicit tensors as the COO index list — the compact
        on-disk relation forms of the spill tier."""
        meta: dict = {"n_out": self.n_out, "n_in": list(self.n_in)}
        arrays: dict = {}
        if self._slots is not None:
            descs = []
            for i, s in enumerate(self._slots):
                if isinstance(s, SlotIdentity):
                    descs.append({"kind": "identity", "n": s.n})
                elif isinstance(s, SlotRange):
                    descs.append({"kind": "range", "start": s.start,
                                  "length": s.length})
                else:
                    descs.append({"kind": "gather"})
                    arrays[f"slot{i}"] = s.src
            meta["slots"] = descs
        else:
            arrays["coo"] = self._coo
        return meta, arrays

    @staticmethod
    def from_payload(meta: dict, arrays: dict) -> "ProvTensor":
        """Inverse of :meth:`to_payload`.  Arrays may be read-only memmap
        views (the spill store's read path) — they are adopted as-is, no
        heap copy, so a faulted tensor's payload stays page-cache-backed."""
        n_out = int(meta["n_out"])
        n_in = tuple(int(n) for n in meta["n_in"])
        if "slots" in meta:
            slots: List[SlotStructure] = []
            for i, d in enumerate(meta["slots"]):
                if d["kind"] == "identity":
                    slots.append(SlotIdentity(int(d["n"])))
                elif d["kind"] == "range":
                    slots.append(SlotRange(int(d["start"]), int(d["length"])))
                else:
                    slots.append(SlotGather(np.asarray(arrays[f"slot{i}"],
                                                       dtype=np.int32)))
            return ProvTensor(n_out=n_out, n_in=n_in, slots=slots)
        return ProvTensor(n_out=n_out, n_in=n_in,
                          coo=np.asarray(arrays["coo"], dtype=np.int32))

    # -- memory accounting (Table IX / XI) -----------------------------------
    def nbytes(self, include_index: bool = True) -> int:
        """Bytes of the provenance encoding.  Structured tensors count their
        implicit payload only (a gather's int32 array; identity and range
        blocks are free); explicit tensors count the COO index list (the
        values list is omitted — binary tensor).  ``include_index`` adds any
        lazily-built mirrors: the COO mirror of a structured tensor, the
        bidirectional CSR halves, memoized relation bitplanes."""
        if self._slots is not None:
            total = sum(s.nbytes() for s in self._slots)
            if include_index:
                if self._coo is not None:
                    total += int(self._coo.nbytes)
                for g in self._sg or []:
                    if g is not None:
                        total += int(g.nbytes)
        else:
            total = int(self._coo.nbytes)
        if include_index:
            for half in (self._fwd or []), (self._bwd or []):
                for csr in half:
                    if csr is not None:
                        total += csr.nbytes()
            for half in (self._bpf or []), (self._bpb or []):
                for plane in half:
                    if plane is not None:
                        total += int(plane.nbytes)
        return total


def _as_row_indices(rows, n: int) -> np.ndarray:
    """Probe rows -> flat int64 index array, without a ``list()`` round-trip.
    Bounds-checked like the legacy dense-mask scatter (IndexError on
    out-of-range), so behavior is unchanged for bad probes."""
    if isinstance(rows, np.ndarray):
        if rows.dtype == bool:
            return np.flatnonzero(rows)
        idx = rows.astype(np.int64, copy=False).reshape(-1)
    else:
        idx = np.fromiter(rows, dtype=np.int64)
    if idx.size and (idx.min() < -n or idx.max() >= n):
        raise IndexError(f"probe row out of range for axis of size {n}")
    return np.where(idx < 0, idx + n, idx)  # legacy negative-index wraparound


# ---------------------------------------------------------------------------
# Row-range partitioning (the sharded index's layout contract)
# ---------------------------------------------------------------------------
def shard_ranges(n: int, n_shards: int) -> List[Tuple[int, int]]:
    """Contiguous balanced row ranges ``[(lo, hi), ...]`` partitioning
    ``[0, n)`` into ``n_shards`` pieces (``np.array_split`` semantics: the
    first ``n % n_shards`` shards take one extra row).  Shard counts that
    exceed ``n`` yield empty trailing ranges — a legal, if silly, layout
    the parity suite exercises (single-row and empty shards)."""
    n = int(n)
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    base, extra = divmod(n, n_shards)
    ranges: List[Tuple[int, int]] = []
    lo = 0
    for s in range(n_shards):
        hi = lo + base + (1 if s < extra else 0)
        ranges.append((lo, hi))
        lo = hi
    return ranges


# ---------------------------------------------------------------------------
# Constructors per operation category (paper §III-A a..g)
# ---------------------------------------------------------------------------
def identity_tensor(n: int, structured: bool = True) -> ProvTensor:
    """Data transformation / vertical reduction / vertical augmentation:
    2-D binary identity tensor — stored as a SCALAR (:class:`SlotIdentity`),
    not ``n`` explicit links."""
    if not structured:
        idx = np.arange(n, dtype=np.int32)
        return ProvTensor(n_out=n, n_in=(n,), coo=np.stack([idx, idx], axis=1))
    return ProvTensor(n_out=n, n_in=(n,), slots=(SlotIdentity(n),))


def hreduce_tensor(kept: np.ndarray, n_in: int, structured: bool = True) -> ProvTensor:
    """Horizontal reduction: masking tensor.  ``kept[i]`` = input index that
    became output record i — stored as the ``kept`` array itself."""
    kept = np.asarray(kept, dtype=np.int32)
    if not structured:
        out = np.arange(len(kept), dtype=np.int32)
        return ProvTensor(n_out=len(kept), n_in=(n_in,),
                          coo=np.stack([out, kept], axis=1))
    return ProvTensor(n_out=len(kept), n_in=(n_in,), slots=(SlotGather(kept),))


def haugment_tensor(src: np.ndarray, n_in: int, structured: bool = True) -> ProvTensor:
    """Horizontal augmentation: ``src[o]`` = input index output o derives from,
    or -1 for synthetic rows with no establishable mapping (paper §III-A e) —
    stored as the ``src`` gather array itself."""
    src = np.asarray(src, dtype=np.int32)
    if not structured:
        out = np.arange(len(src), dtype=np.int32)
        return ProvTensor(n_out=len(src), n_in=(n_in,),
                          coo=np.stack([out, src], axis=1))
    return ProvTensor(n_out=len(src), n_in=(n_in,), slots=(SlotGather(src),))


def join_tensor(pairs: np.ndarray, n_left: int, n_right: int,
                n_out: Optional[int] = None, structured: bool = True) -> ProvTensor:
    """Join: order-3 tensor.  ``pairs`` is (n_out, 2) of (left_idx, right_idx)
    for each output record, or -1 for the dangling side of outer joins —
    each side is one gather over the pair list."""
    pairs = np.asarray(pairs, dtype=np.int32)
    if n_out is None:
        n_out = len(pairs)
    if not structured or n_out != len(pairs):
        out = np.arange(len(pairs), dtype=np.int32)
        coo = np.concatenate([out[:, None], pairs], axis=1)
        return ProvTensor(n_out=n_out, n_in=(n_left, n_right), coo=coo)
    return ProvTensor(
        n_out=n_out,
        n_in=(n_left, n_right),
        slots=(SlotGather(np.ascontiguousarray(pairs[:, 0])),
               SlotGather(np.ascontiguousarray(pairs[:, 1]))),
    )


def append_tensor(n_left: int, n_right: int, structured: bool = True) -> ProvTensor:
    """Append: the paper's two block-diagonal 2-D tensors — TWO BLOCK OFFSETS
    (:class:`SlotRange`), no index arrays at all.  Output rows [0, n_left)
    link to the left input, rows [n_left, n_left+n_right) to the right."""
    if not structured:
        out = np.arange(n_left + n_right, dtype=np.int32)
        left = np.where(out < n_left, out, -1).astype(np.int32)
        right = np.where(out >= n_left, out - n_left, -1).astype(np.int32)
        return ProvTensor(
            n_out=n_left + n_right,
            n_in=(n_left, n_right),
            coo=np.stack([out, left, right], axis=1),
        )
    return ProvTensor(
        n_out=n_left + n_right,
        n_in=(n_left, n_right),
        slots=(SlotRange(0, n_left), SlotRange(n_left, n_right)),
    )
