"""Sparse binary provenance tensors (the paper's Section III).

A :class:`ProvTensor` encodes the why-provenance of ONE data-processing
operation: an order-(k+1) binary tensor ``T(o, i_1..i_k) = 1`` iff output
record ``o`` derives from the tuple of input records ``(i_1..i_k)``.

Representations held simultaneously (all index-only — the values list of a
COO layout is omitted entirely because the tensor is binary, exactly as the
paper's Section III-C argues):

* ``coo`` — ``(nnz, 1+k)`` int32 triples/tuples ``(out, in_1, .., in_k)``.
  ``-1`` marks "no link" for that input (used by append, whose provenance the
  paper stores as two block-diagonal 2-D tensors; we fuse them into one COO
  with a sentinel so the query engine is uniform).
* bidirectional CSR per input ``k`` — the array-resident realization of the
  paper's 3-level rooted-DAG (Fig. 1).  A lineage probe is
  ``row_ptr[q] : row_ptr[q+1]`` then a bounded gather of ``col_idx`` — the
  paper's "three list accesses", vectorized over a batch of probes.
* optional bitplanes — ``(rows, ceil(cols/32))`` uint32 bit-packed boolean
  matrices used by the Einstein-summation composition path
  (:mod:`repro.core.compose`); 32 boolean entries per lane word.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "CSR",
    "ProvTensor",
    "identity_tensor",
    "hreduce_tensor",
    "haugment_tensor",
    "join_tensor",
    "append_tensor",
    "pack_bitplane",
    "unpack_bitplane",
    "pack_mask",
    "unpack_mask",
    "bitplane_or_reduce",
    "bitplane_popcount",
]


# ---------------------------------------------------------------------------
# CSR half of the bidirectional index
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse rows: ``row_ptr`` (n_rows+1,), ``col_idx`` (nnz,).

    ``neighbors(q)`` = ``col_idx[row_ptr[q] : row_ptr[q+1]]``.
    """

    n_rows: int
    n_cols: int
    row_ptr: np.ndarray  # int32 (n_rows+1,)
    col_idx: np.ndarray  # int32 (nnz,)

    @staticmethod
    def from_pairs(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int) -> "CSR":
        rows = np.asarray(rows, dtype=np.int32)
        cols = np.asarray(cols, dtype=np.int32)
        keep = (rows >= 0) & (cols >= 0)
        rows, cols = rows[keep], cols[keep]
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        counts = np.bincount(rows, minlength=n_rows).astype(np.int64)
        row_ptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.cumsum(counts, out=row_ptr[1:])
        return CSR(n_rows=n_rows, n_cols=n_cols, row_ptr=row_ptr, col_idx=cols)

    def neighbors(self, q: int) -> np.ndarray:
        return self.col_idx[self.row_ptr[q] : self.row_ptr[q + 1]]

    def batch_neighbors(self, qs: np.ndarray, max_deg: Optional[int] = None) -> np.ndarray:
        """Padded (-1) batched probe: ``(len(qs), max_deg)`` int32."""
        qs = np.asarray(qs, dtype=np.int32)
        starts = self.row_ptr[qs]
        ends = self.row_ptr[qs + 1]
        degs = ends - starts
        if max_deg is None:
            max_deg = int(degs.max()) if len(degs) else 0
        max_deg = max(max_deg, 1)
        out = np.full((len(qs), max_deg), -1, dtype=np.int32)
        for i, (s, e) in enumerate(zip(starts, ends)):  # host path; jit path in kernels
            d = min(e - s, max_deg)
            out[i, :d] = self.col_idx[s : s + d]
        return out

    def neighbor_mask(self, qs: np.ndarray) -> np.ndarray:
        """OR of neighbor indicator rows for a query set -> bool (n_cols,)."""
        mask = np.zeros(self.n_cols, dtype=bool)
        qs = np.asarray(qs, dtype=np.int64)
        qs = qs[(qs >= 0) & (qs < self.n_rows)]
        if qs.size == 0:
            return mask
        # Vectorized ragged gather via repeat/arange.
        starts = self.row_ptr[qs]
        degs = self.row_ptr[qs + 1] - starts
        total = int(degs.sum())
        if total == 0:
            return mask
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])), degs) + np.arange(total)
        mask[self.col_idx[flat]] = True
        return mask

    def neighbor_mask_many(self, masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`neighbor_mask`: bool (B, n_rows) -> bool (B, n_cols).

        One ragged gather covers the whole batch — the probe rows of every
        batch element share a single repeat/arange expansion, so batch size
        adds no Python-level work.
        """
        masks = np.asarray(masks, dtype=bool)
        out = np.zeros((masks.shape[0], self.n_cols), dtype=bool)
        bs, qs = np.nonzero(masks[:, : self.n_rows])
        if qs.size == 0:
            return out
        starts = self.row_ptr[qs]
        degs = self.row_ptr[qs + 1] - starts
        total = int(degs.sum())
        if total == 0:
            return out
        flat = np.repeat(starts - np.concatenate(([0], np.cumsum(degs)[:-1])), degs) + np.arange(total)
        out[np.repeat(bs, degs), self.col_idx[flat]] = True
        return out

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    def nbytes(self) -> int:
        return int(self.row_ptr.nbytes + self.col_idx.nbytes)


# ---------------------------------------------------------------------------
# Bit-packing helpers (uint32 lanes, little-endian within the word)
# ---------------------------------------------------------------------------
def pack_bitplane(dense: np.ndarray) -> np.ndarray:
    """Pack bool (R, C) -> uint32 (R, ceil(C/32)); bit j of word w = col 32w+j."""
    dense = np.asarray(dense, dtype=bool)
    r, c = dense.shape
    cw = (c + 31) // 32
    padded = np.zeros((r, cw * 32), dtype=bool)
    padded[:, :c] = dense
    bits = padded.reshape(r, cw, 32).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits << shifts[None, None, :]).sum(axis=-1, dtype=np.uint32)


def unpack_bitplane(words: np.ndarray, n_cols: int) -> np.ndarray:
    words = np.asarray(words, dtype=np.uint32)
    r, cw = words.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(r, cw * 32)[:, :n_cols].astype(bool)


def pack_mask(mask: np.ndarray) -> np.ndarray:
    """Pack one bool vector (n,) -> uint32 (⌈n/32⌉,)."""
    return pack_bitplane(np.asarray(mask, dtype=bool)[None, :])[0]


def unpack_mask(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_mask`."""
    return unpack_bitplane(np.asarray(words, dtype=np.uint32)[None, :], n)[0]


def bitplane_or_reduce(sel_words: np.ndarray, plane: np.ndarray, n_mid: int) -> np.ndarray:
    """(OR,AND)-contract packed selectors against a packed relation, on host.

    ``sel_words`` is (B, ⌈n_mid/32⌉) — B packed row-selector masks;
    ``plane`` is (n_mid, W) — a packed relation bitplane.  Returns (B, W):
    row b = OR of the plane rows whose selector bit is set.  This is the numpy
    twin of :func:`repro.kernels.ops.bitmatmul` (same contraction), used where
    kernel-launch latency would dominate the tiny host-side masks.

    Per-probe cost is O(selected rows × W) — a buffered
    ``np.bitwise_or.reduce`` over just the selected plane rows.  (A batch-
    vectorized ``np.bitwise_or.at`` scatter was tried and measured 2-8x
    SLOWER: ufunc.at is unbuffered and pays far more per element than the
    buffered reduce; the per-probe temp here also stays bounded at one
    probe's selection, never (B, n_mid, W).)
    """
    sel_words = np.atleast_2d(np.asarray(sel_words, dtype=np.uint32))
    sel = unpack_bitplane(sel_words, n_mid)                   # (B, n_mid) bool
    out = np.zeros((sel.shape[0], plane.shape[1]), dtype=np.uint32)
    for b in range(sel.shape[0]):
        picked = plane[sel[b]]
        if picked.shape[0]:
            out[b] = np.bitwise_or.reduce(picked, axis=0)
    return out


def bitplane_popcount(words: np.ndarray) -> int:
    """Number of set bits in a packed bitplane (the relation's nnz)."""
    return int(np.unpackbits(np.ascontiguousarray(words).view(np.uint8)).sum())


# ---------------------------------------------------------------------------
# The provenance tensor itself
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ProvTensor:
    """Order-(k+1) sparse binary tensor for one data-processing operation."""

    n_out: int
    n_in: tuple  # sizes of each of the k input index spaces
    coo: np.ndarray  # (nnz, 1+k) int32; col 0 = output index; -1 = no link

    _fwd: Optional[list] = dataclasses.field(default=None, repr=False)
    _bwd: Optional[list] = dataclasses.field(default=None, repr=False)
    _bpf: Optional[list] = dataclasses.field(default=None, repr=False)
    _bpb: Optional[list] = dataclasses.field(default=None, repr=False)
    _slot_nnz: Optional[list] = dataclasses.field(default=None, repr=False)

    # -- construction -------------------------------------------------------
    def __post_init__(self) -> None:
        self.coo = np.asarray(self.coo, dtype=np.int32)
        if self.coo.ndim != 2 or self.coo.shape[1] != 1 + len(self.n_in):
            raise ValueError(
                f"coo shape {self.coo.shape} inconsistent with k={len(self.n_in)} inputs"
            )

    @property
    def k(self) -> int:
        return len(self.n_in)

    @property
    def nnz(self) -> int:
        return int(self.coo.shape[0])

    # -- per-slot relation statistics (the cost model reads these) -----------
    def slot_nnz(self, inp: int) -> int:
        """nnz of the input-``inp`` → output relation: COO entries whose slot
        index is a real link (not the -1 sentinel).  Memoized O(nnz) count —
        no CSR or bitplane is materialized."""
        if self._slot_nnz is None:
            self._slot_nnz = [None] * self.k
        if self._slot_nnz[inp] is None:
            self._slot_nnz[inp] = int(np.count_nonzero(self.coo[:, 1 + inp] >= 0))
        return self._slot_nnz[inp]

    def slot_shape(self, inp: int) -> tuple:
        """(rows, cols) of the input-``inp`` forward relation."""
        return (self.n_in[inp], self.n_out)

    def slot_density(self, inp: int) -> float:
        """nnz / (rows·cols) of the input-``inp`` forward relation."""
        cells = self.n_in[inp] * self.n_out
        return self.slot_nnz(inp) / cells if cells else 0.0

    # -- the paper's optimized representation (bidirectional CSR) -----------
    def fwd(self, inp: int) -> CSR:
        """input-record -> output-records CSR for input ``inp`` (solid edges)."""
        if self._fwd is None:
            self._fwd = [None] * self.k
        if self._fwd[inp] is None:
            self._fwd[inp] = CSR.from_pairs(
                self.coo[:, 1 + inp], self.coo[:, 0], self.n_in[inp], self.n_out
            )
        return self._fwd[inp]

    def bwd(self, inp: int) -> CSR:
        """output-record -> input-records CSR for input ``inp`` (dashed edges)."""
        if self._bwd is None:
            self._bwd = [None] * self.k
        if self._bwd[inp] is None:
            self._bwd[inp] = CSR.from_pairs(
                self.coo[:, 0], self.coo[:, 1 + inp], self.n_out, self.n_in[inp]
            )
        return self._bwd[inp]

    # -- paper §IV: slice + project, expressed on masks ---------------------
    def forward_mask(self, inp: int, in_mask: np.ndarray) -> np.ndarray:
        """project(slice(T, p_in, rows), p_out) with rows given as a mask."""
        rows = np.flatnonzero(np.asarray(in_mask, dtype=bool))
        return self.fwd(inp).neighbor_mask(rows)

    def backward_mask(self, inp: int, out_mask: np.ndarray) -> np.ndarray:
        """project(slice(T, p_out, rows), p_in)."""
        rows = np.flatnonzero(np.asarray(out_mask, dtype=bool))
        return self.bwd(inp).neighbor_mask(rows)

    def forward_mask_batch(self, inp: int, in_masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`forward_mask`: bool (B, n_in[inp]) -> (B, n_out)."""
        return self.fwd(inp).neighbor_mask_many(in_masks)

    def backward_mask_batch(self, inp: int, out_masks: np.ndarray) -> np.ndarray:
        """Batched :meth:`backward_mask`: bool (B, n_out) -> (B, n_in[inp])."""
        return self.bwd(inp).neighbor_mask_many(out_masks)

    def forward_rows(self, inp: int, rows: Sequence[int]) -> np.ndarray:
        m = np.zeros(self.n_in[inp], dtype=bool)
        m[np.asarray(list(rows), dtype=np.int64)] = True
        return np.flatnonzero(self.forward_mask(inp, m))

    def backward_rows(self, inp: int, rows: Sequence[int]) -> np.ndarray:
        m = np.zeros(self.n_out, dtype=bool)
        m[np.asarray(list(rows), dtype=np.int64)] = True
        return np.flatnonzero(self.backward_mask(inp, m))

    # -- bitplane views (for the einsum composition path) -------------------
    def bitplane_fwd(self, inp: int) -> np.ndarray:
        """uint32 (n_in[inp], ceil(n_out/32)) relation matrix R[i, o].
        Memoized — the hop-cache recomposes from these repeatedly."""
        if self._bpf is None:
            self._bpf = [None] * self.k
        if self._bpf[inp] is None:
            dense = np.zeros((self.n_in[inp], self.n_out), dtype=bool)
            valid = self.coo[:, 1 + inp] >= 0
            dense[self.coo[valid, 1 + inp], self.coo[valid, 0]] = True
            self._bpf[inp] = pack_bitplane(dense)
        return self._bpf[inp]

    def bitplane_bwd(self, inp: int) -> np.ndarray:
        """uint32 (n_out, ceil(n_in[inp]/32)) relation matrix R[o, i]."""
        if self._bpb is None:
            self._bpb = [None] * self.k
        if self._bpb[inp] is None:
            dense = np.zeros((self.n_out, self.n_in[inp]), dtype=bool)
            valid = self.coo[:, 1 + inp] >= 0
            dense[self.coo[valid, 0], self.coo[valid, 1 + inp]] = True
            self._bpb[inp] = pack_bitplane(dense)
        return self._bpb[inp]

    # -- set-semantics canonicalization (paper §III-C.a) ---------------------
    def canonicalize(self, duplicate_groups: np.ndarray) -> "ProvTensor":
        """Bag -> set semantics: map each output index to the smallest index of
        its duplicate group.  ``duplicate_groups[o]`` = canonical (smallest)
        output index of o's duplicate-value group."""
        groups = np.asarray(duplicate_groups, dtype=np.int32)
        if groups.shape != (self.n_out,):
            raise ValueError("duplicate_groups must have one entry per output record")
        coo = self.coo.copy()
        coo[:, 0] = groups[coo[:, 0]]
        coo = np.unique(coo, axis=0)
        return ProvTensor(n_out=self.n_out, n_in=self.n_in, coo=coo)

    # -- memory accounting (Table IX / XI) -----------------------------------
    def nbytes(self, include_index: bool = True) -> int:
        """Bytes of the provenance encoding: COO indices (the values list is
        omitted — binary tensor) plus, when built, the bidirectional CSR and
        any memoized relation bitplanes."""
        total = int(self.coo.nbytes)
        if include_index:
            for half in (self._fwd or []), (self._bwd or []):
                for csr in half:
                    if csr is not None:
                        total += csr.nbytes()
            for half in (self._bpf or []), (self._bpb or []):
                for plane in half:
                    if plane is not None:
                        total += int(plane.nbytes)
        return total


# ---------------------------------------------------------------------------
# Constructors per operation category (paper §III-A a..g)
# ---------------------------------------------------------------------------
def identity_tensor(n: int) -> ProvTensor:
    """Data transformation / vertical reduction / vertical augmentation:
    2-D binary identity tensor."""
    idx = np.arange(n, dtype=np.int32)
    return ProvTensor(n_out=n, n_in=(n,), coo=np.stack([idx, idx], axis=1))


def hreduce_tensor(kept: np.ndarray, n_in: int) -> ProvTensor:
    """Horizontal reduction: masking tensor.  ``kept[i]`` = input index that
    became output record i."""
    kept = np.asarray(kept, dtype=np.int32)
    out = np.arange(len(kept), dtype=np.int32)
    return ProvTensor(n_out=len(kept), n_in=(n_in,), coo=np.stack([out, kept], axis=1))


def haugment_tensor(src: np.ndarray, n_in: int) -> ProvTensor:
    """Horizontal augmentation: ``src[o]`` = input index output o derives from,
    or -1 for synthetic rows with no establishable mapping (paper §III-A e)."""
    src = np.asarray(src, dtype=np.int32)
    out = np.arange(len(src), dtype=np.int32)
    coo = np.stack([out, src], axis=1)
    return ProvTensor(n_out=len(src), n_in=(n_in,), coo=coo)


def join_tensor(pairs: np.ndarray, n_left: int, n_right: int, n_out: Optional[int] = None) -> ProvTensor:
    """Join: order-3 tensor.  ``pairs`` is (n_out, 2) of (left_idx, right_idx)
    for each output record, or -1 for the dangling side of outer joins."""
    pairs = np.asarray(pairs, dtype=np.int32)
    if n_out is None:
        n_out = len(pairs)
    out = np.arange(len(pairs), dtype=np.int32)
    coo = np.concatenate([out[:, None], pairs], axis=1)
    return ProvTensor(n_out=n_out, n_in=(n_left, n_right), coo=coo)


def append_tensor(n_left: int, n_right: int) -> ProvTensor:
    """Append: the paper's two block-diagonal 2-D tensors, fused via the -1
    sentinel.  Output rows [0, n_left) link to the left input, rows
    [n_left, n_left+n_right) to the right input."""
    out = np.arange(n_left + n_right, dtype=np.int32)
    left = np.where(out < n_left, out, -1).astype(np.int32)
    right = np.where(out >= n_left, out - n_left, -1).astype(np.int32)
    return ProvTensor(
        n_out=n_left + n_right,
        n_in=(n_left, n_right),
        coo=np.stack([out, left, right], axis=1),
    )
