"""Out-of-core spill tier for streaming provenance capture (ROADMAP item 3).

Production pipelines never stop appending.  Before this module every recorded
op tensor and every composed hop-cache entry lived in RAM forever (or, for
the hop-cache, was dropped outright at eviction and recomposed from scratch
on the next probe).  This module gives both stores a third place to put cold
state: a compact append-only on-disk log with memory-mapped read-back, so

* **capture RSS is bounded** — :class:`TensorSpiller` (wired through
  ``ProvenanceIndex(spill=...)``) keeps a byte-budgeted LRU of resident op
  tensors and serializes cold ones (structured slots as their int payloads,
  explicit COO as the index list) instead of keeping them hot;
* **eviction is not amnesia** — a :class:`~repro.core.hopcache.ComposedIndex`
  configured with ``spill=`` writes LRU-evicted composed relations to the
  store and FAULTS them back transparently on the next probe (one mmap read)
  instead of recomposing the whole chain.

The on-disk format is a log of fixed-size-rotated SEGMENT files (the
append-only layout of PROBE's prov-tracer log; the compact array triples
mirror swh-provenance's on-disk relation flavors — see PAPERS.md):

    [MAGIC][u32 header_len][json header][pad to 64][array bytes, 64-aligned]*

Every array payload is 64-byte aligned within its segment so read-back is a
zero-copy ``np.memmap`` slice ``.view(dtype)`` — faulted CSR triples,
bitplanes, and gather arrays are backed by the page cache, not the heap,
and are byte-identical to what was written (the spill parity suite pins
this).  The in-memory key index is authoritative; the on-disk headers exist
for forensics only — a :class:`SpillStore` is an ephemeral extension of RAM
for one process, not a durable database.

Disk reclamation is log-structured: deleting an entry marks its bytes dead,
and a segment whose entries are all dead is unlinked whole.  An optional
``disk_budget_bytes`` drops the OLDEST segments (live entries in them are
gone — counted in ``drops``); the tensor spiller never sets one, because a
dropped op tensor would lose recorded provenance.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import struct
import tempfile
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "SpillStore",
    "SpillPolicy",
    "TensorSpiller",
    "resolve_spill",
]

_MAGIC = b"RSPL1\x00"
_ALIGN = 64


def _pad(n: int) -> int:
    return (-n) % _ALIGN


@dataclasses.dataclass
class _StoredArray:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    offset: int          # absolute offset within the segment file
    nbytes: int


@dataclasses.dataclass
class _StoredEntry:
    seg: int
    meta: dict
    arrays: List[_StoredArray]
    nbytes: int          # total payload bytes (the live-byte accounting unit)


class SpillStore:
    """Append-only segmented spill log with memory-mapped read-back.

    ``root=None`` creates a private temp directory removed on :meth:`close`
    (and best-effort at garbage collection).  Keys are arbitrary hashables
    (the hop-cache uses ``("rel", index, src, dst)`` tuples, the tensor
    spiller ``("op", index, op_id)``), kept in an insertion-ordered
    in-memory index — oldest first, which is also segment order, so the
    disk-budget drop walks whole segments.  Single-process, single-thread
    use (matching the rest of the host query engine)."""

    def __init__(self, root: Optional[str] = None, *,
                 segment_bytes: int = 64 << 20,
                 disk_budget_bytes: Optional[int] = None) -> None:
        self._owns_root = root is None
        if root is None:
            root = tempfile.mkdtemp(prefix="repro-spill-")
        os.makedirs(root, exist_ok=True)
        self.root = root
        self.segment_bytes = int(segment_bytes)
        self.disk_budget_bytes = disk_budget_bytes
        self._index: "OrderedDict[object, _StoredEntry]" = OrderedDict()
        self._seg_bytes: Dict[int, int] = {}       # seg -> file bytes
        self._seg_live: Dict[int, int] = {}        # seg -> live entry count
        self._maps: Dict[int, Tuple[np.memmap, int]] = {}
        self._active = 0
        self._fh = open(self._seg_path(0), "ab")
        self._seg_bytes[0] = 0
        self._seg_live[0] = 0
        self._closed = False
        self.writes = 0
        self.reads = 0
        self.drops = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self._dead_bytes = 0

    # -- segment plumbing -----------------------------------------------------
    def _seg_path(self, seg: int) -> str:
        return os.path.join(self.root, f"seg{seg:06d}.spill")

    def _rotate(self) -> None:
        self._fh.close()
        self._maps.pop(self._active, None)
        self._active += 1
        self._fh = open(self._seg_path(self._active), "ab")
        self._seg_bytes[self._active] = 0
        self._seg_live[self._active] = 0

    def _drop_segment(self, seg: int) -> None:
        """Unlink one non-active segment; live entries in it are LOST."""
        for key in [k for k, e in self._index.items() if e.seg == seg]:
            entry = self._index.pop(key)
            self._dead_bytes += entry.nbytes
            self.drops += 1
        self._maps.pop(seg, None)
        path = self._seg_path(seg)
        if os.path.exists(path):
            os.remove(path)
        self._seg_bytes.pop(seg, None)
        self._seg_live.pop(seg, None)

    def _gc_segment(self, seg: int) -> None:
        """Unlink a fully-dead, non-active segment (real disk reclamation)."""
        if seg != self._active and self._seg_live.get(seg, 0) == 0:
            self._drop_segment(seg)

    def _enforce_disk_budget(self) -> None:
        if self.disk_budget_bytes is None:
            return
        while (sum(self._seg_bytes.values()) > self.disk_budget_bytes
               and len(self._seg_bytes) > 1):
            self._drop_segment(min(s for s in self._seg_bytes
                                   if s != self._active))

    # -- write path -----------------------------------------------------------
    def put(self, key, arrays: Dict[str, np.ndarray], meta: dict) -> None:
        """Append one entry (overwriting any previous entry under ``key`` —
        the old record's bytes go dead, log-structured)."""
        if self._closed:
            raise RuntimeError("SpillStore is closed")
        if key in self._index:
            self.delete(key)
        descs = []
        payload_bytes = 0
        blobs = []
        for name, arr in arrays.items():
            arr = np.ascontiguousarray(arr)
            descs.append((name, arr))
            payload_bytes += arr.nbytes
        header = json.dumps({
            "key": repr(key), "meta": meta,
            "arrays": [{"name": n, "dtype": str(a.dtype), "shape": a.shape}
                       for n, a in descs],
        }, default=str).encode()
        prefix = _MAGIC + struct.pack("<I", len(header)) + header
        record = len(prefix) + _pad(len(prefix))
        offsets = []
        for _, arr in descs:
            offsets.append(record)
            record += arr.nbytes + _pad(arr.nbytes)
        if self._seg_bytes[self._active] and \
                self._seg_bytes[self._active] + record > self.segment_bytes:
            self._rotate()
        base = self._seg_bytes[self._active]
        blobs.append(prefix + b"\0" * _pad(len(prefix)))
        for _, arr in descs:
            blobs.append(arr.tobytes() + b"\0" * _pad(arr.nbytes))
        self._fh.write(b"".join(blobs))
        stored = [
            _StoredArray(name=n, dtype=str(a.dtype), shape=tuple(a.shape),
                         offset=base + off, nbytes=a.nbytes)
            for (n, a), off in zip(descs, offsets)
        ]
        self._index[key] = _StoredEntry(seg=self._active, meta=meta,
                                        arrays=stored, nbytes=payload_bytes)
        self._seg_bytes[self._active] = base + record
        self._seg_live[self._active] += 1
        self.writes += 1
        self.bytes_written += payload_bytes
        self._enforce_disk_budget()

    # -- read path ------------------------------------------------------------
    def _segment_map(self, seg: int, need: int) -> np.memmap:
        if seg == self._active:
            self._fh.flush()
        cached = self._maps.get(seg)
        if cached is not None and cached[1] >= need:
            return cached[0]
        size = os.path.getsize(self._seg_path(seg))
        m = np.memmap(self._seg_path(seg), dtype=np.uint8, mode="r",
                      shape=(size,))
        self._maps[seg] = (m, size)
        return m

    def get(self, key) -> Tuple[dict, Dict[str, np.ndarray]]:
        """(meta, arrays) of one entry; arrays are READ-ONLY memmap views
        (zero heap copy — the page cache backs them).  ``KeyError`` when the
        key was never written, deleted, or dropped with its segment."""
        entry = self._index[key]
        arrays: Dict[str, np.ndarray] = {}
        for sa in entry.arrays:
            if sa.nbytes == 0:
                arrays[sa.name] = np.empty(sa.shape, dtype=np.dtype(sa.dtype))
                continue
            m = self._segment_map(entry.seg, sa.offset + sa.nbytes)
            arrays[sa.name] = (m[sa.offset: sa.offset + sa.nbytes]
                               .view(np.dtype(sa.dtype)).reshape(sa.shape))
        self.reads += 1
        self.bytes_read += entry.nbytes
        return entry.meta, arrays

    def __contains__(self, key) -> bool:
        return key in self._index

    def keys(self):
        return list(self._index)

    def delete(self, key) -> None:
        entry = self._index.pop(key, None)
        if entry is None:
            return
        self._dead_bytes += entry.nbytes
        self._seg_live[entry.seg] = self._seg_live.get(entry.seg, 1) - 1
        self._gc_segment(entry.seg)

    # -- lifecycle / introspection --------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "root": self.root,
            "entries": len(self._index),
            "segments": len(self._seg_bytes),
            "live_bytes": sum(e.nbytes for e in self._index.values()),
            "disk_bytes": sum(self._seg_bytes.values()),
            "dead_bytes": self._dead_bytes,
            "writes": self.writes,
            "reads": self.reads,
            "bytes_written": self.bytes_written,
            "bytes_read": self.bytes_read,
            "drops": self.drops,
        }

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._fh.close()
        self._maps.clear()
        if self._owns_root:
            shutil.rmtree(self.root, ignore_errors=True)

    def __del__(self) -> None:  # pragma: no cover - interpreter shutdown order
        try:
            self.close()
        except Exception:
            pass


@dataclasses.dataclass
class SpillPolicy:
    """How a store spills: where the log lives and when eviction kicks in.

    ``budget_bytes`` bounds the RESIDENT payload (the tensor spiller's
    budget; the hop-cache keeps its own ``memory_budget_bytes``).  The
    watermarks give eviction hysteresis: spilling starts when resident
    bytes exceed ``high_watermark × budget`` and stops at ``low_watermark ×
    budget``, so a stream of appends pays one burst of spill writes per
    watermark crossing instead of one write per append."""

    store: Optional[SpillStore] = None
    path: Optional[str] = None
    budget_bytes: int = 64 << 20
    high_watermark: float = 1.0
    low_watermark: float = 0.75
    segment_bytes: int = 64 << 20
    disk_budget_bytes: Optional[int] = None

    def ensure_store(self) -> SpillStore:
        if self.store is None:
            self.store = SpillStore(self.path,
                                    segment_bytes=self.segment_bytes,
                                    disk_budget_bytes=self.disk_budget_bytes)
        return self.store


def resolve_spill(spill) -> Optional[SpillPolicy]:
    """Normalize the ``spill=`` argument both stores accept: ``None``/False
    (disabled), ``True`` (private tempdir, defaults), a path, a
    :class:`SpillStore`, or a full :class:`SpillPolicy`."""
    if spill is None or spill is False:
        return None
    if isinstance(spill, SpillPolicy):
        return spill
    if isinstance(spill, SpillStore):
        return SpillPolicy(store=spill)
    if spill is True:
        return SpillPolicy()
    if isinstance(spill, (str, os.PathLike)):
        return SpillPolicy(path=os.fspath(spill))
    raise TypeError(f"spill must be None/True/path/SpillStore/SpillPolicy, "
                    f"got {type(spill).__name__}")


# ---------------------------------------------------------------------------
# Op-tensor spilling (ProvenanceIndex side)
# ---------------------------------------------------------------------------
class _TensorFault:
    """Stand-in for a spilled op tensor.

    Cheap statistics (shape, nnz, per-slot nnz, payload bytes) answer off
    the spill-time metadata so memory accounting and the cost model's
    :meth:`RelStats.from_slot`-adjacent reads never touch disk; ANY other
    attribute access faults the real tensor back in (one mmap read), swaps
    it into ``op.tensor``, and restores the stripped capture payload."""

    __slots__ = ("_spiller", "_op_id", "_meta")

    def __init__(self, spiller: "TensorSpiller", op_id: int, meta: dict):
        object.__setattr__(self, "_spiller", spiller)
        object.__setattr__(self, "_op_id", op_id)
        object.__setattr__(self, "_meta", meta)

    # -- cheap metadata (no disk) ---------------------------------------------
    @property
    def n_out(self) -> int:
        return int(self._meta["n_out"])

    @property
    def n_in(self) -> tuple:
        return tuple(int(n) for n in self._meta["n_in"])

    @property
    def k(self) -> int:
        return len(self._meta["n_in"])

    @property
    def structured(self) -> bool:
        return "slots" in self._meta

    @property
    def nnz(self) -> int:
        return int(self._meta["nnz"])

    def nbytes(self, include_index: bool = True) -> int:
        return int(self._meta["payload_bytes"])

    def slot_nnz(self, inp: int) -> int:
        return int(self._meta["slot_nnz"][inp])

    def slot_shape(self, inp: int) -> tuple:
        return (self.n_in[inp], self.n_out)

    def slot_density(self, inp: int) -> float:
        cells = self.n_in[inp] * self.n_out
        return self.slot_nnz(inp) / cells if cells else 0.0

    # -- everything else rehydrates -------------------------------------------
    def _fault(self):
        return self._spiller.fault(self._op_id)

    def __getattr__(self, name: str):
        return getattr(self._fault(), name)

    def __repr__(self) -> str:
        return (f"_TensorFault(op_id={self._op_id}, n_out={self.n_out}, "
                f"n_in={self.n_in}, spilled)")


class TensorSpiller:
    """Byte-budgeted residency manager for one index's op tensors.

    ``ProvenanceIndex.record`` notifies it per op; past the high watermark it
    serializes the coldest tensors (LRU by record/fault recency) to the
    spill store, STRIPS the capture-payload aliases off ``op.info`` (the
    structured slots share those arrays — spilling the tensor would free
    nothing otherwise), and leaves a :class:`_TensorFault` in ``op.tensor``.
    A re-spilled tensor whose payload is already on disk skips the write
    (tensors are immutable after capture), so fault/evict ping-pong costs
    one write total.  The store must never drop op segments — a dropped
    tensor is lost provenance — so give the tensor spiller its own store
    with no disk budget (the default)."""

    def __init__(self, index, policy: SpillPolicy) -> None:
        self.index = index
        self.policy = policy
        self.store = policy.ensure_store()
        self._resident: "OrderedDict[int, int]" = OrderedDict()
        self._meta: Dict[int, dict] = {}
        self._stored: set = set()
        self.resident_bytes = 0
        self.spills = 0
        self.rehydrations = 0

    def _key(self, op_id: int):
        return ("op", self.index.name, op_id)

    def on_record(self, op) -> None:
        b = op.tensor.nbytes(include_index=False)
        self._resident[op.op_id] = b
        self.resident_bytes += b
        self._maybe_spill()

    def _maybe_spill(self) -> None:
        budget = self.policy.budget_bytes
        if self.resident_bytes <= budget * self.policy.high_watermark:
            return
        target = budget * self.policy.low_watermark
        while self.resident_bytes > target and len(self._resident) > 1:
            op_id, b = self._resident.popitem(last=False)
            self._spill_op(self.index.ops[op_id], b)

    def _spill_op(self, op, payload_bytes: int) -> None:
        from repro.core.capture import strip_payload  # late: capture is upstream

        if op.op_id not in self._stored:
            meta, arrays = op.tensor.to_payload()
            meta["nnz"] = int(op.tensor.nnz)
            meta["slot_nnz"] = [int(op.tensor.slot_nnz(i))
                                for i in range(op.tensor.k)]
            meta["payload_bytes"] = int(payload_bytes)
            self.store.put(self._key(op.op_id), arrays, meta)
            self._meta[op.op_id] = meta
            self._stored.add(op.op_id)
        strip_payload(op.info)
        op.tensor = _TensorFault(self, op.op_id, self._meta[op.op_id])
        self.resident_bytes -= payload_bytes
        self.spills += 1

    def fault(self, op_id: int):
        """Rehydrate one spilled tensor: mmap-backed arrays, payload restored
        onto ``op.info``, residency re-accounted (possibly spilling colder
        ops to stay under the watermark)."""
        from repro.core.capture import restore_payload  # late import
        from repro.core.provtensor import ProvTensor

        op = self.index.ops[op_id]
        if not isinstance(op.tensor, _TensorFault):
            return op.tensor            # another reference already faulted it
        try:
            meta, arrays = self.store.get(self._key(op_id))
        except KeyError:
            raise RuntimeError(
                f"op {op_id} tensor was dropped from the spill store "
                f"({self.store.root}) — op-tensor stores must not set a "
                "disk budget") from None
        tensor = ProvTensor.from_payload(meta, arrays)
        op.tensor = tensor
        restore_payload(op.info, tensor)
        b = int(meta["payload_bytes"])
        self._resident[op_id] = b
        self.resident_bytes += b
        self.rehydrations += 1
        self._maybe_spill()
        return tensor

    def stats(self) -> Dict[str, object]:
        return {
            "resident_ops": len(self._resident),
            "spilled_ops": len(self.index.ops) - len(self._resident),
            "resident_bytes": self.resident_bytes,
            "budget_bytes": self.policy.budget_bytes,
            "spills": self.spills,
            "rehydrations": self.rehydrations,
            "store": self.store.stats(),
        }
