"""Provenance query processing (paper Section IV, Table VII: Q1-Q11).

This module is now two layers:

**The physical layer** (kept public, used by :mod:`repro.provenance`):
record-level queries chain ``project(slice(T, p_in, rows), p_out)`` hops —
realized as batched CSR probes (the optimized representation of §III-C) —
over the topologically-ordered op DAG; attribute-level queries additionally
thread (row-set x attr-set) terms through the Table-VI bitset maps.  It is
fully array-vectorized:

* attribute masks travel PACKED (uint32 words, 32 attrs per lane) and advance
  through an op via one select-OR contraction against the op's memoized
  attribute bitplane (:meth:`AttrMap.fwd_plane` / ``bwd_plane``) — no
  per-attribute rank/select dispatch;
* ``_cells`` materializes the union of (row-set × attr-set) products as a
  broadcasted outer product over packed masks, then one ``argwhere``;
* record-level hops through STRUCTURED op tensors (identities, selections,
  gathers, append blocks — the capture default) skip the CSR entirely: the
  per-op ``forward_mask_batch`` / ``backward_mask_batch`` dispatch to a
  take/scatter fast path on the implicit form, so a filter/gather-heavy
  walk allocates no per-op index at all;
* the batch walkers answer a whole probe batch in one pass — the per-op CSR
  gather covers all batch elements with a single ragged gather
  (:meth:`CSR.neighbor_mask_many`) — and can collect per-probe ``Hop``
  traces (``collect_hops=True``), so how-provenance (Q5-Q8) batches too.

**The legacy shims**: ``q1_forward`` … ``q11_co_dependency`` are THIN
DEPRECATION SHIMS over :mod:`repro.provenance` — each compiles its arguments
to a :class:`~repro.provenance.plan.QueryPlan` and executes it through the
index's shared :class:`~repro.provenance.session.QuerySession`, which owns
the hop-cache routing.  Prefer the builder::

    from repro.provenance import prov
    prov(index).source(src).rows([...]).forward().to(dst).run()

The shims keep the old single-vs-batch *guess* (:func:`is_probe_batch`) and
warn with :class:`~repro.provenance.plan.AmbiguousProbeWarning` on the
inputs where the guess is ambiguous (an empty list; a 1-D integer ndarray):
the builder's explicit ``.rows(...)`` / ``.rows_batch(...)`` never guesses.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.opcat import AttrMap
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    bitplane_or_reduce,
    pack_bitplane,
    pack_mask,
    unpack_bitplane,
)

__all__ = [
    "Hop",
    "forward_record_masks",
    "backward_record_masks",
    "forward_record_masks_batch",
    "backward_record_masks_batch",
    "fused_walk_record_masks_batch",
    "record_masks_terms_batch",
    "attr_propagate_terms_batch",
    "q1_forward",
    "q2_backward",
    "q3_forward_attr",
    "q4_backward_attr",
    "q5_forward_how",
    "q6_backward_how",
    "q7_forward_attr_how",
    "q8_backward_attr_how",
    "q9_all_transformations",
    "q10_co_contributory",
    "q11_co_dependency",
]


@dataclasses.dataclass(frozen=True)
class Hop:
    """One op traversal — the *how* part of how-provenance (Q5-Q8)."""

    op_id: int
    op_name: str
    category: str
    src_dataset: str
    dst_dataset: str
    n_records: int


# ---------------------------------------------------------------------------
# Probe normalization: single probe vs batch of probes
# ---------------------------------------------------------------------------
def _as_mask(rows, n: int) -> np.ndarray:
    if isinstance(rows, np.ndarray):
        if rows.dtype == bool:
            return rows
        idx = rows.astype(np.int64, copy=False).reshape(-1)
    else:
        # no list() round-trip: consume any iterable of row indices directly
        idx = np.fromiter(rows, dtype=np.int64)
    m = np.zeros(n, dtype=bool)
    m[idx] = True
    return m


def is_probe_batch(rows) -> bool:
    """A batch is a 2-D mask stack or a non-empty list/tuple of probe sets."""
    if isinstance(rows, np.ndarray):
        return rows.ndim == 2
    if isinstance(rows, (list, tuple)):
        return len(rows) > 0 and all(
            isinstance(r, (list, tuple, np.ndarray, set, frozenset, range))
            for r in rows
        )
    return False


def _as_mask_batch(rows_batch, n: int) -> np.ndarray:
    if isinstance(rows_batch, np.ndarray) and rows_batch.ndim == 2:
        if rows_batch.dtype == bool:
            return rows_batch
        out = np.zeros((rows_batch.shape[0], n), dtype=bool)
        out[np.arange(rows_batch.shape[0])[:, None], rows_batch.astype(np.int64)] = True
        return out
    return np.stack([_as_mask(r, n) for r in rows_batch], axis=0)


# ---------------------------------------------------------------------------
# Record-level propagation (Q1/Q2 cores)
# ---------------------------------------------------------------------------
def forward_record_masks(
    index: ProvenanceIndex, src: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    """Propagate a row mask from ``src`` to every reachable dataset."""
    masks: Dict[str, np.ndarray] = {src: _as_mask(rows, index.datasets[src].n_rows)}
    hops: List[Hop] = []
    for op in index.downstream_ops(src):
        out_n = op.tensor.n_out
        out_mask = masks.get(op.output_id, np.zeros(out_n, dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                contrib = op.tensor.forward_mask(k, masks[in_id])
                if collect_hops and contrib.any():
                    hops.append(
                        Hop(op.op_id, op.info.op_name, op.info.category.value,
                            in_id, op.output_id, int(contrib.sum()))
                    )
                out_mask |= contrib
        masks[op.output_id] = out_mask
    return masks, hops


def backward_record_masks(
    index: ProvenanceIndex, dst: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    masks: Dict[str, np.ndarray] = {dst: _as_mask(rows, index.datasets[dst].n_rows)}
    hops: List[Hop] = []
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask(k, masks[op.output_id])
            if collect_hops and contrib.any():
                hops.append(
                    Hop(op.op_id, op.info.op_name, op.info.category.value,
                        op.output_id, in_id, int(contrib.sum()))
                )
            prev = masks.get(in_id, np.zeros(index.datasets[in_id].n_rows, dtype=bool))
            masks[in_id] = prev | contrib
    return masks, hops


def forward_record_masks_batch(
    index: ProvenanceIndex, src: str, rows_batch, collect_hops: bool = False
):
    """Batched :func:`forward_record_masks`: every value is (B, n_rows) bool.

    One pass over the op DAG answers all B probes — each hop is a single
    batched CSR gather, not B sequential walks.  With ``collect_hops`` the
    return is ``(masks, hops)`` where ``hops[b]`` is probe b's :class:`Hop`
    trace, identical to the single-probe trace (a hop is recorded for probe
    b iff that probe's contribution through the op is non-empty).
    """
    stack = _as_mask_batch(rows_batch, index.datasets[src].n_rows)
    masks: Dict[str, np.ndarray] = {src: stack}
    B = stack.shape[0]
    hops: List[List[Hop]] = [[] for _ in range(B)]
    for op in index.downstream_ops(src):
        out_mask = masks.get(op.output_id, np.zeros((B, op.tensor.n_out), dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                contrib = op.tensor.forward_mask_batch(k, masks[in_id])
                if collect_hops:
                    counts = contrib.sum(axis=1)
                    for b in np.flatnonzero(counts):
                        hops[b].append(
                            Hop(op.op_id, op.info.op_name, op.info.category.value,
                                in_id, op.output_id, int(counts[b]))
                        )
                out_mask = out_mask | contrib
        masks[op.output_id] = out_mask
    if collect_hops:
        return masks, hops
    return masks


def backward_record_masks_batch(
    index: ProvenanceIndex, dst: str, rows_batch, collect_hops: bool = False
):
    stack = _as_mask_batch(rows_batch, index.datasets[dst].n_rows)
    masks: Dict[str, np.ndarray] = {dst: stack}
    B = stack.shape[0]
    hops: List[List[Hop]] = [[] for _ in range(B)]
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask_batch(k, masks[op.output_id])
            if collect_hops:
                counts = contrib.sum(axis=1)
                for b in np.flatnonzero(counts):
                    hops[b].append(
                        Hop(op.op_id, op.info.op_name, op.info.category.value,
                            op.output_id, in_id, int(counts[b]))
                    )
            prev = masks.get(
                in_id, np.zeros((B, index.datasets[in_id].n_rows), dtype=bool)
            )
            masks[in_id] = prev | contrib
    if collect_hops:
        return masks, hops
    return masks


# ---------------------------------------------------------------------------
# Fused-kernel record walk (ROADMAP item 4)
# ---------------------------------------------------------------------------
def fused_walk_record_masks_batch(
    index: ProvenanceIndex,
    src: str,
    dst: str,
    rows_batch,
    direction: str = "fwd",
    use_pallas: Optional[bool] = None,
    max_plane_bytes: int = 256 << 20,
) -> Optional[np.ndarray]:
    """``(B, n_dst)`` bool answered in ONE kernel launch, or None to fall back.

    The fused :func:`repro.kernels.ops.batched_walk` replaces the per-op
    pass only when the ``src``→``dst`` dataflow is ONE linear chain: every
    op-slot that both receives mass from the upstream end and can pass it
    on to the downstream end must lie on the
    :func:`~repro.core.compose.path_tensors` chain.  Diamonds, self-joins
    and side entrances fail that audit and return None — the caller falls
    back to the full per-op walker (which sums over all paths).  None is
    also returned when the square-padded plane stack the fused kernel
    streams would exceed ``max_plane_bytes``.

    ``direction="bwd"`` probes ``src`` (the downstream end) and answers at
    ``dst`` through the transposed planes of the reversed chain; forward
    and backward both return exactly the target dataset's mask stack of
    the corresponding full walker.  ``use_pallas=None`` is the
    kernel-launch guard: the fused Pallas kernel on TPU, the one-dispatch
    jnp oracle elsewhere.
    """
    from repro.core.compose import path_tensors

    up, down = (src, dst) if direction == "fwd" else (dst, src)
    if up not in index.datasets or down not in index.datasets:
        return None
    try:
        chain = path_tensors(index, up, down)
    except KeyError:
        return None
    stack = _as_mask_batch(rows_batch, index.datasets[src].n_rows)
    if not chain:  # src == dst: the seed is the answer
        return stack.astype(bool, copy=True)

    # linearity audit: one forward and one backward closure over the
    # (topologically ordered) op list find every op-slot carrying mass from
    # `up` toward `down`; the chain is exact iff it covers all of them
    reach = {up}
    for op in index.ops:
        if any(d in reach for d in op.input_ids):
            reach.add(op.output_id)
    feeds = {down}
    for op in reversed(index.ops):
        if op.output_id in feeds:
            feeds.update(op.input_ids)
    relevant = {
        (op.op_id, k)
        for op in index.ops
        for k, in_id in enumerate(op.input_ids)
        if in_id in reach and op.output_id in feeds
    }
    if relevant != {(op.op_id, slot) for op, slot in chain}:
        return None

    # the fused kernel square-pads every hop to one common dim — cap the
    # streamed plane stack before materializing any bitplane
    n_max = max(
        max(op.tensor.n_in[slot], op.tensor.n_out) for op, slot in chain
    )
    if len(chain) * n_max * n_max // 8 > max_plane_bytes:
        return None

    if direction == "fwd":
        planes = [op.tensor.bitplane_fwd(slot) for op, slot in chain]
    else:
        planes = [op.tensor.bitplane_bwd(slot) for op, slot in reversed(chain)]

    from repro.kernels import ops as K

    mask_bits = pack_bitplane(np.ascontiguousarray(stack))
    out_bits, _counts = K.batched_walk(mask_bits, planes, use_pallas=use_pallas)
    return unpack_bitplane(np.asarray(out_bits), index.datasets[dst].n_rows)


# ---------------------------------------------------------------------------
# Legacy Table-VII shims over repro.provenance (deprecated spellings)
# ---------------------------------------------------------------------------
_DEPRECATION_WARNED: Set[str] = set()


def _warn_deprecated(name: str, spelling: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"repro.core.query.{name} is deprecated; use "
        f"repro.provenance.prov(index){spelling}",
        DeprecationWarning,
        stacklevel=3,
    )


def _legacy_probe_is_batch(name: str, rows) -> bool:
    """The old :func:`is_probe_batch` guess, with an
    :class:`AmbiguousProbeWarning` on the spellings it cannot distinguish."""
    from repro.provenance import AmbiguousProbeWarning

    if isinstance(rows, (list, tuple)) and len(rows) == 0:
        warnings.warn(
            f"{name}: an empty probe [] is ambiguous (one empty probe set vs "
            "an empty batch) and takes the single-probe path; spell it "
            "prov(index)...rows([]) or .rows_batch([]) instead",
            AmbiguousProbeWarning,
            stacklevel=3,
        )
        return False
    if isinstance(rows, np.ndarray) and rows.ndim == 1 and rows.dtype != bool:
        warnings.warn(
            f"{name}: a 1-D integer ndarray probe is ambiguous (row indices "
            "vs a length-1 batch) and takes the single-probe (row-index) "
            "path; spell it prov(index)...rows(...) or .rows_batch(...) "
            "instead",
            AmbiguousProbeWarning,
            stacklevel=3,
        )
        return False
    return is_probe_batch(rows)


def _record_shim(index, name, spelling, start, rows, target, direction, how):
    from repro.provenance import prov

    _warn_deprecated(name, spelling)
    qb = prov(index).source(start)
    qb = qb.rows_batch(rows) if _legacy_probe_is_batch(name, rows) else qb.rows(rows)
    qb = qb.forward() if direction == "fwd" else qb.backward()
    if how:
        qb = qb.how()
    return qb.to(target).run()


def _cells_shim(index, name, spelling, start, rows, attrs, target, direction, how):
    from repro.provenance import prov

    _warn_deprecated(name, spelling)
    qb = prov(index).source(start)
    batched = _legacy_probe_is_batch(name, rows)
    qb = qb.rows_batch(rows) if batched else qb.rows(rows)
    if batched and is_probe_batch(attrs):
        qb = qb.attrs_batch(attrs)
    else:
        qb = qb.attrs(attrs)
    qb = qb.forward() if direction == "fwd" else qb.backward()
    if how:
        qb = qb.how()
    return qb.to(target).run()


def q1_forward(index: ProvenanceIndex, src: str, rows, dst: str):
    """Q1: records in ``dst`` derived from ``rows`` of ``src``.

    Deprecated shim — ``prov(index).source(src).rows(...).forward().to(dst)``.
    ``rows`` may be one probe set or a batch (list of sets); a batch returns
    a list of index arrays, answered in one vectorized pass.
    """
    return _record_shim(index, "q1_forward", ".source(src).rows(...).forward().to(dst)",
                        src, rows, dst, "fwd", how=False)


def q2_backward(index: ProvenanceIndex, dst: str, rows, src: str):
    """Q2: records in ``src`` that contributed to ``rows`` of ``dst``.

    Deprecated shim — ``prov(index).source(dst).rows(...).backward().to(src)``.
    """
    return _record_shim(index, "q2_backward", ".source(dst).rows(...).backward().to(src)",
                        dst, rows, src, "bwd", how=False)


def q5_forward_how(index: ProvenanceIndex, src: str, rows, dst: str):
    """Q5: Q1 plus the per-op :class:`Hop` trace.  Deprecated shim —
    ``prov(index).source(src).rows(...).forward().to(dst).how()``.  Batch
    probes (new) return one ``(records, hops)`` pair per probe."""
    return _record_shim(index, "q5_forward_how",
                        ".source(src).rows(...).forward().to(dst).how()",
                        src, rows, dst, "fwd", how=True)


def q6_backward_how(index: ProvenanceIndex, dst: str, rows, src: str):
    """Q6: Q2 plus the hop trace.  Deprecated shim —
    ``prov(index).source(dst).rows(...).backward().to(src).how()``."""
    return _record_shim(index, "q6_backward_how",
                        ".source(dst).rows(...).backward().to(src).how()",
                        dst, rows, src, "bwd", how=True)


# ---------------------------------------------------------------------------
# Attribute maps (Table VI bitsets -> per-op attr propagation)
#
# An attr mask is PACKED uint32 words; one op hop is a select-OR contraction
# of the packed mask against the op's memoized attribute bitplane.
# ---------------------------------------------------------------------------
def _attrs_forward(amap: AttrMap, attrs: np.ndarray, n_out_attrs: int) -> np.ndarray:
    """Map an input-attr mask to the output-attr mask through one op input."""
    attrs = np.asarray(attrs, dtype=bool)
    plane = amap.fwd_plane(attrs.shape[0], n_out_attrs)
    words = bitplane_or_reduce(pack_mask(attrs)[None, :], plane, attrs.shape[0])
    return unpack_bitplane(words, n_out_attrs)[0]


def _attrs_backward(amap: AttrMap, attrs: np.ndarray, n_in_attrs: int) -> np.ndarray:
    attrs = np.asarray(attrs, dtype=bool)
    plane = amap.bwd_plane(n_in_attrs, attrs.shape[0])
    words = bitplane_or_reduce(pack_mask(attrs)[None, :], plane, attrs.shape[0])
    return unpack_bitplane(words, n_in_attrs)[0]


# ---------------------------------------------------------------------------
# Attribute-level queries (Q3/Q4/Q7/Q8): (row-mask, packed-attr-words) terms
# ---------------------------------------------------------------------------
def _attr_propagate(
    index: ProvenanceIndex, start: str, rows, attrs, direction: str,
    collect_hops: bool = False,
):
    ds0 = index.datasets[start]
    terms: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
        start: [(_as_mask(rows, ds0.n_rows), pack_mask(_as_mask(attrs, ds0.n_cols)))]
    }
    hops: List[Hop] = []
    ops = (
        index.downstream_ops(start)
        if direction == "fwd"
        else list(reversed(index.upstream_ops(start)))
    )
    for op in ops:
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                in_ds = index.datasets[in_id]
                plane = op.info.attr_maps[k].fwd_plane(in_ds.n_cols, out_ds.n_cols)
                for (rm, aw) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask(k, rm)
                    new_aw = bitplane_or_reduce(aw[None, :], plane, in_ds.n_cols)[0]
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, in_id,
                                            op.output_id, int(new_rm.sum())))
        else:
            for (rm, aw) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    plane = op.info.attr_maps[k].bwd_plane(in_ds.n_cols, out_ds.n_cols)
                    new_rm = op.tensor.backward_mask(k, rm)
                    new_aw = bitplane_or_reduce(aw[None, :], plane, out_ds.n_cols)[0]
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(in_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, op.output_id,
                                            in_id, int(new_rm.sum())))
    return terms, hops


def _ops_from_entries(index: ProvenanceIndex, entries, direction: str):
    """Ops reachable from ANY entry dataset, in traversal order.

    Op registration order is topological, so sorting the union by ``op_id``
    (descending for ``"bwd"``) reproduces the single-entry walk order
    exactly — a one-entry seed walks the identical op sequence as
    ``downstream_ops`` / ``reversed(upstream_ops)``.
    """
    by_id = {}
    for ds in entries:
        ops = (index.downstream_ops(ds) if direction == "fwd"
               else index.upstream_ops(ds))
        for op in ops:
            by_id[op.op_id] = op
    out = [by_id[i] for i in sorted(by_id)]
    if direction == "bwd":
        out.reverse()
    return out


def attr_propagate_terms_batch(
    index: ProvenanceIndex, entry_terms, direction: str,
    collect_hops: bool = False,
):
    """Term propagation seeded at ARBITRARY datasets (federated segments).

    ``entry_terms`` maps dataset id -> list of ``((B, n_rows) bool,
    (B, nw) uint32)`` already-packed terms (:func:`pack_bitplane` words).
    The per-op semantics are identical to :func:`_attr_propagate_batch` —
    a single-entry seed reproduces it term-for-term — but the walk covers
    every op reachable from ANY entry, so a federation can hand one member
    all of its boundary entries at once and read terms off every exit in
    one pass (hop traces then match a merged index's single walk instead
    of duplicating shared ops per entry/exit pair).

    Returns ``(terms, B, hops)`` with ``collect_hops``, else ``(terms, B)``.
    """
    terms: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
        ds: list(ts) for ds, ts in entry_terms.items() if ts
    }
    if not terms:
        raise ValueError("attr_propagate_terms_batch needs at least one "
                         "non-empty entry term list")
    B = next(iter(terms.values()))[0][0].shape[0]
    hops: List[List[Hop]] = [[] for _ in range(B)]

    def _trace(op, src_id, dst_id, new_rm, new_aw):
        counts = new_rm.sum(axis=1)
        live = counts.astype(bool) & new_aw.any(axis=1)
        for b in np.flatnonzero(live):
            hops[b].append(Hop(op.op_id, op.info.op_name, op.info.category.value,
                               src_id, dst_id, int(counts[b])))

    for op in _ops_from_entries(index, list(terms), direction):
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                in_ds = index.datasets[in_id]
                plane = op.info.attr_maps[k].fwd_plane(in_ds.n_cols, out_ds.n_cols)
                for (rm, aw) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask_batch(k, rm)
                    new_aw = bitplane_or_reduce(aw, plane, in_ds.n_cols)
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            _trace(op, in_id, op.output_id, new_rm, new_aw)
        else:
            for (rm, aw) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    plane = op.info.attr_maps[k].bwd_plane(in_ds.n_cols, out_ds.n_cols)
                    new_rm = op.tensor.backward_mask_batch(k, rm)
                    new_aw = bitplane_or_reduce(aw, plane, out_ds.n_cols)
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(in_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            _trace(op, op.output_id, in_id, new_rm, new_aw)
    if collect_hops:
        return terms, B, hops
    return terms, B


def _attr_propagate_batch(
    index: ProvenanceIndex, start: str, rows_batch, attrs_batch, direction: str,
    collect_hops: bool = False,
):
    """Batched term propagation: every term is ((B, n_rows) bool, (B, nw) u32).

    A term stays alive while ANY batch element is non-empty; per-element
    emptiness zeroes that element's masks, which contributes nothing to the
    final outer product — exactly the single-probe pruning, batched.

    With ``collect_hops`` the return gains a per-probe :class:`Hop` trace
    (``hops[b]``): a hop is recorded for probe b iff probe b's term survives
    the op with non-empty row AND attr masks — matching the single-probe
    :func:`_attr_propagate` trace exactly.
    """
    ds0 = index.datasets[start]
    rm0 = _as_mask_batch(rows_batch, ds0.n_rows)
    B = rm0.shape[0]
    am0 = _as_mask_batch(attrs_batch, ds0.n_cols) if is_probe_batch(attrs_batch) \
        else np.broadcast_to(_as_mask(attrs_batch, ds0.n_cols), (B, ds0.n_cols))
    entry = {start: [(rm0, pack_bitplane(np.ascontiguousarray(am0)))]}
    return attr_propagate_terms_batch(index, entry, direction,
                                      collect_hops=collect_hops)


def record_masks_terms_batch(
    index: ProvenanceIndex, entry_masks, direction: str,
    collect_hops: bool = False,
):
    """Record propagation seeded at ARBITRARY datasets (federated segments).

    ``entry_masks`` maps dataset id -> ``(B, n_rows)`` bool probe stacks.
    The multi-seed twin of :func:`forward_record_masks_batch` /
    :func:`backward_record_masks_batch`: one pass over every op reachable
    from any entry (registration order is topological), per-probe hop
    traces identical to the single-entry walkers.  Returns
    ``(masks, hops)`` with ``collect_hops``, else ``masks``.
    """
    masks: Dict[str, np.ndarray] = {
        ds: np.asarray(m, dtype=bool) for ds, m in entry_masks.items()
    }
    if not masks:
        raise ValueError("record_masks_terms_batch needs at least one entry")
    B = next(iter(masks.values())).shape[0]
    hops: List[List[Hop]] = [[] for _ in range(B)]
    for op in _ops_from_entries(index, list(masks), direction):
        if direction == "fwd":
            out_mask = masks.get(op.output_id,
                                 np.zeros((B, op.tensor.n_out), dtype=bool))
            for k, in_id in enumerate(op.input_ids):
                if in_id in masks and masks[in_id].any():
                    contrib = op.tensor.forward_mask_batch(k, masks[in_id])
                    if collect_hops:
                        counts = contrib.sum(axis=1)
                        for b in np.flatnonzero(counts):
                            hops[b].append(
                                Hop(op.op_id, op.info.op_name,
                                    op.info.category.value, in_id,
                                    op.output_id, int(counts[b]))
                            )
                    out_mask = out_mask | contrib
            masks[op.output_id] = out_mask
        else:
            if op.output_id not in masks or not masks[op.output_id].any():
                continue
            for k, in_id in enumerate(op.input_ids):
                contrib = op.tensor.backward_mask_batch(k, masks[op.output_id])
                if collect_hops:
                    counts = contrib.sum(axis=1)
                    for b in np.flatnonzero(counts):
                        hops[b].append(
                            Hop(op.op_id, op.info.op_name,
                                op.info.category.value, op.output_id, in_id,
                                int(counts[b]))
                        )
                prev = masks.get(
                    in_id,
                    np.zeros((B, index.datasets[in_id].n_rows), dtype=bool),
                )
                masks[in_id] = prev | contrib
    if collect_hops:
        return masks, hops
    return masks


def _cells(
    terms: List[Tuple[np.ndarray, np.ndarray]], n_rows: int, n_cols: int
) -> np.ndarray:
    """Union of (rows × attrs) products -> (n, 2) sorted unique cell list.

    Broadcasted outer product on PACKED attr words: scatter each term's packed
    attr mask into the rows its row-mask selects, then unpack once."""
    nw = max((n_cols + 31) // 32, 1)
    acc = np.zeros((n_rows, nw), dtype=np.uint32)
    for rm, aw in terms:
        acc[rm] |= aw[None, :]
    return np.argwhere(unpack_bitplane(acc, n_cols)).astype(np.int64)


def _cells_batch(
    terms: List[Tuple[np.ndarray, np.ndarray]], B: int, n_rows: int, n_cols: int
) -> List[np.ndarray]:
    nw = max((n_cols + 31) // 32, 1)
    acc = np.zeros((B, n_rows, nw), dtype=np.uint32)
    for rm, aw in terms:
        acc |= np.where(rm[:, :, None], aw[:, None, :], np.uint32(0))
    return [np.argwhere(unpack_bitplane(acc[b], n_cols)).astype(np.int64)
            for b in range(B)]


def q3_forward_attr(index, src: str, rows, attrs, dst: str):
    """Q3: attribute values (cells) of ``dst`` derived from the given cells.

    Deprecated shim —
    ``prov(index).source(src).rows(...).attrs(...).forward().to(dst)``.
    Batched when ``rows`` (and optionally ``attrs``) is a list of probe sets:
    returns one cell list per probe."""
    return _cells_shim(index, "q3_forward_attr",
                       ".source(src).rows(...).attrs(...).forward().to(dst)",
                       src, rows, attrs, dst, "fwd", how=False)


def q4_backward_attr(index, dst: str, rows, attrs, src: str):
    """Q4: source cells the given ``dst`` cells derive from.  Deprecated shim
    — ``prov(index).source(dst).rows(...).attrs(...).backward().to(src)``."""
    return _cells_shim(index, "q4_backward_attr",
                       ".source(dst).rows(...).attrs(...).backward().to(src)",
                       dst, rows, attrs, src, "bwd", how=False)


def q7_forward_attr_how(index, src: str, rows, attrs, dst: str):
    """Q7: Q3 plus the hop trace.  Deprecated shim — Q3's spelling + ``.how()``.
    Batch probes (new) return one ``(cells, hops)`` pair per probe."""
    return _cells_shim(index, "q7_forward_attr_how",
                       ".source(src).rows(...).attrs(...).forward().to(dst).how()",
                       src, rows, attrs, dst, "fwd", how=True)


def q8_backward_attr_how(index, dst: str, rows, attrs, src: str):
    """Q8: Q4 plus the hop trace.  Deprecated shim — Q4's spelling + ``.how()``."""
    return _cells_shim(index, "q8_backward_attr_how",
                       ".source(dst).rows(...).attrs(...).backward().to(src).how()",
                       dst, rows, attrs, src, "bwd", how=True)


# ---------------------------------------------------------------------------
# Q9: all transformations applied to a dataset (metadata only — no tensors)
# ---------------------------------------------------------------------------
def q9_all_transformations(index: ProvenanceIndex, dataset: str) -> List[Dict]:
    """Deprecated shim — ``prov(index).source(dataset).transformations()``."""
    from repro.provenance import prov

    _warn_deprecated("q9_all_transformations", ".source(dataset).transformations()")
    return prov(index).source(dataset).transformations().run()


# ---------------------------------------------------------------------------
# Q10/Q11: co-contributory and co-dependency (forward + backward combos)
# ---------------------------------------------------------------------------
def _pick_via(index: ProvenanceIndex, d1: str, d2: str, fwd_masks, b=None) -> Optional[str]:
    """The naive default: the last forward-reached dataset that d2 also feeds."""
    candidates = [
        d for d, m in fwd_masks.items()
        if d != d1 and (m[b].any() if b is not None else m.any())
        and index.path_exists(d2, d)
    ]
    return candidates[-1] if candidates else None


def q10_co_contributory(
    index: ProvenanceIndex, d1: str, rows, d2: str, via: Optional[str] = None
):
    """Records of ``d2`` used together with ``rows`` of ``d1`` to create new
    records (in ``via``; defaults to any common descendant).  Deprecated shim
    — ``prov(index).source(d1).rows(...).co_contributory(d2, via=via)``."""
    from repro.provenance import prov

    _warn_deprecated("q10_co_contributory",
                     ".source(d1).rows(...).co_contributory(d2, via=via)")
    qb = prov(index).source(d1)
    qb = (qb.rows_batch(rows)
          if _legacy_probe_is_batch("q10_co_contributory", rows) else qb.rows(rows))
    return qb.co_contributory(d2, via=via).run()


def q11_co_dependency(
    index: ProvenanceIndex, d2: str, rows, d1: str, d3: str
):
    """Records of ``d3`` lineage-dependent on the ``d1`` records that
    generated ``rows`` of ``d2``.  Deprecated shim —
    ``prov(index).source(d2).rows(...).co_dependency(d1, d3)``."""
    from repro.provenance import prov

    _warn_deprecated("q11_co_dependency", ".source(d2).rows(...).co_dependency(d1, d3)")
    qb = prov(index).source(d2)
    qb = (qb.rows_batch(rows)
          if _legacy_probe_is_batch("q11_co_dependency", rows) else qb.rows(rows))
    return qb.co_dependency(d1, d3).run()
