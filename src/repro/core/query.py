"""Provenance query processing (paper Section IV, Table VII: Q1-Q11).

Record-level queries chain ``project(slice(T, p_in, rows), p_out)`` hops —
realized as batched CSR probes (the optimized representation of §III-C) —
over the topologically-ordered op DAG.  Attribute-level queries additionally
thread (row-set x attr-set) terms through the Table-VI bitset maps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.opcat import AttrMap, OpCategory
from repro.core.pipeline import OpRecord, ProvenanceIndex
from repro.core import schema as sc

__all__ = [
    "Hop",
    "forward_record_masks",
    "backward_record_masks",
    "q1_forward",
    "q2_backward",
    "q3_forward_attr",
    "q4_backward_attr",
    "q5_forward_how",
    "q6_backward_how",
    "q7_forward_attr_how",
    "q8_backward_attr_how",
    "q9_all_transformations",
    "q10_co_contributory",
    "q11_co_dependency",
]


@dataclasses.dataclass(frozen=True)
class Hop:
    """One op traversal — the *how* part of how-provenance (Q5-Q8)."""

    op_id: int
    op_name: str
    category: str
    src_dataset: str
    dst_dataset: str
    n_records: int


def _as_mask(rows, n: int) -> np.ndarray:
    if isinstance(rows, np.ndarray) and rows.dtype == bool:
        return rows
    m = np.zeros(n, dtype=bool)
    m[np.asarray(list(rows), dtype=np.int64)] = True
    return m


# ---------------------------------------------------------------------------
# Record-level propagation (Q1/Q2 cores)
# ---------------------------------------------------------------------------
def forward_record_masks(
    index: ProvenanceIndex, src: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    """Propagate a row mask from ``src`` to every reachable dataset."""
    masks: Dict[str, np.ndarray] = {src: _as_mask(rows, index.datasets[src].n_rows)}
    hops: List[Hop] = []
    for op in index.downstream_ops(src):
        out_n = op.tensor.n_out
        out_mask = masks.get(op.output_id, np.zeros(out_n, dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                contrib = op.tensor.forward_mask(k, masks[in_id])
                if collect_hops and contrib.any():
                    hops.append(
                        Hop(op.op_id, op.info.op_name, op.info.category.value,
                            in_id, op.output_id, int(contrib.sum()))
                    )
                out_mask |= contrib
        masks[op.output_id] = out_mask
    return masks, hops


def backward_record_masks(
    index: ProvenanceIndex, dst: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    masks: Dict[str, np.ndarray] = {dst: _as_mask(rows, index.datasets[dst].n_rows)}
    hops: List[Hop] = []
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask(k, masks[op.output_id])
            if collect_hops and contrib.any():
                hops.append(
                    Hop(op.op_id, op.info.op_name, op.info.category.value,
                        op.output_id, in_id, int(contrib.sum()))
                )
            prev = masks.get(in_id, np.zeros(index.datasets[in_id].n_rows, dtype=bool))
            masks[in_id] = prev | contrib
    return masks, hops


def q1_forward(index: ProvenanceIndex, src: str, rows, dst: str) -> np.ndarray:
    """Q1: records in ``dst`` derived from ``rows`` of ``src``."""
    masks, _ = forward_record_masks(index, src, rows)
    if dst not in masks:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(masks[dst])


def q2_backward(index: ProvenanceIndex, dst: str, rows, src: str) -> np.ndarray:
    """Q2: records in ``src`` that contributed to ``rows`` of ``dst``."""
    masks, _ = backward_record_masks(index, dst, rows)
    if src not in masks:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(masks[src])


def q5_forward_how(index: ProvenanceIndex, src: str, rows, dst: str):
    masks, hops = forward_record_masks(index, src, rows, collect_hops=True)
    recs = np.flatnonzero(masks[dst]) if dst in masks else np.zeros(0, dtype=np.int64)
    return recs, hops


def q6_backward_how(index: ProvenanceIndex, dst: str, rows, src: str):
    masks, hops = backward_record_masks(index, dst, rows, collect_hops=True)
    recs = np.flatnonzero(masks[src]) if src in masks else np.zeros(0, dtype=np.int64)
    return recs, hops


# ---------------------------------------------------------------------------
# Attribute maps (Table VI bitsets -> per-op attr propagation)
# ---------------------------------------------------------------------------
def _attrs_forward(amap: AttrMap, attrs: np.ndarray, n_out_attrs: int) -> np.ndarray:
    """Map an input-attr mask to the output-attr mask through one op input."""
    out = np.zeros(n_out_attrs, dtype=bool)
    src = np.flatnonzero(attrs)
    if amap.kind == "identity":
        valid = src[src < n_out_attrs]
        out[valid] = True
        return out
    if amap.kind == "vreduce":
        b = amap.bitset
        if amap.perm is not None:  # order-changing fallback (paper: int list)
            for j, a in enumerate(amap.perm):
                if attrs[a]:
                    out[j] = True
            return out
        for a in src:
            j = sc.map_vr_f(b, int(a))
            if j is not None:
                out[j] = True
        return out
    if amap.kind == "vaugment":
        b, m = amap.bitset, amap.m
        new_attrs = [j for j in range(m, b.n) if b.test(j)]
        for a in src:
            out[sc.map_va_f(m, int(a))] = True           # preserved position
            if a < m and b.test(int(a)):                  # engineered features
                for j in new_attrs:
                    out[j] = True
        return out
    if amap.kind == "join":
        if amap.perm is not None:
            for j, a in enumerate(amap.perm):
                if a >= 0 and attrs[a]:
                    out[j] = True
            return out
        for a in src:
            j = sc.map_join_f(amap.bitset, int(a))
            if j is not None:
                out[j] = True
        return out
    raise ValueError(amap.kind)


def _attrs_backward(amap: AttrMap, attrs: np.ndarray, n_in_attrs: int) -> np.ndarray:
    out = np.zeros(n_in_attrs, dtype=bool)
    src = np.flatnonzero(attrs)
    if amap.kind == "identity":
        valid = src[src < n_in_attrs]
        out[valid] = True
        return out
    if amap.kind == "vreduce":
        if amap.perm is not None:
            for j in src:
                out[amap.perm[j]] = True
            return out
        for j in src:
            out[sc.map_vr_b(amap.bitset, int(j))] = True
        return out
    if amap.kind == "vaugment":
        for j in src:
            for a in sc.map_va_b(amap.bitset, amap.m, int(j)):
                out[a] = True
        return out
    if amap.kind == "join":
        if amap.perm is not None:
            for j in src:
                if amap.perm[j] >= 0:
                    out[amap.perm[j]] = True
            return out
        for j in src:
            a = sc.map_join_b(amap.bitset, int(j))
            if a is not None:
                out[a] = True
        return out
    raise ValueError(amap.kind)


# ---------------------------------------------------------------------------
# Attribute-level queries (Q3/Q4/Q7/Q8): (row-mask, attr-mask) terms
# ---------------------------------------------------------------------------
def _attr_propagate(
    index: ProvenanceIndex, start: str, rows, attrs, direction: str,
    collect_hops: bool = False,
):
    ds0 = index.datasets[start]
    terms: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
        start: [(_as_mask(rows, ds0.n_rows), _as_mask(attrs, ds0.n_cols))]
    }
    hops: List[Hop] = []
    ops = (
        index.downstream_ops(start)
        if direction == "fwd"
        else list(reversed(index.upstream_ops(start)))
    )
    for op in ops:
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                for (rm, am) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask(k, rm)
                    new_am = _attrs_forward(op.info.attr_maps[k], am, out_ds.n_cols)
                    if new_rm.any() and new_am.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_am))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, in_id,
                                            op.output_id, int(new_rm.sum())))
        else:
            for (rm, am) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    new_rm = op.tensor.backward_mask(k, rm)
                    new_am = _attrs_backward(op.info.attr_maps[k], am, in_ds.n_cols)
                    if new_rm.any() and new_am.any():
                        terms.setdefault(in_id, []).append((new_rm, new_am))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, op.output_id,
                                            in_id, int(new_rm.sum())))
    return terms, hops


def _cells(terms: List[Tuple[np.ndarray, np.ndarray]]) -> np.ndarray:
    """Union of (rows x attrs) products -> (n, 2) sorted unique cell list."""
    cells = set()
    for rm, am in terms:
        rs, as_ = np.flatnonzero(rm), np.flatnonzero(am)
        for r in rs:
            for a in as_:
                cells.add((int(r), int(a)))
    return np.array(sorted(cells), dtype=np.int64).reshape(-1, 2)


def q3_forward_attr(index, src: str, rows, attrs, dst: str) -> np.ndarray:
    """Q3: attribute values (cells) of ``dst`` derived from the given cells."""
    terms, _ = _attr_propagate(index, src, rows, attrs, "fwd")
    return _cells(terms.get(dst, []))


def q4_backward_attr(index, dst: str, rows, attrs, src: str) -> np.ndarray:
    terms, _ = _attr_propagate(index, dst, rows, attrs, "bwd")
    return _cells(terms.get(src, []))


def q7_forward_attr_how(index, src: str, rows, attrs, dst: str):
    terms, hops = _attr_propagate(index, src, rows, attrs, "fwd", collect_hops=True)
    return _cells(terms.get(dst, [])), hops


def q8_backward_attr_how(index, dst: str, rows, attrs, src: str):
    terms, hops = _attr_propagate(index, dst, rows, attrs, "bwd", collect_hops=True)
    return _cells(terms.get(src, [])), hops


# ---------------------------------------------------------------------------
# Q9: all transformations applied to a dataset (metadata only — no tensors)
# ---------------------------------------------------------------------------
def q9_all_transformations(index: ProvenanceIndex, dataset: str) -> List[Dict]:
    return [
        {
            "op_id": op.op_id,
            "op": op.info.op_name,
            "category": op.info.category.value,
            "contextual": op.info.contextual,
            "inputs": op.input_ids,
            "output": op.output_id,
        }
        for op in index.upstream_ops(dataset)
    ]


# ---------------------------------------------------------------------------
# Q10/Q11: co-contributory and co-dependency (forward + backward combos)
# ---------------------------------------------------------------------------
def q10_co_contributory(
    index: ProvenanceIndex, d1: str, rows, d2: str, via: Optional[str] = None
) -> np.ndarray:
    """Records of ``d2`` used together with ``rows`` of ``d1`` to create new
    records (in ``via``; defaults to any common descendant)."""
    fwd_masks, _ = forward_record_masks(index, d1, rows)
    if via is None:
        candidates = [
            d for d, m in fwd_masks.items()
            if d != d1 and m.any() and index.path_exists(d2, d)
        ]
        if not candidates:
            return np.zeros(0, dtype=np.int64)
        via = candidates[-1]
    if via not in fwd_masks or not fwd_masks[via].any():
        return np.zeros(0, dtype=np.int64)
    back, _ = backward_record_masks(index, via, fwd_masks[via])
    if d2 not in back:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(back[d2])


def q11_co_dependency(
    index: ProvenanceIndex, d2: str, rows, d1: str, d3: str
) -> np.ndarray:
    """Records of ``d3`` lineage-dependent on the ``d1`` records that
    generated ``rows`` of ``d2``."""
    back, _ = backward_record_masks(index, d2, rows)
    if d1 not in back or not back[d1].any():
        return np.zeros(0, dtype=np.int64)
    fwd, _ = forward_record_masks(index, d1, back[d1])
    if d3 not in fwd:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(fwd[d3])
