"""Provenance query processing (paper Section IV, Table VII: Q1-Q11).

Record-level queries chain ``project(slice(T, p_in, rows), p_out)`` hops —
realized as batched CSR probes (the optimized representation of §III-C) —
over the topologically-ordered op DAG.  Attribute-level queries additionally
thread (row-set x attr-set) terms through the Table-VI bitset maps.

This engine is fully array-vectorized:

* attribute masks travel PACKED (uint32 words, 32 attrs per lane) and advance
  through an op via one select-OR contraction against the op's memoized
  attribute bitplane (:meth:`AttrMap.fwd_plane` / ``bwd_plane``) — no
  per-attribute rank/select dispatch;
* ``_cells`` materializes the union of (row-set × attr-set) products as a
  broadcasted outer product over packed masks, then one ``argwhere``;
* every public query accepts EITHER one probe set OR a batch (a list of probe
  sets / a 2-D boolean mask stack) and answers the batch in one pass — the
  per-op CSR gather covers all batch elements with a single ragged gather
  (:meth:`CSR.neighbor_mask_many`).

Multi-hop batched probes can additionally skip the per-op walk entirely via
the composed hop-cache (:mod:`repro.core.hopcache`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.opcat import AttrMap
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    bitplane_or_reduce,
    pack_bitplane,
    pack_mask,
    unpack_bitplane,
)

__all__ = [
    "Hop",
    "forward_record_masks",
    "backward_record_masks",
    "forward_record_masks_batch",
    "backward_record_masks_batch",
    "q1_forward",
    "q2_backward",
    "q3_forward_attr",
    "q4_backward_attr",
    "q5_forward_how",
    "q6_backward_how",
    "q7_forward_attr_how",
    "q8_backward_attr_how",
    "q9_all_transformations",
    "q10_co_contributory",
    "q11_co_dependency",
]


@dataclasses.dataclass(frozen=True)
class Hop:
    """One op traversal — the *how* part of how-provenance (Q5-Q8)."""

    op_id: int
    op_name: str
    category: str
    src_dataset: str
    dst_dataset: str
    n_records: int


# ---------------------------------------------------------------------------
# Probe normalization: single probe vs batch of probes
# ---------------------------------------------------------------------------
def _as_mask(rows, n: int) -> np.ndarray:
    if isinstance(rows, np.ndarray) and rows.dtype == bool:
        return rows
    m = np.zeros(n, dtype=bool)
    m[np.asarray(list(rows), dtype=np.int64)] = True
    return m


def is_probe_batch(rows) -> bool:
    """A batch is a 2-D mask stack or a non-empty list/tuple of probe sets."""
    if isinstance(rows, np.ndarray):
        return rows.ndim == 2
    if isinstance(rows, (list, tuple)):
        return len(rows) > 0 and all(
            isinstance(r, (list, tuple, np.ndarray, set, frozenset, range))
            for r in rows
        )
    return False


def _as_mask_batch(rows_batch, n: int) -> np.ndarray:
    if isinstance(rows_batch, np.ndarray) and rows_batch.ndim == 2:
        if rows_batch.dtype == bool:
            return rows_batch
        out = np.zeros((rows_batch.shape[0], n), dtype=bool)
        out[np.arange(rows_batch.shape[0])[:, None], rows_batch.astype(np.int64)] = True
        return out
    return np.stack([_as_mask(r, n) for r in rows_batch], axis=0)


def _empty_rows() -> np.ndarray:
    return np.zeros(0, dtype=np.int64)


# ---------------------------------------------------------------------------
# Record-level propagation (Q1/Q2 cores)
# ---------------------------------------------------------------------------
def forward_record_masks(
    index: ProvenanceIndex, src: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    """Propagate a row mask from ``src`` to every reachable dataset."""
    masks: Dict[str, np.ndarray] = {src: _as_mask(rows, index.datasets[src].n_rows)}
    hops: List[Hop] = []
    for op in index.downstream_ops(src):
        out_n = op.tensor.n_out
        out_mask = masks.get(op.output_id, np.zeros(out_n, dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                contrib = op.tensor.forward_mask(k, masks[in_id])
                if collect_hops and contrib.any():
                    hops.append(
                        Hop(op.op_id, op.info.op_name, op.info.category.value,
                            in_id, op.output_id, int(contrib.sum()))
                    )
                out_mask |= contrib
        masks[op.output_id] = out_mask
    return masks, hops


def backward_record_masks(
    index: ProvenanceIndex, dst: str, rows, collect_hops: bool = False
) -> Tuple[Dict[str, np.ndarray], List[Hop]]:
    masks: Dict[str, np.ndarray] = {dst: _as_mask(rows, index.datasets[dst].n_rows)}
    hops: List[Hop] = []
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask(k, masks[op.output_id])
            if collect_hops and contrib.any():
                hops.append(
                    Hop(op.op_id, op.info.op_name, op.info.category.value,
                        op.output_id, in_id, int(contrib.sum()))
                )
            prev = masks.get(in_id, np.zeros(index.datasets[in_id].n_rows, dtype=bool))
            masks[in_id] = prev | contrib
    return masks, hops


def forward_record_masks_batch(
    index: ProvenanceIndex, src: str, rows_batch
) -> Dict[str, np.ndarray]:
    """Batched :func:`forward_record_masks`: every value is (B, n_rows) bool.

    One pass over the op DAG answers all B probes — each hop is a single
    batched CSR gather, not B sequential walks.
    """
    stack = _as_mask_batch(rows_batch, index.datasets[src].n_rows)
    masks: Dict[str, np.ndarray] = {src: stack}
    B = stack.shape[0]
    for op in index.downstream_ops(src):
        out_mask = masks.get(op.output_id, np.zeros((B, op.tensor.n_out), dtype=bool))
        for k, in_id in enumerate(op.input_ids):
            if in_id in masks and masks[in_id].any():
                out_mask = out_mask | op.tensor.forward_mask_batch(k, masks[in_id])
        masks[op.output_id] = out_mask
    return masks


def backward_record_masks_batch(
    index: ProvenanceIndex, dst: str, rows_batch
) -> Dict[str, np.ndarray]:
    stack = _as_mask_batch(rows_batch, index.datasets[dst].n_rows)
    masks: Dict[str, np.ndarray] = {dst: stack}
    B = stack.shape[0]
    for op in reversed(index.upstream_ops(dst)):
        if op.output_id not in masks or not masks[op.output_id].any():
            continue
        for k, in_id in enumerate(op.input_ids):
            contrib = op.tensor.backward_mask_batch(k, masks[op.output_id])
            prev = masks.get(
                in_id, np.zeros((B, index.datasets[in_id].n_rows), dtype=bool)
            )
            masks[in_id] = prev | contrib
    return masks


def q1_forward(index: ProvenanceIndex, src: str, rows, dst: str):
    """Q1: records in ``dst`` derived from ``rows`` of ``src``.

    ``rows`` may be one probe set or a batch (list of sets); a batch returns
    a list of index arrays, answered in one vectorized pass.
    """
    if is_probe_batch(rows):
        masks = forward_record_masks_batch(index, src, rows)
        B = len(rows) if not isinstance(rows, np.ndarray) else rows.shape[0]
        if dst not in masks:
            return [_empty_rows() for _ in range(B)]
        return [np.flatnonzero(m) for m in masks[dst]]
    masks, _ = forward_record_masks(index, src, rows)
    if dst not in masks:
        return _empty_rows()
    return np.flatnonzero(masks[dst])


def q2_backward(index: ProvenanceIndex, dst: str, rows, src: str):
    """Q2: records in ``src`` that contributed to ``rows`` of ``dst``."""
    if is_probe_batch(rows):
        masks = backward_record_masks_batch(index, dst, rows)
        B = len(rows) if not isinstance(rows, np.ndarray) else rows.shape[0]
        if src not in masks:
            return [_empty_rows() for _ in range(B)]
        return [np.flatnonzero(m) for m in masks[src]]
    masks, _ = backward_record_masks(index, dst, rows)
    if src not in masks:
        return _empty_rows()
    return np.flatnonzero(masks[src])


def q5_forward_how(index: ProvenanceIndex, src: str, rows, dst: str):
    masks, hops = forward_record_masks(index, src, rows, collect_hops=True)
    recs = np.flatnonzero(masks[dst]) if dst in masks else _empty_rows()
    return recs, hops


def q6_backward_how(index: ProvenanceIndex, dst: str, rows, src: str):
    masks, hops = backward_record_masks(index, dst, rows, collect_hops=True)
    recs = np.flatnonzero(masks[src]) if src in masks else _empty_rows()
    return recs, hops


# ---------------------------------------------------------------------------
# Attribute maps (Table VI bitsets -> per-op attr propagation)
#
# An attr mask is PACKED uint32 words; one op hop is a select-OR contraction
# of the packed mask against the op's memoized attribute bitplane.
# ---------------------------------------------------------------------------
def _attrs_forward(amap: AttrMap, attrs: np.ndarray, n_out_attrs: int) -> np.ndarray:
    """Map an input-attr mask to the output-attr mask through one op input."""
    attrs = np.asarray(attrs, dtype=bool)
    plane = amap.fwd_plane(attrs.shape[0], n_out_attrs)
    words = bitplane_or_reduce(pack_mask(attrs)[None, :], plane, attrs.shape[0])
    return unpack_bitplane(words, n_out_attrs)[0]


def _attrs_backward(amap: AttrMap, attrs: np.ndarray, n_in_attrs: int) -> np.ndarray:
    attrs = np.asarray(attrs, dtype=bool)
    plane = amap.bwd_plane(n_in_attrs, attrs.shape[0])
    words = bitplane_or_reduce(pack_mask(attrs)[None, :], plane, attrs.shape[0])
    return unpack_bitplane(words, n_in_attrs)[0]


# ---------------------------------------------------------------------------
# Attribute-level queries (Q3/Q4/Q7/Q8): (row-mask, packed-attr-words) terms
# ---------------------------------------------------------------------------
def _attr_propagate(
    index: ProvenanceIndex, start: str, rows, attrs, direction: str,
    collect_hops: bool = False,
):
    ds0 = index.datasets[start]
    terms: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
        start: [(_as_mask(rows, ds0.n_rows), pack_mask(_as_mask(attrs, ds0.n_cols)))]
    }
    hops: List[Hop] = []
    ops = (
        index.downstream_ops(start)
        if direction == "fwd"
        else list(reversed(index.upstream_ops(start)))
    )
    for op in ops:
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                in_ds = index.datasets[in_id]
                plane = op.info.attr_maps[k].fwd_plane(in_ds.n_cols, out_ds.n_cols)
                for (rm, aw) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask(k, rm)
                    new_aw = bitplane_or_reduce(aw[None, :], plane, in_ds.n_cols)[0]
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, in_id,
                                            op.output_id, int(new_rm.sum())))
        else:
            for (rm, aw) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    plane = op.info.attr_maps[k].bwd_plane(in_ds.n_cols, out_ds.n_cols)
                    new_rm = op.tensor.backward_mask(k, rm)
                    new_aw = bitplane_or_reduce(aw[None, :], plane, out_ds.n_cols)[0]
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(in_id, []).append((new_rm, new_aw))
                        if collect_hops:
                            hops.append(Hop(op.op_id, op.info.op_name,
                                            op.info.category.value, op.output_id,
                                            in_id, int(new_rm.sum())))
    return terms, hops


def _attr_propagate_batch(
    index: ProvenanceIndex, start: str, rows_batch, attrs_batch, direction: str
):
    """Batched term propagation: every term is ((B, n_rows) bool, (B, nw) u32).

    A term stays alive while ANY batch element is non-empty; per-element
    emptiness zeroes that element's masks, which contributes nothing to the
    final outer product — exactly the single-probe pruning, batched.
    """
    ds0 = index.datasets[start]
    rm0 = _as_mask_batch(rows_batch, ds0.n_rows)
    B = rm0.shape[0]
    am0 = _as_mask_batch(attrs_batch, ds0.n_cols) if is_probe_batch(attrs_batch) \
        else np.broadcast_to(_as_mask(attrs_batch, ds0.n_cols), (B, ds0.n_cols))
    terms: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {
        start: [(rm0, pack_bitplane(am0))]
    }
    ops = (
        index.downstream_ops(start)
        if direction == "fwd"
        else list(reversed(index.upstream_ops(start)))
    )
    for op in ops:
        out_ds = index.datasets[op.output_id]
        if direction == "fwd":
            for k, in_id in enumerate(op.input_ids):
                in_ds = index.datasets[in_id]
                plane = op.info.attr_maps[k].fwd_plane(in_ds.n_cols, out_ds.n_cols)
                for (rm, aw) in terms.get(in_id, []):
                    if not rm.any():
                        continue
                    new_rm = op.tensor.forward_mask_batch(k, rm)
                    new_aw = bitplane_or_reduce(aw, plane, in_ds.n_cols)
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(op.output_id, []).append((new_rm, new_aw))
        else:
            for (rm, aw) in terms.get(op.output_id, []):
                if not rm.any():
                    continue
                for k, in_id in enumerate(op.input_ids):
                    in_ds = index.datasets[in_id]
                    plane = op.info.attr_maps[k].bwd_plane(in_ds.n_cols, out_ds.n_cols)
                    new_rm = op.tensor.backward_mask_batch(k, rm)
                    new_aw = bitplane_or_reduce(aw, plane, out_ds.n_cols)
                    if new_rm.any() and new_aw.any():
                        terms.setdefault(in_id, []).append((new_rm, new_aw))
    return terms, B


def _cells(
    terms: List[Tuple[np.ndarray, np.ndarray]], n_rows: int, n_cols: int
) -> np.ndarray:
    """Union of (rows × attrs) products -> (n, 2) sorted unique cell list.

    Broadcasted outer product on PACKED attr words: scatter each term's packed
    attr mask into the rows its row-mask selects, then unpack once."""
    nw = max((n_cols + 31) // 32, 1)
    acc = np.zeros((n_rows, nw), dtype=np.uint32)
    for rm, aw in terms:
        acc[rm] |= aw[None, :]
    return np.argwhere(unpack_bitplane(acc, n_cols)).astype(np.int64)


def _cells_batch(
    terms: List[Tuple[np.ndarray, np.ndarray]], B: int, n_rows: int, n_cols: int
) -> List[np.ndarray]:
    nw = max((n_cols + 31) // 32, 1)
    acc = np.zeros((B, n_rows, nw), dtype=np.uint32)
    for rm, aw in terms:
        acc |= np.where(rm[:, :, None], aw[:, None, :], np.uint32(0))
    return [np.argwhere(unpack_bitplane(acc[b], n_cols)).astype(np.int64)
            for b in range(B)]


def q3_forward_attr(index, src: str, rows, attrs, dst: str):
    """Q3: attribute values (cells) of ``dst`` derived from the given cells.

    Batched when ``rows`` (and optionally ``attrs``) is a list of probe sets:
    returns one cell list per probe."""
    out_ds = index.datasets[dst]
    if is_probe_batch(rows):
        terms, B = _attr_propagate_batch(index, src, rows, attrs, "fwd")
        return _cells_batch(terms.get(dst, []), B, out_ds.n_rows, out_ds.n_cols)
    terms, _ = _attr_propagate(index, src, rows, attrs, "fwd")
    return _cells(terms.get(dst, []), out_ds.n_rows, out_ds.n_cols)


def q4_backward_attr(index, dst: str, rows, attrs, src: str):
    src_ds = index.datasets[src]
    if is_probe_batch(rows):
        terms, B = _attr_propagate_batch(index, dst, rows, attrs, "bwd")
        return _cells_batch(terms.get(src, []), B, src_ds.n_rows, src_ds.n_cols)
    terms, _ = _attr_propagate(index, dst, rows, attrs, "bwd")
    return _cells(terms.get(src, []), src_ds.n_rows, src_ds.n_cols)


def q7_forward_attr_how(index, src: str, rows, attrs, dst: str):
    terms, hops = _attr_propagate(index, src, rows, attrs, "fwd", collect_hops=True)
    out_ds = index.datasets[dst]
    return _cells(terms.get(dst, []), out_ds.n_rows, out_ds.n_cols), hops


def q8_backward_attr_how(index, dst: str, rows, attrs, src: str):
    terms, hops = _attr_propagate(index, dst, rows, attrs, "bwd", collect_hops=True)
    src_ds = index.datasets[src]
    return _cells(terms.get(src, []), src_ds.n_rows, src_ds.n_cols), hops


# ---------------------------------------------------------------------------
# Q9: all transformations applied to a dataset (metadata only — no tensors)
# ---------------------------------------------------------------------------
def q9_all_transformations(index: ProvenanceIndex, dataset: str) -> List[Dict]:
    return [
        {
            "op_id": op.op_id,
            "op": op.info.op_name,
            "category": op.info.category.value,
            "contextual": op.info.contextual,
            "inputs": op.input_ids,
            "output": op.output_id,
        }
        for op in index.upstream_ops(dataset)
    ]


# ---------------------------------------------------------------------------
# Q10/Q11: co-contributory and co-dependency (forward + backward combos)
# ---------------------------------------------------------------------------
def _pick_via(index: ProvenanceIndex, d1: str, d2: str, fwd_masks, b=None) -> Optional[str]:
    """The naive default: the last forward-reached dataset that d2 also feeds."""
    candidates = [
        d for d, m in fwd_masks.items()
        if d != d1 and (m[b].any() if b is not None else m.any())
        and index.path_exists(d2, d)
    ]
    return candidates[-1] if candidates else None


def q10_co_contributory(
    index: ProvenanceIndex, d1: str, rows, d2: str, via: Optional[str] = None
):
    """Records of ``d2`` used together with ``rows`` of ``d1`` to create new
    records (in ``via``; defaults to any common descendant)."""
    if is_probe_batch(rows):
        return _q10_batch(index, d1, rows, d2, via)
    fwd_masks, _ = forward_record_masks(index, d1, rows)
    if via is None:
        via = _pick_via(index, d1, d2, fwd_masks)
        if via is None:
            return _empty_rows()
    if via not in fwd_masks or not fwd_masks[via].any():
        return _empty_rows()
    back, _ = backward_record_masks(index, via, fwd_masks[via])
    if d2 not in back:
        return _empty_rows()
    return np.flatnonzero(back[d2])


def _q10_batch(index, d1, rows_batch, d2, via):
    fwd = forward_record_masks_batch(index, d1, rows_batch)
    B = fwd[d1].shape[0]
    results: List[np.ndarray] = [_empty_rows()] * B
    # group probes by their (possibly per-probe) via dataset, batch each group
    groups: Dict[str, List[int]] = {}
    for b in range(B):
        v = via if via is not None else _pick_via(index, d1, d2, fwd, b)
        if v is None or v not in fwd or not fwd[v][b].any():
            continue
        groups.setdefault(v, []).append(b)
    for v, bs in groups.items():
        back = backward_record_masks_batch(index, v, fwd[v][bs])
        if d2 not in back:
            continue
        for i, b in enumerate(bs):
            results[b] = np.flatnonzero(back[d2][i])
    return results


def q11_co_dependency(
    index: ProvenanceIndex, d2: str, rows, d1: str, d3: str
):
    """Records of ``d3`` lineage-dependent on the ``d1`` records that
    generated ``rows`` of ``d2``."""
    if is_probe_batch(rows):
        back = backward_record_masks_batch(index, d2, rows)
        B = back[d2].shape[0]
        if d1 not in back or not back[d1].any():
            return [_empty_rows() for _ in range(B)]
        fwd = forward_record_masks_batch(index, d1, back[d1])
        if d3 not in fwd:
            return [_empty_rows() for _ in range(B)]
        return [np.flatnonzero(m) for m in fwd[d3]]
    back, _ = backward_record_masks(index, d2, rows)
    if d1 not in back or not back[d1].any():
        return _empty_rows()
    fwd, _ = forward_record_masks(index, d1, back[d1])
    if d3 not in fwd:
        return _empty_rows()
    return np.flatnonzero(fwd[d3])
