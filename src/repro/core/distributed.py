"""Mesh-sharded provenance index — 'in-memory' generalized to 'in-HBM'.

The paper's premise is an index resident in the memory of one development
machine.  At pod scale the training data (and therefore its provenance
relations) are sharded; this module keeps the SAME tensor algebra but lays
the packed relation bitplanes out over the device mesh:

* a relation R (n_src × n_dst bits, packed to (n_src, ceil(n_dst/32)) uint32)
  is sharded by SOURCE ROWS across the ("pod", "data") axes — each data shard
  owns the lineage of the records it feeds to training;
* composition (R1 · R2) is a LOCAL boolean matmul per shard: R1's row shard
  contracts against the full R2, which is all-gathered in WORD-packed form
  (32x smaller than the boolean operand — this is why bitplanes, not masks,
  cross the ICI);
* dataset-level audits (the paper's fairness / consent example) are a local
  popcount + ``psum`` — one scalar vector crosses the mesh, never records.

Everything here is shard_map'd jax; the host-resident ProvenanceIndex hands
over packed numpy bitplanes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
else:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.kernels import ref as kref

__all__ = [
    "shard_relation",
    "compose_sharded",
    "lineage_audit_sharded",
    "backward_frontier_sharded",
]


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The axes provenance rows shard over: ('pod','data') when present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_relation(bits: np.ndarray, mesh: Mesh) -> jax.Array:
    """Place a packed (rows, words) relation with rows sharded over the data
    axes (rows padded up to the shard multiple)."""
    axes = _data_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    r, w = bits.shape
    pad = (-r) % n_shards
    if pad:
        bits = np.pad(bits, ((0, pad), (0, 0)))
    spec = P(axes if axes else None, None)
    return jax.device_put(jnp.asarray(bits, jnp.uint32), NamedSharding(mesh, spec))


def compose_sharded(a_bits: jax.Array, b_bits: jax.Array, mesh: Mesh) -> jax.Array:
    """C = A·B over the (OR,AND) semiring; A row-sharded, B row-sharded.

    B's rows are A's contraction dim: the local matmul needs ALL of B, so B is
    all-gathered in packed (uint32) form — 1/32 the bytes of a boolean gather.
    Output C inherits A's row sharding (no re-shard, no output collective).
    """
    axes = _data_axes(mesh)
    if not axes:
        return _bitmm(a_bits, b_bits)

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None)),
        out_specs=P(axes, None),
    )
    def _kernel(a_shard, b_shard):
        b_full = jax.lax.all_gather(b_shard, axes, axis=0, tiled=True)
        return _bitmm(a_shard, b_full)

    return _kernel(a_bits, b_bits)


def _bitmm(a_bits: jax.Array, b_bits: jax.Array) -> jax.Array:
    """(OR,AND) matmul on packed operands, jnp path (Pallas on real TPU via
    repro.kernels.ops.bitmatmul; the jnp form lowers on any backend and is
    what the dry-run compiles)."""
    m, kw = a_bits.shape
    k, nw = b_bits.shape
    a = kref.unpack_bits(a_bits, kw * 32)[:, :k].astype(jnp.float32)  # (m, k)
    b = kref.unpack_bits(b_bits, nw * 32).astype(jnp.float32)          # (k, n)
    c = (a @ b) > 0
    return kref.pack_bits(c)


def lineage_audit_sharded(
    rel_bits: jax.Array,
    group: jax.Array,
    dst_mask_words: jax.Array,
    n_groups: int,
    mesh: Optional[Mesh] = None,
) -> jax.Array:
    """The paper's dataset-level audit, sharded.

    For each source-row group g (e.g. gender value), count source rows of
    group g that contributed to ANY selected output record:

        hits[i] = OR_w popcount(rel[i, w] & dst_mask[w]) > 0
        out[g]  = sum_i hits[i] * [group[i] == g]

    ``rel_bits`` row-sharded; ``group`` row-aligned int32; ``dst_mask_words``
    packed output-row selector, replicated.  Result: (n_groups,) int32,
    identical on all devices (psum).
    """
    if mesh is None or not _data_axes(mesh):
        return _audit_local(rel_bits, group, dst_mask_words, n_groups)
    axes = _data_axes(mesh)

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes), P(None)),
        out_specs=P(),
    )
    def _kernel(rel_shard, group_shard, mask_words):
        local = _audit_local(rel_shard, group_shard, mask_words, n_groups)
        return jax.lax.psum(local, axes)

    return _kernel(rel_bits, group, dst_mask_words)


def _audit_local(rel_bits, group, mask_words, n_groups: int):
    hit_words = rel_bits & mask_words[None, :]
    hits = jax.lax.population_count(hit_words).astype(jnp.int32).sum(axis=1) > 0
    onehot = jax.nn.one_hot(group, n_groups, dtype=jnp.int32)
    return (hits.astype(jnp.int32)[:, None] * onehot).sum(axis=0)


def backward_frontier_sharded(
    rel_bits: jax.Array,
    dst_mask_words: jax.Array,
    mesh: Optional[Mesh] = None,
) -> jax.Array:
    """Backward record lineage at dataset scale: which SOURCE rows reach any
    selected output record.  Local AND+popcount per shard; the result mask is
    row-aligned with the shard — no collective at all (owner-computes)."""
    def _local(rel_shard, mask_words):
        hit_words = rel_shard & mask_words[None, :]
        return jax.lax.population_count(hit_words).astype(jnp.int32).sum(axis=1) > 0

    if mesh is None or not _data_axes(mesh):
        return _local(rel_bits, dst_mask_words)
    axes = _data_axes(mesh)

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P(None)),
        out_specs=P(axes),
    )
    def _kernel(rel_shard, mask_words):
        return _local(rel_shard, mask_words)

    return _kernel(rel_bits, dst_mask_words)
