"""Hybrid provenance capture (paper §III-B): CaptureInfo -> ProvTensor.

The *hybrid* strategy is realized in :mod:`repro.dataprep.ops`: index-
preserving ops carry their kept-row lists straight out of the operation's own
semantics (observation over preserved dataframe indices), while the join
threads row-ids through the merge (active capture).  This module only turns
those payloads into the tensors of §III-A — no content diffing anywhere.

Capture emits STRUCTURED tensors by default: identity categories become a
:class:`~repro.core.provtensor.SlotIdentity` scalar, horizontal ops wrap the
capture payload (``kept_rows`` / ``src_rows`` / ``join_pairs``) as gather
slots, append becomes two block offsets — the explicit ``(nnz, 1+k)`` COO is
never allocated on this path (it stays available as a lazy mirror).  Only
the multi-parent ``links`` payload still builds a raw COO.

:func:`force_coo_capture` switches the legacy eager-COO construction back on
for a scope — the parity suite and the memory/capture benches use it to pin
byte-identical answers and before/after footprints.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import numpy as np

from repro.core.opcat import CaptureInfo, IDENTITY_CATEGORIES, OpCategory
from repro.core.provtensor import (
    ProvTensor,
    append_tensor,
    haugment_tensor,
    hreduce_tensor,
    identity_tensor,
    join_tensor,
)

__all__ = [
    "build_tensor",
    "force_coo_capture",
    "structured_capture_enabled",
    "strip_payload",
    "restore_payload",
]

_structured_stack = [True]


def structured_capture_enabled() -> bool:
    """Whether :func:`build_tensor` currently emits structured tensors."""
    return _structured_stack[-1]


@contextlib.contextmanager
def force_coo_capture() -> Iterator[None]:
    """Scope under which capture builds legacy explicit-COO tensors.

    Baselines only: the parity suite records each random pipeline twice
    (structured vs forced COO) and pins byte-identical query answers; the
    Table-IX / Fig-3 benches use it for the before/after columns."""
    _structured_stack.append(False)
    try:
        yield
    finally:
        _structured_stack.pop()


def build_tensor(info: CaptureInfo, structured: Optional[bool] = None) -> ProvTensor:
    if structured is None:
        structured = _structured_stack[-1]
    cat = info.category
    if cat in IDENTITY_CATEGORIES:
        # transformation / vertical reduction / vertical augmentation:
        # 2-D binary identity tensor (paper §III-A a, b, d)
        if info.n_out != info.n_in[0]:
            raise ValueError(f"{info.op_name}: identity category but n_out != n_in")
        return identity_tensor(info.n_out, structured=structured)
    if cat is OpCategory.HREDUCE:
        if info.kept_rows is None:
            raise ValueError(f"{info.op_name}: HREDUCE needs kept_rows")
        return hreduce_tensor(info.kept_rows, info.n_in[0], structured=structured)
    if cat is OpCategory.HAUGMENT:
        if info.links is not None:
            # multi-parent augmentation (sequence packing et al.): raw COO
            return ProvTensor(n_out=info.n_out, n_in=(info.n_in[0],),
                              coo=np.asarray(info.links, dtype=np.int32))
        if info.src_rows is None:
            raise ValueError(f"{info.op_name}: HAUGMENT needs src_rows or links")
        return haugment_tensor(info.src_rows, info.n_in[0], structured=structured)
    if cat is OpCategory.JOIN:
        if info.join_pairs is None:
            raise ValueError(f"{info.op_name}: JOIN needs join_pairs")
        return join_tensor(info.join_pairs, info.n_in[0], info.n_in[1],
                           structured=structured)
    if cat is OpCategory.APPEND:
        return append_tensor(info.n_in[0], info.n_in[1], structured=structured)
    raise ValueError(f"unknown category {cat}")


# ---------------------------------------------------------------------------
# Spill-tier payload stripping (repro.core.spill.TensorSpiller)
# ---------------------------------------------------------------------------
def _slot_column(tensor: ProvTensor, slot: int) -> np.ndarray:
    g = tensor.slot_gather(slot)
    return g if g is not None else tensor.coo[:, 1 + slot]


def strip_payload(info: CaptureInfo) -> None:
    """Drop the capture payload arrays off ``info`` when the op's tensor is
    spilled.  The structured slots hold these very arrays BY REFERENCE
    (``kept_rows`` IS the gather slot's payload), so spilling the tensor
    frees nothing while the info-side alias survives.  Which fields were
    stripped is remembered on the record so :func:`restore_payload` puts
    back exactly what existed — a COO HAUGMENT tensor alone cannot tell a
    stripped ``src_rows`` from stripped multi-parent ``links``."""
    stripped = []
    for field in ("kept_rows", "src_rows", "join_pairs", "links"):
        if getattr(info, field) is not None:
            setattr(info, field, None)
            stripped.append(field)
    info._spill_stripped = tuple(stripped)


def restore_payload(info: CaptureInfo, tensor: ProvTensor) -> None:
    """Inverse of :func:`strip_payload`, reconstructing the payload fields
    from a rehydrated tensor (memmap-backed arrays are adopted as-is).
    Round-trips value-identical: the tensor constructors stored these exact
    arrays per slot at capture time."""
    stripped = getattr(info, "_spill_stripped", ())
    for field in stripped:
        if field == "kept_rows":
            info.kept_rows = _slot_column(tensor, 0)
        elif field == "src_rows":
            info.src_rows = _slot_column(tensor, 0)
        elif field == "join_pairs":
            g0 = tensor.slot_gather(0)
            if g0 is not None:
                info.join_pairs = np.stack([g0, tensor.slot_gather(1)], axis=1)
            else:
                info.join_pairs = tensor.coo[:, 1:3]
        elif field == "links":
            info.links = tensor.coo
    info._spill_stripped = ()
