"""Hybrid provenance capture (paper §III-B): CaptureInfo -> ProvTensor.

The *hybrid* strategy is realized in :mod:`repro.dataprep.ops`: index-
preserving ops carry their kept-row lists straight out of the operation's own
semantics (observation over preserved dataframe indices), while the join
threads row-ids through the merge (active capture).  This module only turns
those payloads into the tensors of §III-A — no content diffing anywhere.
"""
from __future__ import annotations

import numpy as np

from repro.core.opcat import CaptureInfo, IDENTITY_CATEGORIES, OpCategory
from repro.core.provtensor import (
    ProvTensor,
    append_tensor,
    haugment_tensor,
    hreduce_tensor,
    identity_tensor,
    join_tensor,
)

__all__ = ["build_tensor"]


def build_tensor(info: CaptureInfo) -> ProvTensor:
    cat = info.category
    if cat in IDENTITY_CATEGORIES:
        # transformation / vertical reduction / vertical augmentation:
        # 2-D binary identity tensor (paper §III-A a, b, d)
        if info.n_out != info.n_in[0]:
            raise ValueError(f"{info.op_name}: identity category but n_out != n_in")
        return identity_tensor(info.n_out)
    if cat is OpCategory.HREDUCE:
        if info.kept_rows is None:
            raise ValueError(f"{info.op_name}: HREDUCE needs kept_rows")
        return hreduce_tensor(info.kept_rows, info.n_in[0])
    if cat is OpCategory.HAUGMENT:
        if info.links is not None:
            # multi-parent augmentation (sequence packing et al.): raw COO
            return ProvTensor(n_out=info.n_out, n_in=(info.n_in[0],),
                              coo=np.asarray(info.links, dtype=np.int32))
        if info.src_rows is None:
            raise ValueError(f"{info.op_name}: HAUGMENT needs src_rows or links")
        return haugment_tensor(info.src_rows, info.n_in[0])
    if cat is OpCategory.JOIN:
        if info.join_pairs is None:
            raise ValueError(f"{info.op_name}: JOIN needs join_pairs")
        return join_tensor(info.join_pairs, info.n_in[0], info.n_in[1])
    if cat is OpCategory.APPEND:
        return append_tensor(info.n_in[0], info.n_in[1])
    raise ValueError(f"unknown category {cat}")
