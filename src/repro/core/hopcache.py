"""Composed hop-cache: memoized multi-hop lineage relations (paper §III-D/§IV).

Answering Q1/Q2/Q10/Q11 between DISTANT datasets by walking the op DAG costs
one CSR probe per hop per query.  The Einstein-summation machinery of
:mod:`repro.core.compose` can instead contract the whole path into ONE
composed relation; this module memoizes those relations so repeated /
batched queries between the same dataset pair become a single batched probe.

Design points:

* **Per-entry backends.**  Every cached relation carries its own
  representation tag (:class:`_Entry`): ``csr`` (scipy sparse boolean
  matmul — composition cost scales with nnz), ``bitplane`` (packed uint32
  planes through :func:`compose_pair` — the :mod:`repro.kernels` bitmatmul,
  Pallas on TPU), or ``structured`` (an IMPLICIT gather: one int32 array
  mapping each destination row to its ≤1 source row, ``None`` for the pure
  identity).  ``backend="auto"`` (the host default) picks per pair by
  the cost model's density threshold
  (:data:`repro.core.costmodel.DENSITY_THRESHOLD`) and CONVERTS an
  accumulation that densifies past it — a filter-heavy 0.1%-dense path stays
  CSR while a join blow-up rides the packed planes, in one cache.
  ``backend="csr"`` / ``backend="bitplane"`` force a uniform representation.
* **Closed-form composition algebra.**  In ``auto`` mode, ops whose slot
  relation is structured (:meth:`ProvTensor.slot_structure` — identities,
  selections, gathers, append blocks, join sides) compose WITHOUT spmm:
  an identity step is eliminated outright (the accumulation is reused
  unchanged, whatever its backend); gather∘gather — and therefore
  selection∘selection — is ONE ``np.take``; append's sibling-branch union
  distributes over its disjoint blocks and stays a gather.  A composed
  chain of selections is cached as ONE int32 array — its byte accounting
  reflects the implicit form, not a CSR.  Only a genuinely multi-parent
  step (raw-COO links) or an overlapping-branch union densifies the
  accumulation into csr/bitplane, from where the old algebra takes over.
* **Multi-path exact** — ``relation(src, dst)`` accumulates over the op DAG
  in topological order, UNIONING the contributions of every input slot whose
  dataset is reachable from ``src``.  On DAGs where ``src`` reaches ``dst``
  through multiple paths (a diamond: one source feeding two branches
  re-joined downstream) the composed relation sums over ALL paths, exactly
  matching the hop-walking engine — not just the unique producer chain.
* **Lazy + incremental** — every intermediate ``(src, mid)`` accumulation is
  cached, so a later query to a further dataset reuses the cached prefix and
  composes only the new suffix.
* **Eviction-bounded** — an LRU keyed on ``(src, dst)`` with a byte budget
  (``memory_budget_bytes``), honoring the paper's minimal-memory goal: the
  cache trades recompute for memory and can be sized down to nothing.
  Overwriting an existing key first releases the old entry's bytes.
* **Fast backward probes** — bitplane entries lazily materialize a
  TRANSPOSED plane (bytes accounted against the budget), so a backward probe
  select-ORs just the probe's set rows (the same
  :func:`bitplane_or_reduce` contraction as forward probes) costing
  O(probe nnz × words) per probe instead of the old scan of every relation
  row per probe.
* **Append-safe AND stream-native** — the op DAG is append-only (one
  producer per dataset, enforced by ``ProvenanceIndex.record``), so
  composed relations between existing datasets stay exact when new ops are
  recorded and the cache is kept across version bumps.  Beyond that,
  ``_sync`` drains newly-recorded ops INCREMENTALLY: for every source
  dataset the cache has been probed through, a new op with a structured
  tail (identity / filter / gather / append — the common capture output)
  EXTENDS the warm composed relation by one closed-form step
  (:func:`~repro.core.compose.extend_tail` — a take for structured
  prefixes, a column gather for dense ones) instead of leaving the next
  probe to recompose the chain; a cold multi-hop miss with a dense prefix
  is gated by :func:`~repro.core.costmodel.extend_vs_recompose` between
  stepwise extension and fold-the-tail-first recomposition.  ``extends`` /
  ``recomposes`` counters in :meth:`stats` expose which maintenance path
  ran.
* **Spill-backed eviction** — with a ``spill=`` policy
  (:mod:`repro.core.spill`), LRU eviction past the byte budget's high
  watermark serializes entries to the compact on-disk log (structured
  gathers as one int array, CSR as its index/indptr/data triple, bitplanes
  as the packed words) instead of dropping them; a probe of a spilled pair
  FAULTS it back transparently (one memory-mapped read, counted in
  ``rehydrations``) rather than recomposing the chain.  Without ``spill=``
  eviction behaves exactly as before (drop at the budget).

When NO path exists, the probe methods answer empty (matching the walking
engine); ``relation`` itself raises ``KeyError``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.compose import (
    HAVE_SCIPY,
    compose_gather,
    compose_pair,
    compose_pair_csr,
    extend_tail_bitplane,
    extend_tail_csr,
    op_bitplane,
    op_csr,
    resolve_use_pallas,
)
from repro.core.costmodel import (
    CostModel,
    RelStats,
    extend_vs_recompose,
    pick_backend,
)
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    SlotIdentity,
    bitplane_or_reduce,
    bitplane_popcount,
    pack_bitplane,
    unpack_bitplane,
)
from repro.core.spill import resolve_spill

__all__ = ["ComposedIndex"]


@dataclasses.dataclass
class _Entry:
    """One cached composed relation, tagged with its representation.

    ``structured`` entries hold the relation implicitly: ``rel`` is an int32
    ``(cols,)`` gather mapping each destination row to its (at most one)
    source row, ``-1`` = no link — or ``None`` for the pure identity
    (``rows == cols``), which costs nothing at all."""

    backend: str              # "csr" | "bitplane" | "structured"
    rel: object               # scipy CSR (float32 ones), packed uint32 plane,
                              # or int32 gather (None = identity)
    rows: int                 # |src|
    cols: int                 # |dst|
    nnz: int
    relT: Optional[np.ndarray] = None  # lazy (cols, ⌈rows/32⌉) transposed plane

    @property
    def density(self) -> float:
        cells = self.rows * self.cols
        return self.nnz / cells if cells else 0.0

    def nbytes(self) -> int:
        if self.backend == "structured":
            total = 0 if self.rel is None else int(self.rel.nbytes)
        elif self.backend == "csr":
            r = self.rel
            total = int(r.data.nbytes + r.indices.nbytes + r.indptr.nbytes)
        else:
            total = int(self.rel.nbytes)
        if self.relT is not None:
            total += int(self.relT.nbytes)
        return total

    def gather(self) -> np.ndarray:
        """The structured entry's (cols,) destination→source map, with the
        identity materialized on demand."""
        if self.rel is not None:
            return self.rel
        return np.arange(self.cols, dtype=np.int32)


class ComposedIndex:
    """Memoized composed-relation store + batched probe engine over one
    :class:`ProvenanceIndex`."""

    def __init__(
        self,
        index: ProvenanceIndex,
        memory_budget_bytes: int = 64 << 20,
        backend: Optional[str] = None,
        use_pallas: Optional[bool] = None,
        spill=None,
        extend_eager: bool = True,
    ) -> None:
        # tri-state kernel flag: None -> Pallas iff on TPU (jax-free on
        # hosts), so the default backend stays "auto" off-TPU bit-for-bit
        # and becomes all-bitplane where the kernels actually pay off
        use_pallas = resolve_use_pallas(use_pallas)
        if backend is None:
            backend = "bitplane" if use_pallas else "auto"
        if backend not in ("auto", "csr", "bitplane"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "csr" and not HAVE_SCIPY:
            raise ImportError("backend='csr' requires scipy")
        self.index = index
        self.backend = backend
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.use_pallas = use_pallas
        self.extend_eager = bool(extend_eager)
        self.costmodel = CostModel(index)
        self._cache: "OrderedDict[Tuple[str, str], _Entry]" = OrderedDict()
        self._bytes = 0
        self._version = index.version
        self._ops_seen = len(index.ops)
        # probed-through sources: reach sets maintained incrementally so a
        # 1M-op stream never re-runs the O(ops) reachability scan per probe
        self._reach: Dict[str, set] = {}
        self._spill = resolve_spill(spill)
        self._spill_store = self._spill.ensure_store() if self._spill else None
        # keys whose entry is on disk and NOT resident; plus what the disk
        # copy holds (backend, nnz) so an unchanged re-eviction skips the
        # write (composed relations are immutable under the append-only DAG)
        self._spilled: "OrderedDict[Tuple[str, str], bool]" = OrderedDict()
        self._store_meta: Dict[Tuple[str, str], Tuple[str, int]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.conversions = 0
        self.extends = 0
        self.recomposes = 0
        self.spills = 0
        self.rehydrations = 0

    # -- cache plumbing -----------------------------------------------------
    def _sync(self) -> None:
        """Reconcile with the index after writes — incrementally.

        The op DAG is APPEND-ONLY (every dataset has exactly one producer —
        ``ProvenanceIndex.record`` rejects duplicate output ids — and a new
        op can only produce a NEW dataset, never splice a path between two
        existing ones), so composed relations between existing datasets stay
        exact across version bumps and the cache is KEPT.

        Ops recorded since the last sync are drained ONCE: every tracked
        source's reach set absorbs them, and (``extend_eager``, auto
        backend) a new op whose on-path slots are all structured and whose
        on-path prefixes are all RAM-resident EXTENDS the warm composed
        relations by one closed-form step right now — the next probe of the
        new dataset is a pure cache hit instead of a chain recompose.  Ops
        whose tensors are spilled, or whose prefixes are cold, are left for
        the lazy path (which faults / rebuilds on demand): eager
        maintenance must never pull cold state back in.
        """
        n = len(self.index.ops)
        if n > self._ops_seen:
            for op in self.index.ops[self._ops_seen:n]:
                self._absorb_op(op)
            self._ops_seen = n
        self._version = self.index.version

    def _absorb_op(self, op) -> None:
        for src, reach in self._reach.items():
            slots = [k for k, d in enumerate(op.input_ids) if d in reach]
            if not slots:
                continue
            reach.add(op.output_id)
            if not (self.extend_eager and self.backend == "auto"):
                continue
            if not getattr(op.tensor, "structured", False):
                continue
            prefixes = {}
            for k in slots:
                d = op.input_ids[k]
                if d == src:
                    prefixes[k] = None
                else:
                    e = self._cache.get((src, d))
                    if e is None:
                        break  # cold/partial prefix: a partial union is wrong
                    prefixes[k] = e
            if len(prefixes) != len(slots):
                continue
            # the extension reads the gather slot: fault a spilled tensor
            # back NOW (one ~KB memmap read, LRU-linear during a sync drain)
            # — skipping instead would leave the relation to a full
            # recompose over every op appended since the last probe
            op.tensor.resident()
            acc: Optional[_Entry] = None
            for k in slots:
                contrib = self._extend(prefixes[k], op, k)
                acc = contrib if acc is None else self._union(acc, contrib)
            self._insert((src, op.output_id), self._settle(acc))
            self.extends += 1

    def _reach_set(self, src: str) -> set:
        """Datasets reachable from ``src`` — computed by ONE full op scan on
        the first probe through ``src``, then maintained per appended op by
        ``_sync`` (the O(ops)-per-miss rescan this replaces was the
        streaming bottleneck)."""
        reach = self._reach.get(src)
        if reach is None:
            reach = {src}
            for op in self.index.ops:
                if any(d in reach for d in op.input_ids):
                    reach.add(op.output_id)
            self._reach[src] = reach
        return reach

    def _evict_over_budget(self) -> None:
        if self._spill is None:
            while (self._bytes > self.memory_budget_bytes
                   and len(self._cache) > 1):
                _, evicted = self._cache.popitem(last=False)
                self._bytes -= evicted.nbytes()
                self.evictions += 1
            return
        # spill tier: watermark hysteresis — start evicting past high,
        # spill LRU entries to disk down to low, so an append stream pays
        # one burst of writes per crossing instead of one per insert
        high = self.memory_budget_bytes * self._spill.high_watermark
        low = self.memory_budget_bytes * self._spill.low_watermark
        if self._bytes <= high:
            return
        while self._bytes > low and len(self._cache) > 1:
            key, evicted = self._cache.popitem(last=False)
            self._bytes -= evicted.nbytes()
            self.evictions += 1
            self._spill_entry(key, evicted)

    def _spill_entry(self, key: Tuple[str, str], entry: _Entry) -> None:
        entry.relT = None  # lazily rebuilt after fault; never serialized
        if self._store_meta.get(key) != (entry.backend, entry.nnz):
            meta = {"backend": entry.backend, "rows": entry.rows,
                    "cols": entry.cols, "nnz": entry.nnz,
                    "identity": entry.backend == "structured"
                    and entry.rel is None}
            if entry.backend == "structured":
                arrays = {} if entry.rel is None else {"gather": entry.rel}
            elif entry.backend == "csr":
                arrays = {"data": entry.rel.data, "indices": entry.rel.indices,
                          "indptr": entry.rel.indptr}
            else:
                arrays = {"plane": entry.rel}
            self._spill_store.put(("rel", self.index.name) + key, arrays, meta)
            self._store_meta[key] = (entry.backend, entry.nnz)
        self._spilled[key] = True
        self.spills += 1

    def _fault(self, key: Tuple[str, str]) -> Optional[_Entry]:
        """Rehydrate one spilled composed relation: arrays come back as
        read-only memmap views (page-cache-backed, byte-identical to what
        was evicted), the entry re-enters the LRU as MRU."""
        try:
            meta, arrays = self._spill_store.get(("rel", self.index.name)
                                                 + key)
        except KeyError:
            self._spilled.pop(key, None)
            self._store_meta.pop(key, None)
            return None  # dropped by a disk budget: rebuild from scratch
        backend = meta["backend"]
        rows, cols, nnz = int(meta["rows"]), int(meta["cols"]), int(meta["nnz"])
        if backend == "structured":
            rel = None if meta["identity"] else np.asarray(arrays["gather"])
            entry = _Entry("structured", rel, rows, cols, nnz)
        elif backend == "csr":
            import scipy.sparse as sp

            rel = sp.csr_matrix(
                (arrays["data"], arrays["indices"], arrays["indptr"]),
                shape=(rows, cols))
            entry = _Entry("csr", rel, rows, cols, nnz)
        else:
            entry = _Entry("bitplane", np.asarray(arrays["plane"]),
                           rows, cols, nnz)
        self._spilled.pop(key, None)
        self.rehydrations += 1
        self._insert(key, entry)
        if key not in self._cache:
            self._spilled[key] = True  # declined (over budget); disk copy stays
        return entry

    def _insert(self, key: Tuple[str, str], entry: _Entry) -> None:
        nbytes = entry.nbytes()
        if nbytes > self.memory_budget_bytes:
            # larger than the whole budget: with a spill tier, park it on
            # disk (a memmap fault beats recomposing the chain); without
            # one, serve uncached — the seed behavior
            if self._spill_store is not None:
                self._spill_entry(key, entry)
            return
        old = self._cache.pop(key, None)
        if old is not None:
            # overwrite releases the old entry's bytes FIRST — re-inserting a
            # key must not double-count and force spurious evictions
            self._bytes -= old.nbytes()
        self._cache[key] = entry
        self._bytes += nbytes
        self._spilled.pop(key, None)  # resident again; disk copy kept as-is
        self._evict_over_budget()

    def _lookup(self, key: Tuple[str, str]) -> Optional[_Entry]:
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
            return entry
        if self._spilled and key in self._spilled:
            return self._fault(key)
        return None

    def _peek(self, key: Tuple[str, str]) -> bool:
        """Composed and answerable without recomposition (resident OR
        spilled) — no LRU touch, no fault, no composition."""
        return key in self._cache or key in self._spilled

    # -- backend primitives ---------------------------------------------------
    def _resolve_backend(self, density: float) -> str:
        """Representation for a relation of the given density (auto mode:
        the cost model's threshold; forced modes: the forced backend)."""
        if self.backend != "auto":
            return self.backend
        return pick_backend(density, HAVE_SCIPY)

    def _identity_entry(self, n: int) -> _Entry:
        if self.backend == "auto":
            # the src == dst relation IS the identity: store nothing
            return _Entry("structured", None, n, n, n)
        density = 1.0 / n if n else 0.0
        backend = self._resolve_backend(density)
        if backend == "csr":
            import scipy.sparse as sp

            return _Entry("csr", sp.identity(n, dtype=np.float32, format="csr"),
                          n, n, n)
        words = np.zeros((n, max((n + 31) // 32, 1)), dtype=np.uint32)
        i = np.arange(n)
        words[i, i // 32] = np.left_shift(np.uint32(1), (i % 32).astype(np.uint32))
        return _Entry("bitplane", words, n, n, n)

    def _step_rel(self, op, slot: int, backend: str):
        return op_csr(op.tensor, slot) if backend == "csr" \
            else op_bitplane(op.tensor, slot)

    @staticmethod
    def _structured_pairs(entry: _Entry):
        """Valid (source_row, dest_row) link pairs of a structured entry."""
        if entry.rel is None:
            i = np.arange(entry.rows, dtype=np.int32)
            return i, i
        dst = np.flatnonzero(entry.rel >= 0).astype(np.int32)
        return entry.rel[dst], dst

    def _to_bitplane(self, entry: _Entry) -> _Entry:
        if entry.backend == "bitplane":
            return entry
        self.conversions += 1
        if entry.backend == "structured":
            src, dst = self._structured_pairs(entry)
            dense = np.zeros((entry.rows, entry.cols), dtype=bool)
            dense[src, dst] = True
        else:
            dense = np.asarray(entry.rel.toarray()) > 0
        return _Entry("bitplane", pack_bitplane(dense),
                      entry.rows, entry.cols, entry.nnz)

    def _to_csr(self, entry: _Entry) -> _Entry:
        if entry.backend == "csr":
            return entry
        import scipy.sparse as sp

        self.conversions += 1
        if entry.backend == "structured":
            src, dst = self._structured_pairs(entry)
            rel = sp.csr_matrix(
                (np.ones(len(dst), dtype=np.float32), (src, dst)),
                shape=(entry.rows, entry.cols))
        else:
            dense = unpack_bitplane(entry.rel, entry.cols)
            rel = sp.csr_matrix(dense.astype(np.float32))
        return _Entry("csr", rel, entry.rows, entry.cols, entry.nnz)

    def _densify(self, entry: _Entry) -> _Entry:
        """A structured entry leaving the closed-form algebra (overlapping
        union, unstructured step): the representation the density picks."""
        if entry.backend != "structured":
            return entry
        return self._to_csr(entry) \
            if pick_backend(entry.density, HAVE_SCIPY) == "csr" \
            else self._to_bitplane(entry)

    def _structured_step_entry(self, op, slot: int) -> _Entry:
        t = op.tensor
        s = t.slot_structure(slot)
        rel = None if isinstance(s, SlotIdentity) else t.slot_gather(slot)
        return _Entry("structured", rel, t.n_in[slot], t.n_out,
                      t.slot_nnz(slot))

    def _extend(self, prefix: Optional[_Entry], op, slot: int) -> _Entry:
        """``prefix ∘ op[slot]`` as a fresh entry (prefix None = identity).

        Closed forms first (``auto`` mode): an identity step is ELIMINATED —
        the result reuses the prefix's relation unchanged, whatever its
        backend; a structured prefix composed with a structured step
        (gather∘gather, so also selection∘selection) is ONE ``np.take``;
        only an unstructured step densifies the prefix and falls back to
        spmm / packed-plane contraction."""
        t = op.tensor
        s = t.slot_structure(slot) if self.backend == "auto" else None
        if prefix is None:
            if s is not None:
                return self._structured_step_entry(op, slot)
            backend = self._resolve_backend(t.slot_density(slot))
            return _Entry(backend, self._step_rel(op, slot, backend),
                          t.n_in[slot], t.n_out, t.slot_nnz(slot))
        if isinstance(s, SlotIdentity):
            # identity elimination: prefix ∘ I = prefix.  The relation is
            # COPIED (a memcpy, still no spmm/bitmatmul): both entries live
            # in the cache under their own keys, and aliased arrays would
            # make the budget double-count bytes and eviction free nothing.
            rel = prefix.rel if prefix.rel is None else prefix.rel.copy()
            return _Entry(prefix.backend, rel, prefix.rows, t.n_out,
                          prefix.nnz)
        if prefix.backend == "structured":
            if prefix.rel is None:
                # identity prefix: the step's own relation is the result
                return self._extend(None, op, slot)
            if s is not None:
                g_step = t.slot_gather(slot)            # (n_out,) → |mid|
                valid = g_step >= 0
                g_new = np.where(valid,
                                 prefix.rel[np.where(valid, g_step, 0)],
                                 np.int32(-1))
                return _Entry("structured", g_new, prefix.rows, t.n_out,
                              int(np.count_nonzero(g_new >= 0)))
            prefix = self._densify(prefix)
        if s is not None:
            # DENSE prefix ∘ structured step: the closed-form tail extension
            # (a column gather, no matmul) — the streaming append fast path
            g_step = t.slot_gather(slot)
            if prefix.backend == "csr":
                rel = extend_tail_csr(prefix.rel, g_step)
                return _Entry("csr", rel, prefix.rows, t.n_out, int(rel.nnz))
            rel = extend_tail_bitplane(prefix.rel, g_step, prefix.cols)
            return _Entry("bitplane", rel, prefix.rows, t.n_out,
                          bitplane_popcount(rel))
        rows = prefix.rows
        step = self._step_rel(op, slot, prefix.backend)
        if prefix.backend == "csr":
            rel = compose_pair_csr(prefix.rel, step)
            nnz = int(rel.nnz)
        else:
            rel = compose_pair(prefix.rel, step, t.n_in[slot],
                               use_pallas=self.use_pallas)
            nnz = bitplane_popcount(rel)
        return _Entry(prefix.backend, rel, rows, t.n_out, nnz)

    def _union(self, a: _Entry, b: _Entry) -> _Entry:
        """(OR)-union two relations — the sum over parallel DAG paths.

        Two structured gathers whose links never disagree stay structured —
        append's sibling branches land in DISJOINT destination blocks (the
        block-append distribution), so their union is still one gather.
        Everything else densifies; mixed representations meet on the packed
        plane (the denser side)."""
        if a.backend == "structured" and b.backend == "structured":
            ga, gb = a.gather(), b.gather()
            both = (ga >= 0) & (gb >= 0)
            if not both.any() or np.array_equal(ga[both], gb[both]):
                g = np.where(ga >= 0, ga, gb)
                return _Entry("structured", g, a.rows, a.cols,
                              int(np.count_nonzero(g >= 0)))
        a, b = self._densify(a), self._densify(b)
        if a.backend != b.backend:
            a, b = self._to_bitplane(a), self._to_bitplane(b)
        if a.backend == "csr":
            rel = (a.rel + b.rel).tocsr()
            rel.data = np.ones_like(rel.data)
            return _Entry("csr", rel, a.rows, a.cols, int(rel.nnz))
        rel = np.bitwise_or(a.rel, b.rel)
        return _Entry("bitplane", rel, a.rows, a.cols, bitplane_popcount(rel))

    def _compose_entries(self, a: _Entry, b: _Entry) -> _Entry:
        """Generic ``a ∘ b`` over already-composed entries (``a`` maps
        X→Y, ``b`` maps Y→Z) — the recompose path's fold primitive.  The
        same closed forms as :meth:`_extend` apply: identity elimination
        (copying, per the no-aliasing budget rule), gather∘gather as one
        take, dense∘gather as the column-gather tail extension; only
        dense∘dense pays a matmul."""
        if a.backend == "structured" and a.rel is None:
            rel = b.rel
            if rel is not None:
                rel = rel.copy()
            return _Entry(b.backend, rel, a.rows, b.cols, b.nnz)
        if b.backend == "structured" and b.rel is None:
            rel = a.rel if a.rel is None else a.rel.copy()
            return _Entry(a.backend, rel, a.rows, b.cols, a.nnz)
        if b.backend == "structured":
            if a.backend == "structured":
                g = compose_gather(a.rel, b.rel)
                return _Entry("structured", g, a.rows, b.cols,
                              int(np.count_nonzero(g >= 0)))
            if a.backend == "csr":
                rel = extend_tail_csr(a.rel, b.rel)
                return _Entry("csr", rel, a.rows, b.cols, int(rel.nnz))
            rel = extend_tail_bitplane(a.rel, b.rel, a.cols)
            return _Entry("bitplane", rel, a.rows, b.cols,
                          bitplane_popcount(rel))
        a = self._densify(a)
        if a.backend != b.backend:
            b = self._to_csr(b) if a.backend == "csr" else self._to_bitplane(b)
        if a.backend == "csr":
            rel = compose_pair_csr(a.rel, b.rel)
            return _Entry("csr", rel, a.rows, b.cols, int(rel.nnz))
        rel = compose_pair(a.rel, b.rel, b.rows, use_pallas=self.use_pallas)
        return _Entry("bitplane", rel, a.rows, b.cols, bitplane_popcount(rel))

    def _settle(self, entry: _Entry) -> _Entry:
        """auto mode: convert an accumulation whose observed density crossed
        the cost model's threshold (densification → packed plane, and back).
        Structured entries never settle — the implicit form beats both."""
        if self.backend != "auto" or entry.backend == "structured":
            return entry
        want = pick_backend(entry.density, HAVE_SCIPY)
        if want == entry.backend:
            return entry
        return self._to_bitplane(entry) if want == "bitplane" \
            else self._to_csr(entry)

    # -- the composed relation ----------------------------------------------
    def _pending_ops(self, src: str, dst: str, reach: set) -> List[object]:
        """Ops that must run to compose ``(src, dst)``: backward DFS from
        ``dst``, stopping at ``src`` and at datasets whose ``(src, ·)``
        relation is already composed (resident or spilled) — returned in
        topological (op-id) order.  For a one-op append onto a warm chain
        this is a SINGLE op, independent of pipeline depth; the seed path
        rescanned the whole DAG region per miss."""
        pending: Dict[int, object] = {}
        visited = set()
        stack = [dst]
        while stack:
            d = stack.pop()
            if d == src or d in visited:
                continue
            visited.add(d)
            if self._peek((src, d)):
                continue
            op = self.index.ops[self.index.producer[d]]
            pending[op.op_id] = op
            for in_id in op.input_ids:
                if in_id in reach:
                    stack.append(in_id)
        return [pending[i] for i in sorted(pending)]

    @staticmethod
    def _linear_tail(pending: List[object], reach: set):
        """``(base_dataset, [(op, slot), ...])`` when the pending ops form
        one single-parent chain (each op exactly one on-path input, chained
        consecutively) — the shape :func:`extend_vs_recompose` prices —
        else None."""
        steps = []
        base = None
        prev_out = None
        for op in pending:
            slots = [k for k, d in enumerate(op.input_ids) if d in reach]
            if len(slots) != 1:
                return None
            in_id = op.input_ids[slots[0]]
            if prev_out is None:
                base = in_id
            elif in_id != prev_out:
                return None
            prev_out = op.output_id
            steps.append((op, slots[0]))
        return base, steps

    def _step_entry(self, op, slot: int) -> _Entry:
        """One op slot's own relation as an entry (the tail fold's leaves)."""
        t = op.tensor
        if self.backend == "auto" and t.slot_structure(slot) is not None:
            return self._structured_step_entry(op, slot)
        backend = self._resolve_backend(t.slot_density(slot))
        return _Entry(backend, self._step_rel(op, slot, backend),
                      t.n_in[slot], t.n_out, t.slot_nnz(slot))

    def _relation_entry(self, src: str, dst: str) -> _Entry:
        self._sync()
        cached = self._lookup((src, dst))
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        if src == dst:
            entry = self._identity_entry(self.index.datasets[src].n_rows)
            self._insert((src, dst), entry)
            return entry
        reach = self._reach_set(src)
        if dst not in reach:
            raise KeyError(f"no dataflow path {src} -> {dst}")
        pending = self._pending_ops(src, dst, reach)
        pending_out = {op.output_id for op in pending}
        # Resolve every boundary prefix FIRST (cached (src, ·) relations the
        # pending ops compose onto), before any insert below can evict one.
        # local holds live references, so cascading evictions during the
        # build cannot invalidate them.
        local: Dict[str, Optional[_Entry]] = {src: None}  # None = identity
        for op in pending:
            for in_id in op.input_ids:
                if (in_id in reach and in_id != src
                        and in_id not in pending_out and in_id not in local):
                    hit = self._lookup((src, in_id))
                    if hit is not None:
                        self.hits += 1
                    else:
                        # evicted (no spill tier) between peek and resolve:
                        # rebuild the prefix recursively
                        hit = self._relation_entry(src, in_id)
                    local[in_id] = hit
        # Cost-model gate (dense warm prefix, multi-step tail): fold the
        # tail FIRST in the chain DP's order and apply it to the prefix
        # once, when that beats dragging the full-width prefix through
        # every hop.  Intermediates are NOT cached on this path — the gate
        # chose it precisely because they would be expensive dead weight.
        if len(pending) >= 2:
            lin = self._linear_tail(pending, reach)
            if lin is not None and lin[0] in local:
                base, steps = lin
                prefix = local[base]
                if prefix is not None and prefix.backend != "structured":
                    pstats = RelStats(prefix.rows, prefix.cols, prefix.nnz,
                                      structured=False)
                    tstats = [RelStats.from_slot(op.tensor, k)
                              for op, k in steps]
                    verdict = extend_vs_recompose(pstats, tstats,
                                                  have_scipy=HAVE_SCIPY)
                    if verdict["strategy"] == "recompose":
                        entries = [self._step_entry(op, k) for op, k in steps]
                        for (i, _k) in verdict["tail_order"]:
                            j = i + 1
                            while entries[j] is None:
                                j += 1
                            entries[i] = self._compose_entries(entries[i],
                                                               entries[j])
                            entries[j] = None
                        folded = next(e for e in entries if e is not None)
                        acc = self._settle(self._compose_entries(prefix,
                                                                 folded))
                        self.recomposes += 1
                        self._insert((src, dst), acc)
                        return acc
        # Stepwise accumulation in topo order: UNION over on-path input
        # slots of (prefix ∘ slot step); every intermediate (src, mid) is
        # cached so later further-dataset queries reuse the prefix.
        rels = local
        for op in pending:
            acc: Optional[_Entry] = None
            for k, in_id in enumerate(op.input_ids):
                if in_id not in rels:
                    continue  # input unreachable from src: contributes nothing
                contrib = self._extend(rels[in_id], op, k)
                acc = contrib if acc is None else self._union(acc, contrib)
            if acc is None:
                continue
            acc = self._settle(acc)
            rels[op.output_id] = acc
            self._insert((src, op.output_id), acc)
        if len(pending) == 1:
            self.extends += 1
        elif len(pending) > 1:
            self.recomposes += 1
        if dst not in rels or rels[dst] is None:
            raise KeyError(f"no dataflow path {src} -> {dst}")
        return rels[dst]

    def relation(self, src: str, dst: str):
        """The composed ``src`` → ``dst`` relation, from cache or composed
        incrementally: scipy CSR or packed bitplane per the entry's backend
        (see :meth:`relation_backend`); a ``structured`` entry answers a
        COPY of its int32 destination→source gather array (identity chains
        materialize the arange) — a copy because the cached gather may BE an
        op tensor's own capture payload, and handing out the live array
        would let a caller corrupt the recorded provenance.  Callers that
        need a uniform matrix regardless of backend use
        :meth:`relation_csr`.

        Accumulates over the op DAG in topological order restricted to ops
        that lie on some ``src`` → ``dst`` path: each op's output relation is
        the UNION over its input slots of (input relation ∘ slot step), so
        multi-path DAGs (diamonds, self-joins) compose exactly.  Every
        intermediate ``(src, mid)`` accumulation is cached — later queries
        to further datasets reuse the prefix.
        """
        entry = self._relation_entry(src, dst)
        if entry.backend == "structured":
            return entry.gather().copy()
        return entry.rel

    def relation_backend(self, src: str, dst: str) -> str:
        """Which representation the (composed-on-demand) relation uses."""
        return self._relation_entry(src, dst).backend

    def relation_csr(self, src: str, dst: str):
        """The composed relation as scipy CSR regardless of the entry's
        backend — the federation's cross-index composition hook (a
        :class:`~repro.provenance.catalog.BoundaryHandle` grants exactly
        this read for boundary-ancestor pairs).  Bitplane entries convert
        TRANSIENTLY: the cache entry, its backend tag, and the conversion
        counter are untouched."""
        if not HAVE_SCIPY:
            raise ImportError("relation_csr requires scipy")
        entry = self._relation_entry(src, dst)
        if entry.backend == "csr":
            # a COPY: handing out the live cached arrays would let a
            # "read-only" BoundaryHandle corrupt the index's private cache
            return entry.rel.copy()
        if entry.backend == "structured":
            import scipy.sparse as sp

            src_rows, dst_rows = self._structured_pairs(entry)
            return sp.csr_matrix(
                (np.ones(len(dst_rows), dtype=np.float32), (src_rows, dst_rows)),
                shape=(entry.rows, entry.cols))
        import scipy.sparse as sp

        # unpack in row blocks: a large packed plane must not transiently
        # materialize the full (rows, cols) dense array (32x the packed
        # bytes) just to re-sparsify it
        step = max(1, (4 << 20) // max(entry.cols, 1))
        blocks = [
            sp.csr_matrix(unpack_bitplane(entry.rel[i : i + step], entry.cols))
            for i in range(0, max(entry.rows, 1), step)
        ]
        rel = blocks[0] if len(blocks) == 1 else sp.vstack(blocks, format="csr")
        return rel.astype(np.float32)

    # -- batched probes -------------------------------------------------------
    def _probe_masks(self, rows, n: int) -> Tuple[np.ndarray, bool]:
        from repro.core.query import _as_mask, _as_mask_batch, is_probe_batch

        if is_probe_batch(rows):
            return _as_mask_batch(rows, n), True
        return _as_mask(rows, n)[None, :], False

    def _try_relation(self, src: str, dst: str) -> Optional[_Entry]:
        """``_relation_entry`` for probes: no dataflow path -> None (probes
        answer empty, matching the walking engine; ``relation`` itself still
        raises so relation-materializing callers get the loud error)."""
        try:
            return self._relation_entry(src, dst)
        except KeyError:
            return None

    def _entry_relT(self, key: Tuple[str, str], entry: _Entry) -> np.ndarray:
        """The transposed plane of a bitplane entry, materialized lazily and
        accounted against the byte budget (recomposed if later evicted).

        A CACHED entry only retains its transposed plane when rel+relT still
        fit the budget — ``_insert`` guarantees post-insert ``_bytes`` never
        exceeds the budget, and a sole over-budget entry could never be
        evicted (the eviction loop keeps one entry); otherwise the plane is
        served transiently.
        """
        if entry.relT is not None:
            return entry.relT
        dense = unpack_bitplane(entry.rel, entry.cols)
        relT = pack_bitplane(np.ascontiguousarray(dense.T))
        if self._cache.get(key) is not entry:
            entry.relT = relT       # transient entry: lives only this call
        elif entry.nbytes() + relT.nbytes <= self.memory_budget_bytes:
            entry.relT = relT
            self._bytes += int(relT.nbytes)
            self._evict_over_budget()
        return relT

    def _forward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool -> (B, |dst|) bool through the composed relation."""
        entry = self._try_relation(src, dst)
        if entry is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[dst].n_rows), dtype=bool)
        if entry.backend == "structured":
            # one take along the gather: out[b, d] = masks[b, g[d]]
            if entry.rel is None:
                return masks[:, : entry.cols].copy()
            g = entry.rel
            valid = g >= 0
            return masks[:, : entry.rows][:, np.where(valid, g, 0)] & valid[None, :]
        if entry.backend == "csr":
            return np.asarray(masks.astype(np.float32) @ entry.rel) > 0
        if self.use_pallas:
            from repro.kernels import ops as K  # late import: host path stays jax-free

            words = np.asarray(K.bitplane_probe(pack_bitplane(masks), entry.rel))
        else:
            words = bitplane_or_reduce(pack_bitplane(masks), entry.rel, entry.rows)
        return unpack_bitplane(words, entry.cols)

    def _backward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |dst|) bool -> (B, |src|) bool: rows of the composed relation
        intersecting each probe set.

        Bitplane entries answer through the lazily-cached TRANSPOSED plane:
        selecting a probe's set rows from ``relT`` and OR-reducing them
        costs O(probe nnz × words) per probe, instead of the old full
        scan of every relation row per probe — it is the exact mirror of
        the forward select-OR, so both directions share
        :func:`bitplane_or_reduce`.
        """
        entry = self._try_relation(src, dst)
        if entry is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[src].n_rows), dtype=bool)
        if entry.backend == "structured":
            # one scatter through the gather: out[b, g[d]] |= masks[b, d]
            if entry.rel is None:
                return masks[:, : entry.rows].copy()
            g = entry.rel
            out = np.zeros((masks.shape[0], entry.rows), dtype=bool)
            sel = masks[:, : entry.cols] & (g >= 0)[None, :]
            bs, ds = np.nonzero(sel)
            out[bs, g[ds]] = True
            return out
        if entry.backend == "csr":
            return (entry.rel @ masks.astype(np.float32).T).T > 0
        relT = self._entry_relT((src, dst), entry)
        words = bitplane_or_reduce(
            pack_bitplane(masks[:, : entry.cols]), relT, entry.cols)
        return unpack_bitplane(words, entry.rows)

    # -- mask-stack probes (the QuerySession entry points) ---------------------
    def contains(self, src: str, dst: str) -> bool:
        """Whether the ``src`` → ``dst`` relation is already composed (no LRU
        touch, no composition) — the planner's routing test.  A SPILLED
        entry counts: faulting it back is one mmap read, far cheaper than
        the walk/recompose the router would otherwise pick."""
        self._sync()
        return (src, dst) in self._cache or (src, dst) in self._spilled

    def residency(self, src: str, dst: str) -> Optional[str]:
        """Where the composed ``(src, dst)`` relation lives right now:
        ``"ram"``, ``"spilled"``, or None (not composed).  No LRU touch, no
        fault — the EXPLAIN surface reads this."""
        self._sync()
        if (src, dst) in self._cache:
            return "ram"
        if (src, dst) in self._spilled:
            return "spilled"
        return None

    def probe_forward(self, masks, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool mask stack -> (B, |dst|) bool via the composed
        relation.  No path -> all-empty (matching the walking engine)."""
        return self._forward_probe(np.asarray(masks, dtype=bool), src, dst)

    def probe_backward(self, masks, dst: str, src: str) -> np.ndarray:
        """(B, |dst|) bool mask stack -> (B, |src|) bool: relation rows
        intersecting each probe set."""
        return self._backward_probe(np.asarray(masks, dtype=bool), src, dst)

    def q1_forward(self, src: str, rows, dst: str):
        """Q1 via ONE batched probe of the composed relation (no DAG walk)."""
        masks, batched = self._probe_masks(rows, self.index.datasets[src].n_rows)
        out = self._forward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q2_backward(self, dst: str, rows, src: str):
        """Q2: src rows whose composed relation row intersects the probe set."""
        masks, batched = self._probe_masks(rows, self.index.datasets[dst].n_rows)
        out = self._backward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q10_co_contributory(self, d1: str, rows, d2: str, via: str):
        """Records of ``d2`` co-contributing with ``rows`` of ``d1`` into
        ``via`` — two composed probes, zero DAG hops."""
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        via_rows = self.q1_forward(d1, rows, via)
        res = self.q2_backward(via, via_rows if batched else [via_rows], d2)
        return res if batched else res[0]

    def q11_co_dependency(self, d2: str, rows, d1: str, d3: str):
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        back = self.q2_backward(d2, rows if batched else [rows], d1)
        res = self.q1_forward(d1, back, d3)
        return res if batched else res[0]

    # -- impact invalidation --------------------------------------------------
    def stale_entries(self, datasets) -> List[Tuple[str, str, str]]:
        """Composed entries (resident or spilled) whose ``src`` → ``dst``
        DAG region intersects ``datasets``, as ``(src, dst, residency)``
        triples — exactly the relations an erasure/rewrite of those
        datasets' rows leaves stale.  A relation is stale when some
        affected dataset lies ON a ``src`` → ``dst`` path (endpoints
        included): the composed product sums over every such path, so a
        mid-chain rewrite poisons it even when both endpoints survive.
        Enumeration only — nothing is dropped, no LRU touch, no fault."""
        self._sync()
        affected = [d for d in set(datasets) if d in self.index.datasets]
        if not affected:
            return []
        keys = [(k, "ram") for k in self._cache]
        keys += [(k, "spilled") for k in self._spilled]
        out = []
        for (src, dst), residency in keys:
            if any(self.index.path_exists(src, m)
                   and self.index.path_exists(m, dst) for m in affected):
                out.append((src, dst, residency))
        return out

    def invalidate_datasets(self, datasets) -> List[Tuple[str, str, str]]:
        """Drop every :meth:`stale_entries` entry: resident entries leave
        the LRU (their bytes released), on-disk payloads are DELETED from
        the spill store.  Returns the dropped triples.  The append-only-DAG
        keep-on-append policy is untouched — this is the escape hatch for
        REWRITES (erasure, what-if rebuilds), where recorded history itself
        changes and cached compositions over it must not survive."""
        dropped = self.stale_entries(datasets)
        for src, dst, _residency in dropped:
            key = (src, dst)
            entry = self._cache.pop(key, None)
            if entry is not None:
                self._bytes -= entry.nbytes()
            self._spilled.pop(key, None)
            # a resident entry may ALSO hold a stale disk copy (spilled
            # once, faulted back): _store_meta remembers it — delete both
            if key in self._store_meta:
                del self._store_meta[key]
                if self._spill_store is not None:
                    self._spill_store.delete(("rel", self.index.name) + key)
        return dropped

    # -- introspection --------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        per_backend = {"csr": 0, "bitplane": 0, "structured": 0}
        for entry in self._cache.values():
            per_backend[entry.backend] += 1
        out = {
            "index": self.index.name,
            "backend": self.backend,
            "entries": len(self._cache),
            "entries_csr": per_backend["csr"],
            "entries_bitplane": per_backend["bitplane"],
            "entries_structured": per_backend["structured"],
            "bytes": self._bytes,
            "budget_bytes": self.memory_budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "conversions": self.conversions,
            "extends": self.extends,
            "recomposes": self.recomposes,
            "spills": self.spills,
            "rehydrations": self.rehydrations,
            "spilled_entries": len(self._spilled),
        }
        if self._spill_store is not None:
            out["spill"] = self._spill_store.stats()
        return out
