"""Composed hop-cache: memoized multi-hop lineage relations (paper §III-D/§IV).

Answering Q1/Q2/Q10/Q11 between DISTANT datasets by walking the op DAG costs
one CSR probe per hop per query.  The Einstein-summation machinery of
:mod:`repro.core.compose` can instead contract the whole path into ONE
composed relation; this module memoizes those relations so repeated /
batched queries between the same dataset pair become a single batched probe.

Design points:

* **Two backends.**  ``csr`` (host default, requires scipy) composes the
  per-op CSR halves with sparse boolean matmul — composition cost scales
  with nnz, matching the paper's sparse-tensor premise.  ``bitplane``
  composes packed uint32 relation bitplanes via :func:`compose_pair` (the
  :mod:`repro.kernels` bitmatmul — the Pallas path on TPU), and probes with
  :func:`bitplane_or_reduce` / ``kernels.ops.bitplane_probe``.
* **Multi-path exact** — ``relation(src, dst)`` accumulates over the op DAG
  in topological order, UNIONING the contributions of every input slot whose
  dataset is reachable from ``src``.  On DAGs where ``src`` reaches ``dst``
  through multiple paths (a diamond: one source feeding two branches
  re-joined downstream) the composed relation sums over ALL paths, exactly
  matching the hop-walking engine — not just the unique producer chain.
* **Lazy + incremental** — every intermediate ``(src, mid)`` accumulation is
  cached, so a later query to a further dataset reuses the cached prefix and
  composes only the new suffix.
* **Eviction-bounded** — an LRU keyed on ``(src, dst)`` with a byte budget
  (``memory_budget_bytes``), honoring the paper's minimal-memory goal: the
  cache trades recompute for memory and can be sized down to nothing.
* **Append-safe** — the op DAG is append-only (one producer per dataset,
  enforced by ``ProvenanceIndex.record``), so composed relations between
  existing datasets stay exact when new ops are recorded and the cache is
  kept across version bumps — continuous serving reuses its lineage
  relations instead of recomposing per generation.

When NO path exists, the probe methods answer empty (matching the walking
engine); ``relation`` itself raises ``KeyError``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.compose import (
    HAVE_SCIPY,
    compose_pair,
    compose_pair_csr,
    op_bitplane,
    op_csr,
)
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    bitplane_or_reduce,
    pack_bitplane,
    unpack_bitplane,
)

__all__ = ["ComposedIndex"]


def _rel_nbytes(rel) -> int:
    if isinstance(rel, np.ndarray):
        return int(rel.nbytes)
    return int(rel.data.nbytes + rel.indices.nbytes + rel.indptr.nbytes)


class ComposedIndex:
    """Memoized composed-relation store + batched probe engine over one
    :class:`ProvenanceIndex`."""

    def __init__(
        self,
        index: ProvenanceIndex,
        memory_budget_bytes: int = 64 << 20,
        backend: Optional[str] = None,
        use_pallas: bool = False,
    ) -> None:
        if backend is None:
            backend = "csr" if (HAVE_SCIPY and not use_pallas) else "bitplane"
        if backend not in ("csr", "bitplane"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "csr" and not HAVE_SCIPY:
            raise ImportError("backend='csr' requires scipy")
        self.index = index
        self.backend = backend
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.use_pallas = use_pallas
        self._cache: "OrderedDict[Tuple[str, str], object]" = OrderedDict()
        self._bytes = 0
        self._version = index.version
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- cache plumbing -----------------------------------------------------
    def _sync(self) -> None:
        """Reconcile with the index after writes.

        The op DAG is APPEND-ONLY (every dataset has exactly one producer —
        ``ProvenanceIndex.record`` rejects duplicate output ids — and a new
        op can only produce a NEW dataset, never splice a path between two
        existing ones), so composed relations between existing datasets stay
        exact across version bumps and the cache is KEPT.  Continuous
        serving (one recorded op per request batch) therefore reuses its
        composed lineage relations instead of recomposing per generation.
        """
        self._version = self.index.version

    def _insert(self, key: Tuple[str, str], rel) -> None:
        nbytes = _rel_nbytes(rel)
        if nbytes > self.memory_budget_bytes:
            return  # larger than the whole budget: serve uncached
        self._cache[key] = rel
        self._cache.move_to_end(key)
        self._bytes += nbytes
        while self._bytes > self.memory_budget_bytes and len(self._cache) > 1:
            _, evicted = self._cache.popitem(last=False)
            self._bytes -= _rel_nbytes(evicted)
            self.evictions += 1

    def _lookup(self, key: Tuple[str, str]):
        rel = self._cache.get(key)
        if rel is not None:
            self._cache.move_to_end(key)
        return rel

    # -- backend primitives ---------------------------------------------------
    def _identity(self, n: int):
        if self.backend == "csr":
            import scipy.sparse as sp

            return sp.identity(n, dtype=np.float32, format="csr")
        words = np.zeros((n, max((n + 31) // 32, 1)), dtype=np.uint32)
        i = np.arange(n)
        words[i, i // 32] = np.left_shift(np.uint32(1), (i % 32).astype(np.uint32))
        return words

    def _op_step(self, op, slot):
        if self.backend == "csr":
            return op_csr(op.tensor, slot)
        return op_bitplane(op.tensor, slot)

    def _compose(self, acc, step, n_mid: int):
        if self.backend == "csr":
            return compose_pair_csr(acc, step)
        return compose_pair(acc, step, n_mid, use_pallas=self.use_pallas)

    def _union(self, a, b):
        """(OR)-union two relations — the sum over parallel DAG paths."""
        if self.backend == "csr":
            c = (a + b).tocsr()
            c.data = np.ones_like(c.data)
            return c
        return np.bitwise_or(a, b)

    # -- the composed relation ----------------------------------------------
    def relation(self, src: str, dst: str):
        """The composed ``src`` → ``dst`` relation (scipy CSR or packed
        bitplane, per backend), from cache or composed incrementally.

        Accumulates over the op DAG in topological order restricted to ops
        that lie on some ``src`` → ``dst`` path: each op's output relation is
        the UNION over its input slots of (input relation ∘ slot step), so
        multi-path DAGs (diamonds, self-joins) compose exactly.  Every
        intermediate ``(src, mid)`` accumulation is cached — later queries
        to further datasets reuse the prefix.
        """
        self._sync()
        cached = self._lookup((src, dst))
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        if src == dst:
            rel = self._identity(self.index.datasets[src].n_rows)
            self._insert((src, dst), rel)
            return rel
        # ops on a src ~> dst path: downstream of src AND upstream of dst.
        # (Reachable-from-src ancestors of any such op are themselves in the
        # set, so the accumulation below never misses a contribution.)
        up_ids = {op.op_id for op in self.index.upstream_ops(dst)}
        chain = [
            op for op in self.index.downstream_ops(src) if op.op_id in up_ids
        ]
        rels: Dict[str, object] = {src: None}  # None = the implicit identity
        for op in chain:
            out = op.output_id
            hit = self._lookup((src, out))
            if hit is not None:
                self.hits += 1
                rels[out] = hit
                continue
            acc = None
            for k, in_id in enumerate(op.input_ids):
                if in_id not in rels:
                    continue  # input unreachable from src: contributes nothing
                step = self._op_step(op, k)
                prefix = rels[in_id]
                contrib = (
                    step
                    if prefix is None
                    else self._compose(prefix, step, op.tensor.n_in[k])
                )
                acc = contrib if acc is None else self._union(acc, contrib)
            if acc is None:
                continue
            rels[out] = acc
            self._insert((src, out), acc)
        if dst not in rels or rels[dst] is None:
            raise KeyError(f"no dataflow path {src} -> {dst}")
        return rels[dst]

    # -- batched probes -------------------------------------------------------
    def _probe_masks(self, rows, n: int) -> Tuple[np.ndarray, bool]:
        from repro.core.query import _as_mask, _as_mask_batch, is_probe_batch

        if is_probe_batch(rows):
            return _as_mask_batch(rows, n), True
        return _as_mask(rows, n)[None, :], False

    def _try_relation(self, src: str, dst: str):
        """``relation`` for probes: no dataflow path -> None (probes answer
        empty, matching the walking engine; ``relation`` itself still raises
        so relation-materializing callers get the loud error)."""
        try:
            return self.relation(src, dst)
        except KeyError:
            return None

    def _forward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool -> (B, |dst|) bool through the composed relation."""
        rel = self._try_relation(src, dst)
        if rel is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[dst].n_rows), dtype=bool)
        if self.backend == "csr":
            return np.asarray(masks.astype(np.float32) @ rel) > 0
        if self.use_pallas:
            from repro.kernels import ops as K  # late import: host path stays jax-free

            words = np.asarray(K.bitplane_probe(pack_bitplane(masks), rel))
        else:
            n_src = self.index.datasets[src].n_rows
            words = bitplane_or_reduce(pack_bitplane(masks), rel, n_src)
        return unpack_bitplane(words, self.index.datasets[dst].n_rows)

    def _backward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |dst|) bool -> (B, |src|) bool: rows of the composed relation
        intersecting each probe set."""
        rel = self._try_relation(src, dst)
        if rel is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[src].n_rows), dtype=bool)
        if self.backend == "csr":
            return (rel @ masks.astype(np.float32).T).T > 0
        words = pack_bitplane(masks)
        return np.stack([(rel & w[None, :]).any(axis=1) for w in words], axis=0)

    # -- mask-stack probes (the QuerySession entry points) ---------------------
    def contains(self, src: str, dst: str) -> bool:
        """Whether the ``src`` → ``dst`` relation is already composed (no LRU
        touch, no composition) — the planner's routing test."""
        self._sync()
        return (src, dst) in self._cache

    def probe_forward(self, masks, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool mask stack -> (B, |dst|) bool via the composed
        relation.  No path -> all-empty (matching the walking engine)."""
        return self._forward_probe(np.asarray(masks, dtype=bool), src, dst)

    def probe_backward(self, masks, dst: str, src: str) -> np.ndarray:
        """(B, |dst|) bool mask stack -> (B, |src|) bool: relation rows
        intersecting each probe set."""
        return self._backward_probe(np.asarray(masks, dtype=bool), src, dst)

    def q1_forward(self, src: str, rows, dst: str):
        """Q1 via ONE batched probe of the composed relation (no DAG walk)."""
        masks, batched = self._probe_masks(rows, self.index.datasets[src].n_rows)
        out = self._forward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q2_backward(self, dst: str, rows, src: str):
        """Q2: src rows whose composed relation row intersects the probe set."""
        masks, batched = self._probe_masks(rows, self.index.datasets[dst].n_rows)
        out = self._backward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q10_co_contributory(self, d1: str, rows, d2: str, via: str):
        """Records of ``d2`` co-contributing with ``rows`` of ``d1`` into
        ``via`` — two composed probes, zero DAG hops."""
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        via_rows = self.q1_forward(d1, rows, via)
        res = self.q2_backward(via, via_rows if batched else [via_rows], d2)
        return res if batched else res[0]

    def q11_co_dependency(self, d2: str, rows, d1: str, d3: str):
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        back = self.q2_backward(d2, rows if batched else [rows], d1)
        res = self.q1_forward(d1, back, d3)
        return res if batched else res[0]

    # -- introspection --------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {
            "backend": self.backend,
            "entries": len(self._cache),
            "bytes": self._bytes,
            "budget_bytes": self.memory_budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
