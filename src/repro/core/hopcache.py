"""Composed hop-cache: memoized multi-hop lineage relations (paper §III-D/§IV).

Answering Q1/Q2/Q10/Q11 between DISTANT datasets by walking the op DAG costs
one CSR probe per hop per query.  The Einstein-summation machinery of
:mod:`repro.core.compose` can instead contract the whole path into ONE
composed relation; this module memoizes those relations so repeated /
batched queries between the same dataset pair become a single batched probe.

Design points:

* **Two backends.**  ``csr`` (host default, requires scipy) composes the
  per-op CSR halves with sparse boolean matmul — composition cost scales
  with nnz, matching the paper's sparse-tensor premise.  ``bitplane``
  composes packed uint32 relation bitplanes via :func:`compose_pair` (the
  :mod:`repro.kernels` bitmatmul — the Pallas path on TPU), and probes with
  :func:`bitplane_or_reduce` / ``kernels.ops.bitplane_probe``.
* **Lazy + incremental** — ``relation(src, dst)`` finds the longest cached
  prefix ``relation(src, mid)`` along the producer path and extends it hop
  by hop, caching every prefix for later queries to further datasets.
* **Eviction-bounded** — an LRU keyed on ``(src, dst)`` with a byte budget
  (``memory_budget_bytes``), honoring the paper's minimal-memory goal: the
  cache trades recompute for memory and can be sized down to nothing.
* **Write-invalidated** — keyed on ``ProvenanceIndex.version``; recording a
  new op drops cached relations (paths may lengthen).

Caveat (inherited from :func:`repro.core.compose.path_tensors`): the composed
relation follows the unique producer path from ``dst`` back to ``src``.  On
DAGs where ``src`` reaches ``dst`` through MULTIPLE paths (e.g. a self-join),
use the hop-walking engine in :mod:`repro.core.query` instead.  When NO path
exists, the probe methods answer empty (matching the walking engine);
``relation`` itself raises ``KeyError``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.compose import (
    HAVE_SCIPY,
    compose_pair,
    compose_pair_csr,
    op_bitplane,
    op_csr,
    path_tensors,
)
from repro.core.pipeline import ProvenanceIndex
from repro.core.provtensor import (
    bitplane_or_reduce,
    pack_bitplane,
    unpack_bitplane,
)

__all__ = ["ComposedIndex"]


def _rel_nbytes(rel) -> int:
    if isinstance(rel, np.ndarray):
        return int(rel.nbytes)
    return int(rel.data.nbytes + rel.indices.nbytes + rel.indptr.nbytes)


class ComposedIndex:
    """Memoized composed-relation store + batched probe engine over one
    :class:`ProvenanceIndex`."""

    def __init__(
        self,
        index: ProvenanceIndex,
        memory_budget_bytes: int = 64 << 20,
        backend: Optional[str] = None,
        use_pallas: bool = False,
    ) -> None:
        if backend is None:
            backend = "csr" if (HAVE_SCIPY and not use_pallas) else "bitplane"
        if backend not in ("csr", "bitplane"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "csr" and not HAVE_SCIPY:
            raise ImportError("backend='csr' requires scipy")
        self.index = index
        self.backend = backend
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.use_pallas = use_pallas
        self._cache: "OrderedDict[Tuple[str, str], object]" = OrderedDict()
        self._bytes = 0
        self._version = index.version
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- cache plumbing -----------------------------------------------------
    def _sync(self) -> None:
        if self.index.version != self._version:
            self._cache.clear()
            self._bytes = 0
            self._version = self.index.version

    def _insert(self, key: Tuple[str, str], rel) -> None:
        nbytes = _rel_nbytes(rel)
        if nbytes > self.memory_budget_bytes:
            return  # larger than the whole budget: serve uncached
        self._cache[key] = rel
        self._cache.move_to_end(key)
        self._bytes += nbytes
        while self._bytes > self.memory_budget_bytes and len(self._cache) > 1:
            _, evicted = self._cache.popitem(last=False)
            self._bytes -= _rel_nbytes(evicted)
            self.evictions += 1

    def _lookup(self, key: Tuple[str, str]):
        rel = self._cache.get(key)
        if rel is not None:
            self._cache.move_to_end(key)
        return rel

    # -- backend primitives ---------------------------------------------------
    def _identity(self, n: int):
        if self.backend == "csr":
            import scipy.sparse as sp

            return sp.identity(n, dtype=np.float32, format="csr")
        words = np.zeros((n, max((n + 31) // 32, 1)), dtype=np.uint32)
        i = np.arange(n)
        words[i, i // 32] = np.left_shift(np.uint32(1), (i % 32).astype(np.uint32))
        return words

    def _op_step(self, op, slot):
        if self.backend == "csr":
            return op_csr(op.tensor, slot)
        return op_bitplane(op.tensor, slot)

    def _compose(self, acc, step, n_mid: int):
        if self.backend == "csr":
            return compose_pair_csr(acc, step)
        return compose_pair(acc, step, n_mid, use_pallas=self.use_pallas)

    # -- the composed relation ----------------------------------------------
    def relation(self, src: str, dst: str):
        """The composed ``src`` → ``dst`` relation (scipy CSR or packed
        bitplane, per backend), from cache or composed incrementally."""
        self._sync()
        cached = self._lookup((src, dst))
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        if src == dst:
            rel = self._identity(self.index.datasets[src].n_rows)
            self._insert((src, dst), rel)
            return rel
        chain = path_tensors(self.index, src, dst)
        # longest cached prefix: datasets along the path are src, out_1 .. dst
        path_ids = [src] + [op.output_id for op, _ in chain]
        start = 0
        acc = None
        for j in range(len(path_ids) - 1, 0, -1):
            hit = self._lookup((src, path_ids[j]))
            if hit is not None:
                self.hits += 1
                acc, start = hit, j
                break
        for j in range(start, len(chain)):
            op, slot = chain[j]
            step = self._op_step(op, slot)
            acc = step if acc is None else self._compose(
                acc, step, op.tensor.n_in[slot])
            self._insert((src, path_ids[j + 1]), acc)
        return acc

    # -- batched probes -------------------------------------------------------
    def _probe_masks(self, rows, n: int) -> Tuple[np.ndarray, bool]:
        from repro.core.query import _as_mask, _as_mask_batch, is_probe_batch

        if is_probe_batch(rows):
            return _as_mask_batch(rows, n), True
        return _as_mask(rows, n)[None, :], False

    def _try_relation(self, src: str, dst: str):
        """``relation`` for probes: no dataflow path -> None (probes answer
        empty, matching the walking engine; ``relation`` itself still raises
        so relation-materializing callers get the loud error)."""
        try:
            return self.relation(src, dst)
        except KeyError:
            return None

    def _forward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |src|) bool -> (B, |dst|) bool through the composed relation."""
        rel = self._try_relation(src, dst)
        if rel is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[dst].n_rows), dtype=bool)
        if self.backend == "csr":
            return np.asarray(masks.astype(np.float32) @ rel) > 0
        if self.use_pallas:
            from repro.kernels import ops as K  # late import: host path stays jax-free

            words = np.asarray(K.bitplane_probe(pack_bitplane(masks), rel))
        else:
            n_src = self.index.datasets[src].n_rows
            words = bitplane_or_reduce(pack_bitplane(masks), rel, n_src)
        return unpack_bitplane(words, self.index.datasets[dst].n_rows)

    def _backward_probe(self, masks: np.ndarray, src: str, dst: str) -> np.ndarray:
        """(B, |dst|) bool -> (B, |src|) bool: rows of the composed relation
        intersecting each probe set."""
        rel = self._try_relation(src, dst)
        if rel is None:
            return np.zeros(
                (masks.shape[0], self.index.datasets[src].n_rows), dtype=bool)
        if self.backend == "csr":
            return (rel @ masks.astype(np.float32).T).T > 0
        words = pack_bitplane(masks)
        return np.stack([(rel & w[None, :]).any(axis=1) for w in words], axis=0)

    def q1_forward(self, src: str, rows, dst: str):
        """Q1 via ONE batched probe of the composed relation (no DAG walk)."""
        masks, batched = self._probe_masks(rows, self.index.datasets[src].n_rows)
        out = self._forward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q2_backward(self, dst: str, rows, src: str):
        """Q2: src rows whose composed relation row intersects the probe set."""
        masks, batched = self._probe_masks(rows, self.index.datasets[dst].n_rows)
        out = self._backward_probe(masks, src, dst)
        res = [np.flatnonzero(m) for m in out]
        return res if batched else res[0]

    def q10_co_contributory(self, d1: str, rows, d2: str, via: str):
        """Records of ``d2`` co-contributing with ``rows`` of ``d1`` into
        ``via`` — two composed probes, zero DAG hops."""
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        via_rows = self.q1_forward(d1, rows, via)
        res = self.q2_backward(via, via_rows if batched else [via_rows], d2)
        return res if batched else res[0]

    def q11_co_dependency(self, d2: str, rows, d1: str, d3: str):
        from repro.core.query import is_probe_batch

        batched = is_probe_batch(rows)
        back = self.q2_backward(d2, rows if batched else [rows], d1)
        res = self.q1_forward(d1, back, d3)
        return res if batched else res[0]

    # -- introspection --------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {
            "backend": self.backend,
            "entries": len(self._cache),
            "bytes": self._bytes,
            "budget_bytes": self.memory_budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
