"""Cost model for provenance query planning (ROADMAP items (c)/(e)).

The paper's einsum composition (§IV) only wins when its one-time cost is
amortized; before this module the planner used a blind batch-size heuristic
(``hopcache_min_batch``) and the chain DP costed merges by *dense* dims even
though the CSR backend's real cost scales with nnz.  This module centralizes

* **per-relation statistics** (:class:`RelStats`) — rows, cols, nnz, density,
  read straight off each :class:`~repro.core.provtensor.ProvTensor`'s COO /
  CSR without materializing anything new;
* **composition cost estimates** per backend — sparse boolean matmul cost
  scaling with nnz (:func:`spmm_cost`) vs packed-bitplane word ops
  (:func:`bitplane_cost`) — and the density threshold where the packed
  backend overtakes CSR (:func:`pick_backend`);
* an **nnz-aware matrix-chain DP** (:func:`plan_chain_stats`) replacing the
  dims-only DP for einsum chain ordering;
* the **planner model** (:class:`CostModel`) comparing estimated walk cost
  (hops × batched gather) against amortized compose-then-probe cost, with
  per-pair demand tracking so repeated small-batch streams eventually
  amortize a composition the old heuristic never attempted.

Cost units are *estimated nanoseconds on the host*; only ratios matter, the
constants below were calibrated once against ``benchmarks/bench_query.py``
on the CPU container and are deliberately coarse.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "RelStats",
    "Constants",
    "active_constants",
    "set_constants",
    "reset_constants",
    "constants_provenance",
    "maybe_load_calibration",
    "DENSITY_THRESHOLD",
    "CROSS_FALLBACK_MIN_DEMAND",
    "compose_est",
    "spmm_cost",
    "bitplane_cost",
    "structured_cost",
    "pick_backend",
    "plan_chain_stats",
    "extend_tail_cost",
    "extend_vs_recompose",
    "relation_probe_cost",
    "cross_route_choose",
    "CostModel",
]

# -- calibration constants (estimated ns; ratios are what matters) -----------
C_HOP_OVERHEAD = 20_000.0     # python/numpy dispatch per walk hop
C_MASK_ELEM = 2.0             # per (B, n) mask-stack element scanned per hop
C_GATHER = 40.0               # per (frontier row, neighbor) pair gathered
C_SPMM_OVERHEAD = 45_000.0    # per scipy sparse matmul call
C_SPMM_FLOP = 25.0            # per sparse boolean-semiring flop
C_WORD_OP = 3.0               # per uint32 word op in a bitplane compose
C_PROBE_OVERHEAD = 30_000.0   # per composed-relation probe call
C_STRUCT_OVERHEAD = 20_000.0  # per closed-form (gather∘gather) compose call
C_TAKE = 1.0                  # per element of the one np.take it performs
C_STITCH_OVERHEAD = 15_000.0  # per link alignment stitch of a mask stack

# Legacy demand floor for federated stitched-relation composition, used only
# when per-segment relation statistics are unavailable (a member that cannot
# answer relation_stats) — the constant the cost-model gate replaces.
CROSS_FALLBACK_MIN_DEMAND = 32

# Density above which the packed-bitplane backend out-costs CSR composition:
# csr flops ≈ 32·d_a·d_b × bitplane word ops, and a sparse flop costs ~8 word
# ops of indexing — the crossover sits near sqrt(1/(32·8)) ≈ 0.06 geometric-
# mean operand density.  Kept as one named constant so tests/docs can pin it.
DENSITY_THRESHOLD = 0.06

# Per-device-dispatch overhead (one jit'd oracle call / Pallas launch) — the
# term the fused batched-walk kernel pays ONCE instead of K×3 times.
C_LAUNCH_OVERHEAD = 50_000.0

# Machine roofline terms (TPU v5e defaults): shared with
# benchmarks/bench_compose_roofline.py via Constants so the roofline and the
# cost model can never disagree about the machine.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
# VPU: 8 cores x (8,128) lanes x ~940 MHz ~= 1e12 lane-ops/s; each uint32
# lane-op retires 32 boolean MACs in the bitplane kernel.
VPU_WORD_OPS = 0.96e12


@dataclasses.dataclass(frozen=True)
class Constants:
    """One coherent set of cost/roofline constants, with provenance.

    The module-level ``C_*`` literals above stay the uncalibrated defaults
    (``Constants()`` reproduces them bit-for-bit); ``repro.core.calibrate``
    fits a measured set on the actual backend and installs it via
    :func:`set_constants`.  Every cost function in this module reads the
    ACTIVE set, so one install re-prices the whole router — CostModel,
    ``ComposedIndex(backend="auto")`` and ``QuerySession._strategy`` all
    consume it implicitly.
    """

    c_hop_overhead: float = C_HOP_OVERHEAD
    c_mask_elem: float = C_MASK_ELEM
    c_gather: float = C_GATHER
    c_spmm_overhead: float = C_SPMM_OVERHEAD
    c_spmm_flop: float = C_SPMM_FLOP
    c_word_op: float = C_WORD_OP
    c_probe_overhead: float = C_PROBE_OVERHEAD
    c_struct_overhead: float = C_STRUCT_OVERHEAD
    c_take: float = C_TAKE
    c_stitch_overhead: float = C_STITCH_OVERHEAD
    c_launch_overhead: float = C_LAUNCH_OVERHEAD
    density_threshold: float = DENSITY_THRESHOLD
    # machine roofline terms (satellite of the calibration file)
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    vpu_word_ops: float = VPU_WORD_OPS
    # provenance: where these numbers came from
    source: str = "default"       # "default" | "calibrated"
    device: str = ""              # device kind the calibration ran on
    path: str = ""                # calibration file, when source=="calibrated"

    def provenance(self) -> Dict[str, object]:
        """What ``explain()`` surfaces: which constants decided the routing."""
        return {
            "source": self.source,
            "device": self.device or None,
            "path": self.path or None,
            "density_threshold": self.density_threshold,
            "c_word_op": self.c_word_op,
            "c_spmm_flop": self.c_spmm_flop,
            "c_launch_overhead": self.c_launch_overhead,
        }


_ACTIVE = Constants()
_AUTOLOAD_DONE = False


def active_constants() -> Constants:
    """The constant set every cost function in this module currently reads."""
    return _ACTIVE


def set_constants(constants: Constants) -> None:
    """Install a constant set (e.g. a calibrated one) module-wide."""
    global _ACTIVE
    _ACTIVE = constants


def reset_constants() -> None:
    """Back to the uncalibrated defaults (and re-arm autoload)."""
    global _ACTIVE, _AUTOLOAD_DONE
    _ACTIVE = Constants()
    _AUTOLOAD_DONE = False


def constants_provenance() -> Dict[str, object]:
    return _ACTIVE.provenance()


def maybe_load_calibration() -> Constants:
    """Install constants from the calibration file, if one is present.

    The file path comes from ``$REPRO_CALIBRATION`` (else
    ``~/.cache/repro/calibration.json``); entries are keyed by device kind
    (see :mod:`repro.core.calibrate`).  Checked once per process (re-armed
    by :func:`reset_constants`); with no file, the defaults stay active —
    routing is bit-for-bit today's.  Never imports jax: host-only sessions
    stay jax-free.
    """
    global _AUTOLOAD_DONE
    if _AUTOLOAD_DONE or _ACTIVE.source != "default":
        return _ACTIVE
    _AUTOLOAD_DONE = True
    path = os.environ.get("REPRO_CALIBRATION") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "calibration.json")
    if not os.path.exists(path):
        return _ACTIVE
    from repro.core.calibrate import load_constants  # lazy: json/numpy only

    loaded = load_constants(path)
    if loaded is not None:
        set_constants(loaded)
    return _ACTIVE


@dataclasses.dataclass(frozen=True)
class RelStats:
    """Statistics of one binary relation (op step or composed accumulation).

    ``structured`` marks relations the structured layer keeps implicit —
    at most one source row per destination row (identity / selection /
    gather slots and their closed-form compositions): composing two such
    relations is one O(cols) ``np.take``, and the result stores one int32
    per destination row instead of a CSR."""

    rows: int
    cols: int
    nnz: int
    structured: bool = False

    @property
    def density(self) -> float:
        cells = self.rows * self.cols
        return self.nnz / cells if cells else 0.0

    @property
    def out_degree(self) -> float:
        return self.nnz / self.rows if self.rows else 0.0

    def est_bytes(self) -> int:
        """Estimated bytes of the cheaper materialization (implicit gather
        array vs CSR indices+ptr vs packed bitplane) — the retention check
        against a cache budget."""
        csr = 8 * self.nnz + 4 * (self.rows + 1)
        bitplane = 4 * self.rows * max((self.cols + 31) // 32, 1)
        if self.structured:
            return min(4 * self.cols, csr, bitplane)
        return min(csr, bitplane)

    @staticmethod
    def from_slot(tensor, slot: int) -> "RelStats":
        """Stats of one op tensor's forward relation for one input slot —
        read off the implicit structure when the tensor has one, else an
        O(nnz) count off the COO; no CSR/bitplane materialization."""
        return RelStats(
            rows=int(tensor.n_in[slot]),
            cols=int(tensor.n_out),
            nnz=tensor.slot_nnz(slot),
            structured=tensor.slot_structure(slot) is not None,
        )

    @staticmethod
    def from_slot_range(tensor, slot: int, lo: int, hi: int) -> "RelStats":
        """Stats of one op slot restricted to output rows ``[lo, hi)`` —
        the SHARD-LOCAL relation a row-range-partitioned index composes.
        Reads ``slot_nnz_range`` (interval arithmetic / one windowed count),
        so per-shard backend choice never touches the other shards' links."""
        lo, hi = max(int(lo), 0), min(int(hi), int(tensor.n_out))
        return RelStats(
            rows=int(tensor.n_in[slot]),
            cols=max(hi - lo, 0),
            nnz=tensor.slot_nnz_range(slot, lo, hi),
            structured=tensor.slot_structure(slot) is not None,
        )


def compose_est(a: RelStats, b: RelStats) -> RelStats:
    """Estimated stats of ``a ∘ b`` (boolean-semiring product).

    Expected path count is ``a.nnz · b.out_degree``; the union over paths
    saturates the binary relation, modeled as ``cells·(1 - exp(-paths/cells))``
    (independent-placement approximation) so density never exceeds 1.
    Gather∘gather stays a gather, so structuredness is preserved exactly.
    """
    rows, cols = a.rows, b.cols
    structured = a.structured and b.structured
    cells = rows * cols
    if cells == 0:
        return RelStats(rows, cols, 0, structured)
    paths = a.nnz * b.out_degree
    nnz = cells * -math.expm1(-paths / cells)
    return RelStats(rows, cols, int(round(nnz)), structured)


def spmm_cost(a: RelStats, b: RelStats) -> float:
    """CSR (OR,AND) matmul cost: scales with nnz, not dims."""
    return _ACTIVE.c_spmm_overhead + _ACTIVE.c_spmm_flop * a.nnz * b.out_degree


def bitplane_cost(a: RelStats, b: RelStats) -> float:
    """Packed-bitplane compose cost: dense word ops over (rows, mid, cols/32)."""
    words = a.rows * b.rows * max((b.cols + 31) // 32, 1)
    return _ACTIVE.c_word_op * words


def structured_cost(a: RelStats, b: RelStats) -> float:
    """Closed-form gather∘gather compose cost: ONE ``np.take`` over the
    destination dimension — nnz- and density-independent."""
    return _ACTIVE.c_struct_overhead + _ACTIVE.c_take * b.cols


def union_est(a: RelStats, b: RelStats) -> RelStats:
    """Estimated stats of ``a ∪ b`` — the sum over parallel DAG paths,
    capped at full.  A union generally breaks gather structure (two parents
    per destination), so the estimate drops the structured flag; the
    executor still keeps provably-disjoint unions (append's block split)
    structured, making this conservative."""
    cells = a.rows * a.cols
    return RelStats(a.rows, a.cols, min(cells, a.nnz + b.nnz))


def compose_cost_pair(a: RelStats, b: RelStats, backend: str,
                      have_scipy: bool = True) -> float:
    """Cost of one ``a ∘ b`` merge.  ``backend="auto"`` prices structured
    pairs at their closed form (one take), everything else in the
    representation :func:`pick_backend` would choose for the estimated
    result — the adaptive backend the composed hop-cache actually runs."""
    if backend == "auto":
        if a.structured and b.structured:
            return structured_cost(a, b)
        backend = pick_backend(compose_est(a, b).density, have_scipy)
    return spmm_cost(a, b) if backend == "csr" else bitplane_cost(a, b)


def pick_backend(density: float, have_scipy: bool = True) -> str:
    """Backend for a relation of the given density: CSR below
    :data:`DENSITY_THRESHOLD`, packed bitplane above it."""
    if not have_scipy:
        return "bitplane"
    return "bitplane" if density >= _ACTIVE.density_threshold else "csr"


def plan_chain_stats(stats: Sequence[RelStats], backend: str = "csr",
                     have_scipy: bool = True) -> List[Tuple[int, int]]:
    """nnz-aware matrix-chain DP over relation statistics.

    Same merge-order contract as :func:`repro.core.compose.plan_chain`
    (``(i, k)`` merges over a working list, innermost first), but merge cost
    is the *backend's* estimate — nnz-scaled sparse matmul for ``csr``,
    per-merge :func:`pick_backend` pricing for ``auto`` — and intermediate
    stats propagate through :func:`compose_est` instead of assuming dense
    dims.  A filter-heavy 0.1%-dense segment is therefore nearly free to
    merge early, where the dims-only DP saw it as square.  (A pure
    ``bitplane`` backend prices by dims alone — its word ops are
    nnz-independent — and reduces to the classic DP.)
    """
    order, _, _ = _chain_dp(stats, backend, have_scipy)
    return order


def _chain_dp(stats: Sequence[RelStats], backend: str,
              have_scipy: bool) -> Tuple[List[Tuple[int, int]], float,
                                         Optional[RelStats]]:
    """The DP behind :func:`plan_chain_stats`, additionally returning the
    optimal total merge cost and the folded whole-chain estimate (what
    :func:`extend_vs_recompose` prices a recompose at)."""
    n = len(stats)
    if n == 0:
        return [], 0.0, None
    if n == 1:
        return [], 0.0, stats[0]
    # Canonical per-segment stats: est[i][j] = left-to-right fold of the
    # segment.  The true relation is associative; compose_est's saturation
    # is not, so fixing one fold order keeps the DP's optimal substructure
    # exact (segment stats must not depend on the split being considered).
    est: List[List[Optional[RelStats]]] = [[None] * n for _ in range(n)]
    for i in range(n):
        est[i][i] = stats[i]
        for j in range(i + 1, n):
            est[i][j] = compose_est(est[i][j - 1], stats[j])
    INF = float("inf")
    cost = [[0.0] * n for _ in range(n)]
    split = [[0] * n for _ in range(n)]
    for length in range(2, n + 1):
        for i in range(0, n - length + 1):
            j = i + length - 1
            cost[i][j] = INF
            for k in range(i, j):
                c = (cost[i][k] + cost[k + 1][j]
                     + compose_cost_pair(est[i][k], est[k + 1][j], backend,
                                         have_scipy))
                if c < cost[i][j]:
                    cost[i][j] = c
                    split[i][j] = k
    order: List[Tuple[int, int]] = []

    def emit(i: int, j: int) -> None:
        if i == j:
            return
        k = split[i][j]
        emit(i, k)
        emit(k + 1, j)
        order.append((i, k))

    emit(0, n - 1)
    return order, cost[0][n - 1], est[0][n - 1]


def extend_tail_cost(prefix: RelStats, step: RelStats,
                     have_scipy: bool = True) -> float:
    """Cost of extending a warm composed ``prefix`` by ONE structured
    ``step`` via the closed forms in :mod:`repro.core.compose`: a
    structured prefix pays one take (:func:`structured_cost`); a dense
    prefix pays the COLUMN GATHER of ``extend_tail`` — O(nnz moved) for
    CSR, O(dense words) for bitplane — never a matmul."""
    if not step.structured:
        return compose_cost_pair(prefix, step, "auto", have_scipy)
    if prefix.structured:
        return structured_cost(prefix, step)
    if pick_backend(prefix.density, have_scipy) == "csr":
        moved = prefix.nnz * (step.nnz / max(step.rows, 1))
        return _ACTIVE.c_spmm_overhead + _ACTIVE.c_take * (moved + step.cols)
    words = prefix.rows * (max((prefix.cols + 31) // 32, 1)
                           + max((step.cols + 31) // 32, 1))
    return _ACTIVE.c_word_op * words


def extend_vs_recompose(prefix: RelStats, tail: Sequence[RelStats],
                        backend: str = "auto",
                        have_scipy: bool = True) -> Dict[str, object]:
    """Gate the hop-cache's streaming maintenance: when ops land on a warm
    composed ``prefix``, is it cheaper to EXTEND it step by step (the
    closed-form tail extension, left-to-right) or to RECOMPOSE — fold the
    ``tail`` by the nnz-aware chain DP in its own best order, then apply it
    to the prefix with one compose?

    Extension wins almost always for the structured tails streaming capture
    produces (each step is a take / column gather).  Recompose wins when
    the tail is DENSE and strongly row-reducing: folding a heavy tail first
    (where the DP is free to pick the cheap order) makes the single
    prefix-apply touch far fewer columns than dragging the full-width
    prefix through every hop.  Returns ``{"strategy", "extend_ns",
    "recompose_ns", "tail_order", "est"}``; a single-step tail is always
    "extend" (the two plans are the same plan).
    """
    tail = list(tail)
    if not tail:
        return {"strategy": "extend", "extend_ns": 0.0, "recompose_ns": 0.0,
                "tail_order": [], "est": prefix}
    extend_ns = 0.0
    acc = prefix
    for step in tail:
        extend_ns += extend_tail_cost(acc, step, have_scipy)
        acc = compose_est(acc, step)
    if len(tail) == 1:
        return {"strategy": "extend", "extend_ns": extend_ns,
                "recompose_ns": extend_ns, "tail_order": [], "est": acc}
    tail_order, tail_ns, folded = _chain_dp(tail, backend, have_scipy)
    # the final prefix-apply goes through the same closed forms the executor
    # uses: a structured folded tail is ONE column gather, not a matmul
    recompose_ns = tail_ns + extend_tail_cost(prefix, folded, have_scipy)
    strategy = "extend" if extend_ns <= recompose_ns else "recompose"
    return {"strategy": strategy, "extend_ns": extend_ns,
            "recompose_ns": recompose_ns, "tail_order": tail_order,
            "est": acc}


def relation_probe_cost(rel: Optional[RelStats], n_probes: int,
                        probe_rows: float = 1.0) -> float:
    """One batched probe of a composed relation: mask stacks in and out,
    plus the selected-row gather.  (:meth:`CostModel.probe_cost` and the
    federated cross-route gate share this one pricing.)"""
    if rel is None:
        return _ACTIVE.c_probe_overhead
    return (_ACTIVE.c_probe_overhead
            + _ACTIVE.c_mask_elem * n_probes * (rel.rows + rel.cols)
            + _ACTIVE.c_gather * n_probes * max(probe_rows, 1.0) * rel.out_degree)


def cross_route_choose(route_stats: Sequence[Optional[RelStats]],
                       member_compose_ns: float,
                       n_probes: int,
                       demand: int,
                       budget_bytes: Optional[int] = None) -> Dict[str, object]:
    """Segment-at-a-time vs stitched-relation execution for one federated
    route — the cost-model gate that replaces the blind ``cross_min_demand``
    constant (the carried PR 4 follow-up).

    ``route_stats`` holds oriented :class:`RelStats` for every hop of the
    route in traversal order: each member's composed relation AND each
    link's alignment matrix (rows = traversal-from dimension).  Costs:

    * **segments** — every probe batch pays one composed-relation probe per
      member hop plus one mask stitch per link hop, forever;
    * **stitched** — one-time composition (each member's relation compose,
      ``member_compose_ns``, plus the sparse-matmul chain folding the hops
      into ONE relation) amortized over the route's cumulative probe
      ``demand``, then one probe of the stitched relation per batch.

    A stitched relation estimated not to fit ``budget_bytes`` is never
    retained, so its composition cost cannot amortize — the gate then keeps
    segment execution (mirroring :meth:`CostModel.choose`'s budget guard).
    Any ``None`` in ``route_stats`` (a member that cannot price its
    relation) falls back to the legacy demand floor
    :data:`CROSS_FALLBACK_MIN_DEMAND`.
    """
    if not route_stats or any(s is None for s in route_stats):
        compose = demand >= CROSS_FALLBACK_MIN_DEMAND
        return {"strategy": "stitched" if compose else "segments",
                "estimated": False, "demand": demand,
                "segments_ns": 0.0, "stitched_ns": 0.0, "compose_ns": 0.0,
                "retainable": True, "est": None}
    segments_ns = 0.0
    folded: Optional[RelStats] = None
    chain_ns = 0.0
    for s in route_stats:
        # links price as one stitch of the live mask stack; member hops as a
        # composed-relation probe (what segment execution actually runs)
        if s.structured:
            segments_ns += _ACTIVE.c_stitch_overhead + _ACTIVE.c_mask_elem * n_probes * (
                s.rows + s.cols)
        else:
            segments_ns += relation_probe_cost(s, n_probes)
        if folded is None:
            folded = s
        else:
            chain_ns += spmm_cost(folded, s)
            folded = compose_est(folded, s)
    compose_ns = member_compose_ns + chain_ns
    retainable = budget_bytes is None or folded.est_bytes() <= budget_bytes
    stitched_ns = (relation_probe_cost(folded, n_probes)
                   + compose_ns * (n_probes / max(demand, 1)))
    strategy = ("stitched"
                if retainable and stitched_ns < segments_ns else "segments")
    return {"strategy": strategy, "estimated": True, "demand": demand,
            "segments_ns": segments_ns, "stitched_ns": stitched_ns,
            "compose_ns": compose_ns, "retainable": retainable, "est": folded}


# ---------------------------------------------------------------------------
# The planner model
# ---------------------------------------------------------------------------
class CostModel:
    """Walk-vs-compose cost estimates over one :class:`ProvenanceIndex`.

    Chains are append-only (one producer per dataset), so per-pair chain
    statistics are computed once and cached forever.  ``choose`` additionally
    tracks cumulative probe *demand* per pair: the one-time composition cost
    is amortized over the probes seen so far, so a stream of tiny probes to
    one far pair flips from walking to composing once enough demand
    accumulates — the case the old ``hopcache_min_batch`` heuristic
    mis-routed forever.
    """

    def __init__(self, index, have_scipy: Optional[bool] = None) -> None:
        from repro.core.compose import HAVE_SCIPY

        # first model in the process installs calibrated constants when a
        # calibration file exists; a no-op (bit-for-bit defaults) otherwise
        maybe_load_calibration()
        self.index = index
        self.have_scipy = HAVE_SCIPY if have_scipy is None else have_scipy
        self._chains: Dict[Tuple[str, str], Optional[List[RelStats]]] = {}
        self._composed: Dict[Tuple[str, str],
                             Tuple[Optional[RelStats], float]] = {}
        self._demand: Dict[Tuple[str, str], int] = {}

    # -- chain statistics ----------------------------------------------------
    def chain_stats(self, src: str, dst: str) -> Optional[List[RelStats]]:
        """Per-op relation stats along the ``src`` → ``dst`` DAG region, in
        topological order; ``None`` when no dataflow path exists.  Multi-input
        ops aggregate their on-path slots (nnz sums; rows sum — the walk
        frontier spans every contributing input)."""
        key = (src, dst)
        if key in self._chains:
            return self._chains[key]
        if src == dst:
            self._chains[key] = []
            return []
        up_ids = {op.op_id for op in self.index.upstream_ops(dst)}
        reach = {src}
        chain: List[RelStats] = []
        found = False
        for op in self.index.downstream_ops(src):
            if op.op_id not in up_ids:
                continue
            slots = [k for k, d in enumerate(op.input_ids) if d in reach]
            if not slots:
                continue
            reach.add(op.output_id)
            per = [RelStats.from_slot(op.tensor, k) for k in slots]
            chain.append(RelStats(
                rows=sum(s.rows for s in per),
                cols=int(op.tensor.n_out),
                nnz=sum(s.nnz for s in per),
            ))
            if op.output_id == dst:
                found = True
        result = chain if found else None
        self._chains[key] = result
        return result

    # -- cost terms ----------------------------------------------------------
    def walk_cost(self, chain: List[RelStats], n_probes: int,
                  probe_rows: float) -> float:
        """Hops × batched-gather.  Per hop: one python dispatch, a scan over
        the full (B, n_out) mask stack (``neighbor_mask_many`` allocates and
        scatters it whole — the dominant term for large batches), and a
        gather proportional to (batch × frontier × out-degree); the frontier
        grows multiplicatively along the chain, clamped by each dataset
        size."""
        frontier = max(probe_rows, 1.0)
        cost = 0.0
        for s in chain:
            cost += (_ACTIVE.c_hop_overhead
                     + _ACTIVE.c_mask_elem * n_probes * s.cols
                     + _ACTIVE.c_gather * n_probes * frontier * s.out_degree)
            frontier = min(float(s.cols), frontier * max(s.out_degree, 1e-9))
            frontier = max(frontier, 1.0)
        return cost

    def composed_estimate(self, src: str, dst: str
                          ) -> Tuple[Optional[RelStats], float]:
        """(estimated composed ``src`` → ``dst`` relation stats, estimated
        one-time composition cost), accumulated over the op DAG exactly the
        way :class:`~repro.core.hopcache.ComposedIndex` composes it:
        compose along each edge, UNION sibling-branch contributions at
        multi-input ops.  Linearizing parallel branches into one chain would
        multiply stats of relations whose dims don't even touch.  Cached per
        pair (append-only DAG).  ``(None, 0.0)`` when no path."""
        key = (src, dst)
        cached = self._composed.get(key)
        if cached is not None:
            return cached
        up_ids = {op.op_id for op in self.index.upstream_ops(dst)}
        rels: Dict[str, Optional[RelStats]] = {src: None}  # None = identity
        cost = 0.0
        for op in self.index.downstream_ops(src):
            if op.op_id not in up_ids:
                continue
            acc: Optional[RelStats] = None
            for k, in_id in enumerate(op.input_ids):
                if in_id not in rels:
                    continue
                step = RelStats.from_slot(op.tensor, k)
                prefix = rels[in_id]
                if prefix is None:
                    contrib = step   # the op's own relation: no compose work
                else:
                    cost += compose_cost_pair(prefix, step, "auto",
                                              self.have_scipy)
                    contrib = compose_est(prefix, step)
                acc = contrib if acc is None else union_est(acc, contrib)
            if acc is not None:
                rels[op.output_id] = acc
        rel = rels.get(dst)
        result = (rel, cost) if rel is not None else (None, 0.0)
        self._composed[key] = result
        return result

    def probe_cost(self, rel: Optional[RelStats], n_probes: int,
                   probe_rows: float) -> float:
        """One batched probe of the composed relation (see
        :func:`relation_probe_cost`)."""
        return relation_probe_cost(rel, n_probes, probe_rows)

    # -- the decision ---------------------------------------------------------
    def choose(self, src: str, dst: str, n_probes: int,
               probe_rows: float = 1.0, note: bool = True,
               budget_bytes: Optional[int] = None) -> Dict[str, object]:
        """Walk or compose-then-probe for one plan against pair (src, dst).

        Returns ``{"strategy", "walk_ns", "hopcache_ns", "compose_ns",
        "demand", "retainable"}``.  ``note=False`` (EXPLAIN) leaves demand
        untouched.  ``budget_bytes`` is the hop-cache's byte budget: a
        composed relation estimated NOT to fit is served uncached and
        recomposed on EVERY probe, so its composition cost is charged per
        plan instead of amortized over demand — without this check a
        too-small cache would flip to "hopcache" on accumulated demand and
        then recompose the whole chain per query, forever.
        """
        chain = self.chain_stats(src, dst)
        if chain is None or not chain:
            return {"strategy": "walk", "walk_ns": 0.0, "hopcache_ns": 0.0,
                    "compose_ns": 0.0, "demand": 0, "retainable": True,
                    "structured": False}
        pair = (src, dst)
        demand = self._demand.get(pair, 0) + n_probes
        if note:
            self._demand[pair] = demand
        walk = self.walk_cost(chain, n_probes, probe_rows)
        rel, compose = self.composed_estimate(src, dst)
        probe = self.probe_cost(rel, n_probes, probe_rows)
        retainable = (budget_bytes is None or rel is None
                      or rel.est_bytes() <= budget_bytes)
        # amortize the one-time compose over the demand observed so far —
        # but an unretainable relation is recomposed per plan: no amortization
        amortize = max(demand, 1) if retainable else max(n_probes, 1)
        hopcache = probe + compose * (n_probes / amortize)
        return {
            "strategy": "hopcache" if hopcache < walk else "walk",
            "walk_ns": walk,
            "hopcache_ns": hopcache,
            "compose_ns": compose,
            "demand": demand,
            "retainable": retainable,
            "structured": bool(rel is not None and rel.structured),
        }
