"""The ProvenanceIndex — the paper's Figure 2 model, array-resident.

Holds, per pipeline: dataset records, operation records with precedence
(a DAG), each operation's provenance tensor and schema annotations, and the
materialization policy (§III-E): source/sink datasets always kept, inputs of
*contextual* operations materialized, everything else recomputable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.capture import build_tensor
from repro.core.opcat import CaptureInfo, OpCategory
from repro.core.provtensor import ProvTensor
from repro.dataprep.table import Table

__all__ = ["DatasetRecord", "OpRecord", "ProvenanceIndex"]


@dataclasses.dataclass
class DatasetRecord:
    dataset_id: str
    n_rows: int
    n_cols: int
    columns: List[str]
    table: Optional[Table] = None       # materialized content (policy-driven)
    is_source: bool = False
    is_sink: bool = False

    @property
    def materialized(self) -> bool:
        return self.table is not None


@dataclasses.dataclass
class OpRecord:
    op_id: int
    info: CaptureInfo
    tensor: ProvTensor
    input_ids: List[str]
    output_id: str


class ProvenanceIndex:
    """In-memory (in-HBM when sharded) index of one pipeline's provenance."""

    def __init__(self, name: str = "pipeline", spill=None) -> None:
        self.name = name
        self.datasets: Dict[str, DatasetRecord] = {}
        self.ops: List[OpRecord] = []
        self.producer: Dict[str, int] = {}          # dataset -> producing op
        self.consumers: Dict[str, List[int]] = {}   # dataset -> consuming ops
        self.version = 0                            # bumped per recorded op;
        self._composed = None                       # hop-caches key on it
        self._session = None                        # shared QuerySession
        self._record_hooks: List = []               # capture observers
        # out-of-core op-tensor residency (None = everything stays in RAM):
        # accepts True / a path / a SpillStore / a SpillPolicy — cold tensors
        # serialize to the compact on-disk log and fault back on access
        if spill is not None and spill is not False:
            from repro.core.spill import TensorSpiller, resolve_spill

            self._spill = TensorSpiller(self, resolve_spill(spill))
        else:
            self._spill = None

    # -- capture hooks ---------------------------------------------------------
    def add_record_hook(self, fn):
        """Register a capture observer called on every :meth:`record`, after
        input validation and BEFORE the provenance tensor is built, as
        ``fn(input_ids, output_id, out_table, info, input_tables)``.

        This is the supported way to mirror the capture stream into a second
        system (the Chapman baseline in the benches, an audit log, a metrics
        sink) — replacing the old ``idx.record = wrapper`` monkeypatching,
        which silently broke whenever ``record`` grew a parameter.  Returns
        ``fn`` so it can be used as a decorator."""
        self._record_hooks.append(fn)
        return fn

    def remove_record_hook(self, fn) -> None:
        """Unregister a hook added with :meth:`add_record_hook`."""
        self._record_hooks.remove(fn)

    # -- registration ---------------------------------------------------------
    def add_source(self, dataset_id: str, table: Table) -> str:
        """Pipeline input datasets are always materialized (paper §III-E)."""
        self.datasets[dataset_id] = DatasetRecord(
            dataset_id=dataset_id,
            n_rows=table.n_rows,
            n_cols=table.n_cols,
            columns=list(table.columns),
            table=table,
            is_source=True,
        )
        return dataset_id

    def record(
        self,
        input_ids: Sequence[str],
        output_id: str,
        out_table: Table,
        info: CaptureInfo,
        keep_output: bool = False,
        input_tables: Optional[Sequence[Table]] = None,
    ) -> str:
        """Register one executed operation.  ``keep_output`` marks pipeline
        sinks (always materialized).  ``input_tables`` lets the caller hand
        over inputs so the §III-E policy can materialize them for contextual
        ops (TrackedTable always passes them)."""
        if output_id in self.datasets:
            # every dataset has exactly ONE producer; silently overwriting
            # would leave both ops in the DAG and corrupt every walk (and the
            # hop-cache's keep-on-append invalidation policy relies on it)
            raise ValueError(
                f"{info.op_name}: output dataset {output_id!r} already exists"
            )
        for k, d in enumerate(input_ids):
            if d not in self.datasets:
                raise KeyError(f"unknown input dataset {d}")
            if self.datasets[d].n_rows != info.n_in[k]:
                raise ValueError(
                    f"{info.op_name}: input {d} has {self.datasets[d].n_rows} rows, "
                    f"capture says {info.n_in[k]}"
                )
        for hook in self._record_hooks:
            hook(list(input_ids), output_id, out_table, info, input_tables)
        tensor = build_tensor(info)
        op = OpRecord(
            op_id=len(self.ops),
            info=info,
            tensor=tensor,
            input_ids=list(input_ids),
            output_id=output_id,
        )
        self.ops.append(op)
        if self._spill is not None:
            self._spill.on_record(op)
        self.version += 1
        self.producer[output_id] = op.op_id
        for d in input_ids:
            self.consumers.setdefault(d, []).append(op.op_id)
        self.datasets[output_id] = DatasetRecord(
            dataset_id=output_id,
            n_rows=out_table.n_rows,
            n_cols=out_table.n_cols,
            columns=list(out_table.columns),
            table=out_table if keep_output else None,
            is_sink=keep_output,
        )
        # materialization policy: contextual ops keep their INPUT datasets
        if info.contextual:
            for k, d in enumerate(input_ids):
                rec = self.datasets[d]
                if rec.table is None:
                    if input_tables is not None and input_tables[k] is not None:
                        rec.table = input_tables[k]
                    else:
                        raise RuntimeError(
                            f"contextual op {info.op_name} needs materialized input {d}; "
                            "pass input_tables (TrackedTable does this automatically)"
                        )
        return output_id

    # -- graph helpers ---------------------------------------------------------
    def downstream_ops(self, dataset_id: str) -> List[OpRecord]:
        """Ops reachable forward from ``dataset_id``, topologically ordered
        (op registration order is already topological — pipelines execute in
        precedence order)."""
        reach = {dataset_id}
        out = []
        for op in self.ops:
            if any(d in reach for d in op.input_ids):
                out.append(op)
                reach.add(op.output_id)
        return out

    def upstream_ops(self, dataset_id: str) -> List[OpRecord]:
        """Ops contributing to ``dataset_id``, topologically ordered."""
        reach = {dataset_id}
        out = []
        for op in reversed(self.ops):
            if op.output_id in reach:
                out.append(op)
                reach.update(op.input_ids)
        return list(reversed(out))

    def path_exists(self, src: str, dst: str) -> bool:
        if src == dst:
            return True
        reach = {src}
        for op in self.ops:
            if any(d in reach for d in op.input_ids):
                reach.add(op.output_id)
        return dst in reach

    def sources(self) -> List[str]:
        return [d for d, r in self.datasets.items() if r.is_source]

    def sinks(self) -> List[str]:
        produced = set(self.producer)
        consumed = set(self.consumers)
        return [d for d in produced if d not in consumed]

    def composed(self, **kwargs):
        """The index's shared :class:`~repro.core.hopcache.ComposedIndex`.

        Created lazily (late import — hopcache builds on compose which builds
        on this module); pass kwargs (e.g. ``memory_budget_bytes``) on first
        call to configure it."""
        from repro.core.hopcache import ComposedIndex  # circular at module scope

        if self._composed is None:
            self._composed = ComposedIndex(self, **kwargs)
        elif kwargs:
            raise ValueError("composed() already configured; use index.composed()")
        return self._composed

    def session(self, **kwargs):
        """The index's shared :class:`~repro.provenance.session.QuerySession`
        — the planner/executor behind ``repro.provenance.prov(index)`` and
        the legacy ``q1``-``q11`` shims.  It wraps :meth:`composed`, so every
        caller (examples, serving tier, benchmarks) probes the same composed
        relations.  Pass kwargs (e.g. ``hopcache_min_batch``) on first call
        to configure it."""
        from repro.provenance import QuerySession  # circular at module scope

        if self._session is None:
            self._session = QuerySession(self, **kwargs)
        elif kwargs:
            raise ValueError("session() already configured; use index.session()")
        return self._session

    def export(self, dataset_id: str):
        """Mint a read-only :class:`~repro.provenance.catalog.BoundaryHandle`
        over ``dataset_id`` — the capability another party (a serving tier,
        a downstream pipeline's catalog) registers to trace lineage back
        through this index WITHOUT receiving the index itself.  The handle
        can probe relations among the ancestors of the boundary dataset and
        nothing else: no ``record()``/``add_source()``, no non-ancestor
        datasets.  The ancestor closure is fixed at export time (the op DAG
        is append-only with one producer per dataset, so no later write can
        extend an existing dataset's ancestry)."""
        from repro.provenance.catalog import BoundaryHandle  # circular at module scope

        return BoundaryHandle(self, dataset_id)

    # -- memory accounting (Table IX / Table XI) --------------------------------
    def prov_nbytes(self) -> int:
        """Bytes of the provenance encoding proper: tensors (COO + built CSR
        halves) + schema bitsets/permutation lists.  Materialized datasets are
        NOT provenance — they are accounted separately."""
        total = 0
        for op in self.ops:
            total += op.tensor.nbytes()
            for amap in op.info.attr_maps:
                total += amap.nbytes()
        return total

    def materialized_nbytes(self) -> int:
        return sum(r.table.nbytes() for r in self.datasets.values() if r.table is not None)

    def stats(self) -> Dict[str, float]:
        out = {
            "ops": len(self.ops),
            "datasets": len(self.datasets),
            "prov_bytes": self.prov_nbytes(),
            "materialized_bytes": self.materialized_nbytes(),
            "nnz": sum(op.tensor.nnz for op in self.ops),
        }
        if self._spill is not None:
            out["spill"] = self._spill.stats()
        return out
