"""Per-record recomputation of non-materialized intermediates (paper §III-E).

The materialization policy keeps only pipeline sources/sinks and inputs of
*contextual* operations.  A query that must RETURN data from a
non-materialized intermediate dataset re-executes, per record, the op chain
from the nearest materialized ancestor — but only on the provenance-related
rows the tensors identify, never the whole dataset.

* localized op: re-run its value function on exactly the gathered input rows
  (contextual ops re-apply their STORED whole-dataset statistics, so the
  result is numerically identical to the original run);
* oversample's jitter is regenerated from the stored seed, so even synthetic
  rows recompute exactly;
* join/append outputs are assembled directly from their provenance-related
  input rows via the stored attribute permutations.

``recompute_rows(index, dataset, rows)`` returns a Table whose i-th row is
record ``rows[i]`` of ``dataset``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.opcat import OpCategory
from repro.core.pipeline import OpRecord, ProvenanceIndex
from repro.dataprep import ops as P
from repro.dataprep.table import Table

__all__ = ["materialized_frontier", "recompute_rows", "fetch_rows"]


def materialized_frontier(index: ProvenanceIndex, dataset: str) -> str:
    """Nearest materialized ancestor of ``dataset`` (itself if materialized)."""
    cur = dataset
    while not index.datasets[cur].materialized:
        if cur not in index.producer:
            raise RuntimeError(f"{dataset}: no materialized ancestor (corrupt policy)")
        op = index.ops[index.producer[cur]]
        nxt = None
        for in_id in op.input_ids:
            if index.datasets[in_id].materialized:
                nxt = in_id
                break
        cur = nxt if nxt is not None else op.input_ids[0]
    return cur


def fetch_rows(index: ProvenanceIndex, dataset: str, rows: np.ndarray) -> Table:
    """Rows (duplicates allowed, any order) of ``dataset``, aligned 1:1."""
    rows = np.asarray(rows, dtype=np.int64)
    uniq, inv = np.unique(rows, return_inverse=True)
    sub = recompute_rows(index, dataset, uniq)
    return sub.take_rows(inv)


def _apply_rowwise(op: OpRecord, t: Table) -> Table:
    """Re-execute a row-local (identity-category) op on a row subset."""
    info = op.info
    name, params = info.op_name, info.params
    out = t.copy()
    if name.startswith("transform:"):
        j = t.cid(params["col"])
        out.data[:, j] = P.TRANSFORM_FNS[params["fn"]](
            t.data[:, j], params["fn_params"]).astype(np.float32)
        return out
    if name.startswith("normalize:"):
        for c, st in params["stats"].items():
            j = t.cid(c)
            if params["kind"] == "zscore":
                mu, sd = st
                out.data[:, j] = (t.data[:, j] - mu) / (sd or 1.0)
            else:
                lo, hi = st
                out.data[:, j] = (t.data[:, j] - lo) / ((hi - lo) or 1.0)
        return out
    if name.startswith("impute:"):
        for c, fill in params["fills"].items():
            j = t.cid(c)
            null = out.null[:, j]
            out.data[null, j] = fill
            out.null[:, j] = False
        return out
    if name.startswith("discretize:"):
        j = t.cid(params["col"])
        edges = np.asarray(params["edges"], dtype=np.float32)
        out.data[:, j] = np.searchsorted(edges, t.data[:, j]).astype(np.float32)
        return out
    if name in ("select_columns", "drop_columns"):
        keep = (params["cols"] if name == "select_columns"
                else [c for c in t.columns if c not in set(params["cols"])])
        return t.take_cols(keep)
    if name == "onehot":
        out2, _ = P.onehot(t, params["col"], params["n_values"])
        return out2
    if name == "string_indexer":
        j = t.cid(params["col"])
        domain = np.asarray(params["domain"], dtype=np.float32)
        codes = np.searchsorted(domain, t.data[:, j]).astype(np.float32)
        return Table(
            columns=t.columns + [f"{params['col']}#idx"],
            data=np.concatenate([t.data, codes[:, None]], axis=1),
            null=np.concatenate([t.null, t.null[:, j: j + 1]], axis=1),
            index=t.index.copy(), vocab=dict(t.vocab),
        )
    if name == "space_transform":
        out2, _ = P.space_transform(t, params["cols"], params["proj"],
                                    params.get("prefix", "pc"))
        return out2
    raise NotImplementedError(name)


def recompute_rows(index: ProvenanceIndex, dataset: str, rows: Sequence[int]) -> Table:
    """Table whose i-th row is record rows[i] of ``dataset`` (exact values)."""
    rows = np.asarray(list(rows), dtype=np.int64)
    rec = index.datasets[dataset]
    if rec.materialized:
        return rec.table.take_rows(rows)

    op = index.ops[index.producer[dataset]]
    op.tensor.resident()  # fault a spilled tensor back: the payload reads
    info = op.info        # below (kept_rows/src_rows/join_pairs) alias it
    cat = info.category

    if cat in (OpCategory.TRANSFORM, OpCategory.VREDUCE, OpCategory.VAUGMENT):
        sub = fetch_rows(index, op.input_ids[0], rows)
        return _apply_rowwise(op, sub)

    if cat is OpCategory.HREDUCE:
        in_rows = np.asarray(info.kept_rows, dtype=np.int64)[rows]
        return fetch_rows(index, op.input_ids[0], in_rows)

    if cat is OpCategory.HAUGMENT:
        if info.src_rows is None:
            raise NotImplementedError(
                f"{info.op_name}: multi-parent augmentation has no per-row "
                "value recomputation (packed sequences are token streams)")
        src = np.asarray(info.src_rows, dtype=np.int64)[rows]
        if (src < 0).any():
            raise ValueError(f"{info.op_name}: rows {rows[src < 0]} are "
                             "synthetic with no established source")
        sub = fetch_rows(index, op.input_ids[0], src)
        # regenerate oversample jitter exactly from the stored seed
        if info.op_name == "oversample" and info.params.get("noise", 0) > 0:
            n_in = info.n_in[0]
            n_new = info.n_out - n_in
            rng = np.random.default_rng(info.params["seed"])
            rng.integers(0, n_in, size=n_new)          # skip the picks draw
            noise = rng.normal(0.0, info.params["noise"],
                               size=(n_new, sub.n_cols)).astype(np.float32)
            synth = rows >= n_in
            sub.data[synth] += noise[rows[synth] - n_in]
        return sub

    if cat is OpCategory.JOIN:
        pairs = np.asarray(info.join_pairs, dtype=np.int64)[rows]
        has_l, has_r = pairs[:, 0] >= 0, pairs[:, 1] >= 0
        left = fetch_rows(index, op.input_ids[0], np.maximum(pairs[:, 0], 0))
        right = fetch_rows(index, op.input_ids[1], np.maximum(pairs[:, 1], 0))
        # assemble through the stored output-attr -> input-attr permutations
        perm_l = op.info.attr_maps[0].perm
        perm_r = op.info.attr_maps[1].perm
        n_attrs = len(perm_l)
        cols = index.datasets[dataset].columns
        data = np.zeros((len(rows), n_attrs), np.float32)
        null = np.ones((len(rows), n_attrs), bool)
        # right side fills where the left did not (shared key columns keep
        # the left value on matched rows) — perm_l[a] is a scalar, so the
        # per-attr right mask is either all right rows or right-only rows
        right_only = has_r & ~has_l
        for a in range(n_attrs):
            if perm_l[a] >= 0:
                data[has_l, a] = left.data[has_l, perm_l[a]]
                null[has_l, a] = left.null[has_l, perm_l[a]]
            if perm_r[a] >= 0:
                use_r = right_only if perm_l[a] >= 0 else has_r
                data[use_r, a] = right.data[use_r, perm_r[a]]
                null[use_r, a] = right.null[use_r, perm_r[a]]
        vocab = {c: v for c, v in {**right.vocab, **left.vocab}.items()
                 if c in set(cols)}
        return Table(columns=list(cols), data=data, null=null,
                     index=rows.copy(), vocab=vocab)

    if cat is OpCategory.APPEND:
        n_l = info.n_in[0]
        is_l = rows < n_l
        out_cols = index.datasets[dataset].columns
        perm_l = op.info.attr_maps[0].perm
        perm_r = op.info.attr_maps[1].perm
        data = np.zeros((len(rows), len(out_cols)), np.float32)
        null = np.ones((len(rows), len(out_cols)), bool)
        vocab = {}
        if (~is_l).any():
            rt = fetch_rows(index, op.input_ids[1], rows[~is_l] - n_l)
            vocab.update(rt.vocab)
            for a in range(len(out_cols)):
                if perm_r[a] >= 0:
                    data[~is_l, a] = rt.data[:, perm_r[a]]
                    null[~is_l, a] = rt.null[:, perm_r[a]]
        if is_l.any():
            lt = fetch_rows(index, op.input_ids[0], rows[is_l])
            vocab.update(lt.vocab)
            for a in range(len(out_cols)):
                if perm_l[a] >= 0:
                    data[is_l, a] = lt.data[:, perm_l[a]]
                    null[is_l, a] = lt.null[:, perm_l[a]]
        vocab = {c: v for c, v in vocab.items() if c in set(out_cols)}
        return Table(columns=list(out_cols), data=data, null=null,
                     index=rows.copy(), vocab=vocab)

    raise NotImplementedError(cat)
