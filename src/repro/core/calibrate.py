"""Empirical calibration of the cost model (ROADMAP item 4).

The routing constants in :mod:`repro.core.costmodel` were guessed once
against the CPU container; this module MEASURES them on the actual backend:

* a microbench grid over density × shape times the three composition
  primitives the router prices — the packed-bitplane compose
  (:func:`repro.kernels.ops.bitmatmul`, through its own kernel-launch
  guard, so TPU measures the Pallas kernel and hosts measure the oracle),
  scipy CSR spmm, and the fused batched walk;
* medians per grid point feed linear least-squares fits
  ``time = overhead + slope × work`` giving ``c_word_op`` /
  ``c_spmm_flop`` / ``c_spmm_overhead`` / ``c_launch_overhead``, and the
  CSR-vs-bitplane crossover ``density_threshold =
  sqrt(c_word_op / (32 · c_spmm_flop))`` (the same identity the default
  0.06 was derived from);
* the fitted :class:`~repro.core.costmodel.Constants` persist to a JSON
  calibration file keyed by device kind, which
  :func:`repro.core.costmodel.maybe_load_calibration` installs on the
  first :class:`CostModel` of any later process — ``CostModel``,
  ``ComposedIndex(backend="auto")`` and ``QuerySession._strategy`` then
  run on measured numbers, and ``explain()`` reports their provenance.

The machine roofline terms (peak FLOPs / HBM / VPU word-op rate) ride in
the same file so ``bench_compose_roofline`` and the cost model can never
disagree about the machine; they keep their v5e defaults until a real-TPU
pass overwrites them.

Run directly::

    PYTHONPATH=src python -m repro.core.calibrate [--full] [--path FILE]
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import Constants

__all__ = [
    "default_path",
    "device_kind",
    "run_microbench",
    "fit_constants",
    "save_constants",
    "load_constants",
    "calibrate",
]

_FILE_VERSION = 1


def default_path() -> str:
    """``$REPRO_CALIBRATION`` or ``~/.cache/repro/calibration.json`` — the
    same resolution :func:`costmodel.maybe_load_calibration` uses."""
    return os.environ.get("REPRO_CALIBRATION") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "calibration.json")


def device_kind(allow_import: bool = True) -> str:
    """Device-kind key for the calibration file (e.g. ``TPU-v5e`` /
    ``cpu``).  With ``allow_import=False`` jax is only consulted when some
    other module already imported it — the jax-free load path."""
    import sys

    if allow_import or "jax" in sys.modules:
        try:
            import jax

            devs = jax.devices()
            if devs:
                return str(devs[0].device_kind).replace(" ", "-")
            return str(jax.default_backend())
        except Exception:  # pragma: no cover - broken jax install
            pass
    return "cpu"


# ---------------------------------------------------------------------------
# Microbench harness
# ---------------------------------------------------------------------------
def _median_ns(fn, reps: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e9


def _random_plane(rng, rows: int, cols: int, density: float) -> np.ndarray:
    import jax.numpy as jnp
    from repro.kernels import ref

    dense = rng.random((rows, cols)) < density
    return np.asarray(ref.pack_bits(jnp.asarray(dense)))


def run_microbench(quick: bool = True, seed: int = 0) -> Dict[str, object]:
    """Time bitmatmul / CSR-spmm / fused-walk over a density × shape grid.

    Every primitive runs through its OWN kernel-launch guard
    (``use_pallas=None``) so the measurement reflects the backend this
    process would actually route to.  Returns raw grid rows (medians, ns)
    plus the device kind — :func:`fit_constants` turns them into a
    :class:`Constants`.
    """
    from repro.kernels import ops as K

    try:
        import scipy.sparse as sp
    except ImportError:  # pragma: no cover - scipy is a baked-in dep here
        sp = None

    rng = np.random.default_rng(seed)
    if quick:
        sizes = [128, 256, 512]
        densities = [0.02, 0.1]
        reps = 3
    else:
        sizes = [128, 256, 512, 1024, 2048]
        densities = [0.005, 0.02, 0.08, 0.25]
        reps = 7
    rows: List[Dict[str, object]] = []

    for n in sizes:
        nw = (n + 31) // 32
        for d in densities:
            a = _random_plane(rng, n, n, d)
            b = _random_plane(rng, n, n, d)
            t = _median_ns(
                lambda: np.asarray(K.bitmatmul(a, b, use_pallas=None)),
                reps=reps)
            rows.append({"kind": "bitmatmul", "n": n, "density": d,
                         "word_ops": n * n * nw, "t_ns": t})
            if sp is not None:
                da = sp.random(n, n, density=d, format="csr",
                               random_state=int(rng.integers(1 << 30)),
                               dtype=np.float32)
                db = sp.random(n, n, density=d, format="csr",
                               random_state=int(rng.integers(1 << 30)),
                               dtype=np.float32)
                out_deg = db.nnz / max(n, 1)
                t = _median_ns(lambda: (da @ db).tocsr(), reps=reps)
                rows.append({"kind": "spmm", "n": n, "density": d,
                             "flops": da.nnz * out_deg, "t_ns": t})

    # fused-walk dispatch: the smallest chain isolates per-launch overhead
    n, hops = 128, 4
    planes = [_random_plane(rng, n, n, 0.05) for _ in range(hops)]
    mask = _random_plane(rng, 8, n, 0.05)
    t = _median_ns(
        lambda: tuple(np.asarray(x) for x in
                      K.batched_walk(mask, planes, use_pallas=None)),
        reps=reps)
    rows.append({"kind": "fused_walk", "n": n, "hops": hops, "t_ns": t})
    return {"device": device_kind(), "rows": rows}


def _line_fit(xs: List[float], ys: List[float]) -> tuple:
    """(slope, intercept) least squares, both clamped non-negative."""
    if len(xs) < 2:
        return 0.0, float(ys[0]) if ys else 0.0
    slope, intercept = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), 1)
    return max(float(slope), 1e-6), max(float(intercept), 0.0)


def fit_constants(meas: Dict[str, object],
                  base: Optional[Constants] = None) -> Constants:
    """Fit routing constants from :func:`run_microbench` output.

    Non-measured constants (walk dispatch, stitch, machine roofline terms)
    carry over from ``base`` (default: the uncalibrated defaults).
    """
    base = base or Constants()
    rows = meas["rows"]
    bm = [r for r in rows if r["kind"] == "bitmatmul"]
    sm = [r for r in rows if r["kind"] == "spmm"]
    fw = [r for r in rows if r["kind"] == "fused_walk"]

    word_slope, word_icpt = _line_fit([r["word_ops"] for r in bm],
                                      [r["t_ns"] for r in bm])
    updates: Dict[str, object] = {
        "c_word_op": word_slope,
        "source": "calibrated",
        "device": str(meas["device"]),
    }
    launch = [word_icpt] + [float(r["t_ns"]) for r in fw]
    updates["c_launch_overhead"] = max(float(np.median(launch)), 1.0)
    if sm:
        spmm_slope, spmm_icpt = _line_fit([r["flops"] for r in sm],
                                          [r["t_ns"] for r in sm])
        updates["c_spmm_flop"] = spmm_slope
        updates["c_spmm_overhead"] = max(spmm_icpt, 1.0)
        # the CSR/bitplane crossover, from the same identity as the default
        thr = float(np.sqrt(word_slope / (32.0 * spmm_slope)))
        updates["density_threshold"] = float(np.clip(thr, 1e-4, 0.5))
    return dataclasses.replace(base, **updates)


# ---------------------------------------------------------------------------
# Persistence (JSON, keyed by device kind)
# ---------------------------------------------------------------------------
def save_constants(constants: Constants, path: Optional[str] = None) -> str:
    """Merge one device's constants into the calibration file."""
    path = path or default_path()
    data: Dict[str, object] = {"version": _FILE_VERSION, "devices": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if isinstance(old.get("devices"), dict):
                data["devices"] = old["devices"]
        except (OSError, ValueError):
            pass
    entry = dataclasses.asdict(constants)
    entry.pop("source", None)
    entry.pop("device", None)
    entry.pop("path", None)
    data["devices"][constants.device or device_kind()] = entry
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def load_constants(path: Optional[str] = None,
                   device: Optional[str] = None) -> Optional[Constants]:
    """Constants for this device kind from the calibration file, or None.

    jax-free: when jax is not already imported the device key falls back to
    ``"cpu"``; a file holding exactly one device entry matches regardless
    (one-machine calibration files shouldn't depend on import order).
    """
    path = path or default_path()
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    devices = data.get("devices")
    if not isinstance(devices, dict) or not devices:
        return None
    key = device or device_kind(allow_import=False)
    if key not in devices:
        if len(devices) == 1:
            key = next(iter(devices))
        else:
            return None
    entry = devices[key]
    fields = {f.name for f in dataclasses.fields(Constants)}
    kwargs = {k: v for k, v in entry.items() if k in fields}
    kwargs.update(source="calibrated", device=key,
                  path=os.path.abspath(path))
    try:
        return Constants(**kwargs)
    except TypeError:
        return None


def calibrate(path: Optional[str] = None, quick: bool = True,
              install: bool = True, seed: int = 0) -> Constants:
    """Measure → fit → persist → (optionally) install, in one call."""
    meas = run_microbench(quick=quick, seed=seed)
    fitted = fit_constants(meas)
    saved = save_constants(fitted, path)
    fitted = dataclasses.replace(fitted, path=os.path.abspath(saved))
    if install:
        costmodel.set_constants(fitted)
    return fitted


if __name__ == "__main__":  # pragma: no cover - CLI convenience
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full density × shape grid (default: quick)")
    ap.add_argument("--path", default=None,
                    help=f"calibration file (default: {default_path()})")
    args = ap.parse_args()
    c = calibrate(path=args.path, quick=not args.full)
    print(f"calibrated for {c.device!r} -> {c.path}")
    for k, v in sorted(c.provenance().items()):
        print(f"  {k}: {v}")
