"""TensProv core: tensors, schema metadata, capture, queries, composition."""
from repro.core.provtensor import ProvTensor
from repro.core.pipeline import ProvenanceIndex
