"""Operation taxonomy (paper Table I / Section II) and capture payloads."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.schema import Bitset, rank_positions

__all__ = ["OpCategory", "AttrMap", "CaptureInfo"]


def _pack_pairs(rows: np.ndarray, cols: np.ndarray, n_rows: int, n_cols: int) -> np.ndarray:
    """Scatter (row, col) edges into a packed uint32 bitplane (n_rows, ⌈n_cols/32⌉)."""
    plane = np.zeros((n_rows, max((n_cols + 31) // 32, 1)), dtype=np.uint32)
    keep = (rows >= 0) & (rows < n_rows) & (cols >= 0) & (cols < n_cols)
    rows, cols = rows[keep], cols[keep]
    np.bitwise_or.at(
        plane,
        (rows, cols // 32),
        np.left_shift(np.uint32(1), (cols % 32).astype(np.uint32)),
    )
    return plane


class OpCategory(enum.Enum):
    TRANSFORM = "data_transformation"
    VREDUCE = "vertical_reduction"
    VAUGMENT = "vertical_augmentation"
    HREDUCE = "horizontal_reduction"
    HAUGMENT = "horizontal_augmentation"
    JOIN = "join"
    APPEND = "append"


# Categories whose record-level tensor is the 2-D identity (paper §III-A).
IDENTITY_CATEGORIES = (OpCategory.TRANSFORM, OpCategory.VREDUCE, OpCategory.VAUGMENT)
# Categories whose attribute mapping is positional identity (paper §IV).
IDENTITY_ATTR_CATEGORIES = (OpCategory.TRANSFORM, OpCategory.HREDUCE, OpCategory.HAUGMENT)


@dataclasses.dataclass
class AttrMap:
    """Attribute mapping between ONE input schema and the output schema.

    ``kind``:
      * 'identity'  — positional identity (no bitset stored; paper §IV)
      * 'vreduce'   — ``bitset`` over input attrs (1 = kept)
      * 'vaugment'  — ``bitset`` over output attrs (first m = inputs used to
                       engineer, bits >= m = the new attrs), ``m`` = #input attrs
      * 'join'      — ``bitset`` over output attrs (1 = from this input);
                       ``perm`` optional explicit output-attr -> input-attr list
                       (the paper's order-changing fallback)
    """

    kind: str
    bitset: Optional[Bitset] = None
    m: Optional[int] = None
    perm: Optional[np.ndarray] = None  # int32 (n_out_attrs,), -1 = not from here
    # cached packed attribute bitplanes, keyed (n_in_attrs, n_out_attrs):
    _planes: Dict = dataclasses.field(default_factory=dict, repr=False, compare=False)

    def nbytes(self) -> int:
        total = 0
        if self.bitset is not None:
            total += self.bitset.nbytes()
        if self.perm is not None:
            total += int(self.perm.nbytes)
        for plane in self._planes.values():
            total += int(plane.nbytes)
        return total

    # -- vectorized realization (query engine hot path) ----------------------
    def pairs(self, n_in: int, n_out: int):
        """The attribute relation as an (in_attr, out_attr) int32 edge list.

        One vectorized construction per ``kind`` — the per-attribute rank /
        select dispatch of the Table-VI maps collapses into cumsums and
        flatnonzeros over the bitset.
        """
        if self.kind == "identity":
            i = np.arange(min(n_in, n_out), dtype=np.int32)
            return i, i
        if self.kind == "vreduce":
            if self.perm is not None:  # order-changing fallback (paper: int list)
                perm = np.asarray(self.perm, dtype=np.int32)
                return perm, np.arange(len(perm), dtype=np.int32)
            rp = rank_positions(self.bitset)   # map_vr_f at every position at once
            kept = np.flatnonzero(rp >= 0).astype(np.int32)
            return kept, rp[kept]
        if self.kind == "vaugment":
            m = self.m
            new = self.bitset.indices().astype(np.int32)
            eng = new[new < m]          # input attrs used to engineer features
            new = new[new >= m]         # the engineered output attrs
            i = np.arange(min(m, n_out), dtype=np.int32)
            return (
                np.concatenate([i, np.repeat(eng, len(new))]),
                np.concatenate([i, np.tile(new, len(eng))]),
            )
        if self.kind == "join":
            if self.perm is not None:
                out = np.flatnonzero(np.asarray(self.perm) >= 0).astype(np.int32)
                return np.asarray(self.perm, dtype=np.int32)[out], out
            outpos = self.bitset.indices().astype(np.int32)  # select(i+1) per i
            k = min(n_in, len(outpos))
            return np.arange(k, dtype=np.int32), outpos[:k]
        raise ValueError(self.kind)

    def fwd_plane(self, n_in: int, n_out: int) -> np.ndarray:
        """uint32 (n_in, ⌈n_out/32⌉): row i = packed output attrs fed by input
        attr i.  Memoized — built once per (shape) and reused every query."""
        key = ("f", n_in, n_out)
        if key not in self._planes:
            i, o = self.pairs(n_in, n_out)
            self._planes[key] = _pack_pairs(i, o, n_in, n_out)
        return self._planes[key]

    def bwd_plane(self, n_in: int, n_out: int) -> np.ndarray:
        """uint32 (n_out, ⌈n_in/32⌉): transposed relation for backward maps."""
        key = ("b", n_in, n_out)
        if key not in self._planes:
            i, o = self.pairs(n_in, n_out)
            self._planes[key] = _pack_pairs(o, i, n_out, n_in)
        return self._planes[key]


@dataclasses.dataclass
class CaptureInfo:
    """Everything an operation hands to the provenance index at capture time."""

    op_name: str                       # e.g. 'filter', 'onehot', 'join'
    category: OpCategory
    contextual: bool                   # paper §III-E materialization policy
    n_out: int
    n_in: List[int]
    # record-level link payload (exactly one of these per category):
    kept_rows: Optional[np.ndarray] = None    # HREDUCE: out i <- in kept[i]
    src_rows: Optional[np.ndarray] = None     # HAUGMENT: out i <- in src[i] (-1 ok)
    join_pairs: Optional[np.ndarray] = None   # JOIN: (n_out, 2), -1 for outer dangles
    links: Optional[np.ndarray] = None        # HAUGMENT multi-parent: (nnz, 2) of
                                              # (out_row, in_row) — e.g. sequence
                                              # packing, where one packed sequence
                                              # derives from several documents
    # schema-level (prospective) annotations, one per input:
    attr_maps: List[AttrMap] = dataclasses.field(default_factory=list)
    # recomputation closure: op params needed to re-execute on a subset of rows
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
