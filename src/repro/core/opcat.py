"""Operation taxonomy (paper Table I / Section II) and capture payloads."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.schema import Bitset

__all__ = ["OpCategory", "AttrMap", "CaptureInfo"]


class OpCategory(enum.Enum):
    TRANSFORM = "data_transformation"
    VREDUCE = "vertical_reduction"
    VAUGMENT = "vertical_augmentation"
    HREDUCE = "horizontal_reduction"
    HAUGMENT = "horizontal_augmentation"
    JOIN = "join"
    APPEND = "append"


# Categories whose record-level tensor is the 2-D identity (paper §III-A).
IDENTITY_CATEGORIES = (OpCategory.TRANSFORM, OpCategory.VREDUCE, OpCategory.VAUGMENT)
# Categories whose attribute mapping is positional identity (paper §IV).
IDENTITY_ATTR_CATEGORIES = (OpCategory.TRANSFORM, OpCategory.HREDUCE, OpCategory.HAUGMENT)


@dataclasses.dataclass
class AttrMap:
    """Attribute mapping between ONE input schema and the output schema.

    ``kind``:
      * 'identity'  — positional identity (no bitset stored; paper §IV)
      * 'vreduce'   — ``bitset`` over input attrs (1 = kept)
      * 'vaugment'  — ``bitset`` over output attrs (first m = inputs used to
                       engineer, bits >= m = the new attrs), ``m`` = #input attrs
      * 'join'      — ``bitset`` over output attrs (1 = from this input);
                       ``perm`` optional explicit output-attr -> input-attr list
                       (the paper's order-changing fallback)
    """

    kind: str
    bitset: Optional[Bitset] = None
    m: Optional[int] = None
    perm: Optional[np.ndarray] = None  # int32 (n_out_attrs,), -1 = not from here

    def nbytes(self) -> int:
        total = 0
        if self.bitset is not None:
            total += self.bitset.nbytes()
        if self.perm is not None:
            total += int(self.perm.nbytes)
        return total


@dataclasses.dataclass
class CaptureInfo:
    """Everything an operation hands to the provenance index at capture time."""

    op_name: str                       # e.g. 'filter', 'onehot', 'join'
    category: OpCategory
    contextual: bool                   # paper §III-E materialization policy
    n_out: int
    n_in: List[int]
    # record-level link payload (exactly one of these per category):
    kept_rows: Optional[np.ndarray] = None    # HREDUCE: out i <- in kept[i]
    src_rows: Optional[np.ndarray] = None     # HAUGMENT: out i <- in src[i] (-1 ok)
    join_pairs: Optional[np.ndarray] = None   # JOIN: (n_out, 2), -1 for outer dangles
    links: Optional[np.ndarray] = None        # HAUGMENT multi-parent: (nnz, 2) of
                                              # (out_row, in_row) — e.g. sequence
                                              # packing, where one packed sequence
                                              # derives from several documents
    # schema-level (prospective) annotations, one per input:
    attr_maps: List[AttrMap] = dataclasses.field(default_factory=list)
    # recomputation closure: op params needed to re-execute on a subset of rows
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
